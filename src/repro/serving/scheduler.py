"""Continuous batching: iteration-level scheduling over fixed decode slots.

The serving analogue of the ingest runtime's work-stealing (DESIGN.md §5):
a fixed batch of B decode slots runs one jitted serve step per iteration;
finished requests free their slot immediately and the next queued request is
prefilled into it — no waiting for the whole wave to drain (vLLM-style
iteration-level scheduling, minus paging: slots own fixed-depth caches).

Mechanics:
  * one (B, ...) cache tree lives on device; per-slot positions are a (B,)
    vector (decode_step's per-row path: scatter cache writes, per-row rope);
  * admission prefills a request with batch 1 and writes its cache into the
    slot via indexed tree update;
  * empty slots decode a pad token against their own garbage — masked out.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.model import cache_defs, decode_step, prefill
from ..models.params import init_params


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the batcher
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params: Any, *, num_slots: int = 4,
                 max_len: int = 512) -> None:
        self.cfg = cfg
        self.params = params
        self.B = num_slots
        self.max_len = max_len
        self._prefill1 = jax.jit(lambda p, b: prefill(cfg, p, b, max_len))
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
        # device state: batched cache + per-slot bookkeeping
        self.cache = init_params(jax.random.PRNGKey(0),
                                 cache_defs(cfg, num_slots, max_len))
        self.pos = np.zeros(num_slots, np.int32)
        self.tokens = np.zeros((num_slots, 1), np.int32)
        self.active: List[Optional[Request]] = [None] * num_slots
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.steps = 0

    # ----------------------------------------------------------------- admit
    def submit(self, req: Request) -> None:
        req.t_enqueue = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.B):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            T = len(req.prompt)
            batch = {
                "tokens": jnp.asarray(req.prompt[None, :]),
                "segments": jnp.ones((1, T), jnp.int32),
                "positions": jnp.arange(T, dtype=jnp.int32)[None, :],
            }
            if "cross" in self.cfg.pattern + self.cfg.remainder:
                batch["encoder_embeds"] = jnp.zeros(
                    (1, self.cfg.cross_attn_kv_len, self.cfg.d_model),
                    self.cfg.activation_dtype)
            logits, cache1 = self._prefill1(self.params, batch)
            first = int(jnp.argmax(logits[0, -1]))
            # write the single-request cache into this slot.  Scanned pattern
            # caches carry a leading LAYERS dim — batch is axis 1 there,
            # axis 0 for the unrolled remainder caches.
            self.cache = {
                "pattern": jax.tree.map(
                    lambda full, one: full.at[:, slot].set(one[:, 0]),
                    self.cache["pattern"], cache1["pattern"]),
                "remainder": jax.tree.map(
                    lambda full, one: full.at[slot].set(one[0]),
                    self.cache["remainder"], cache1["remainder"]),
            }
            req.slot = slot
            req.generated = [first]
            req.t_first_token = time.perf_counter()
            self.active[slot] = req
            self.pos[slot] = T
            self.tokens[slot, 0] = first

    # ------------------------------------------------------------------ step
    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        req.t_done = time.perf_counter()
        self.done.append(req)
        self.active[slot] = None

    def step(self) -> None:
        """One decode iteration across all occupied slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        self.steps += 1
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.generated.append(int(nxt[slot]))
            self.pos[slot] += 1
            self.tokens[slot, 0] = nxt[slot]
            hit_eos = (req.eos_id is not None
                       and req.generated[-1] == req.eos_id)
            if len(req.generated) >= req.max_new_tokens or hit_eos:
                self._retire(slot)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain.  Returns finished requests."""
        while (self.queue or any(r is not None for r in self.active)) \
                and self.steps < max_steps:
            self.step()
        return self.done
