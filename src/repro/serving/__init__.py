from .scheduler import ContinuousBatcher, Request
