import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod AOT dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the exact step the production job would run
(train_step / prefill / serve_step), with parameters, optimizer state, and
decode caches as ShapeDtypeStructs (no allocation), jits it with the
production in/out shardings, and runs ``.lower().compile()``.  Success proves
the distribution config is coherent: every collective the partitioner needs
exists and every per-device buffer fits.

Outputs per cell (written to benchmarks/artifacts/dryrun/*.json):
  memory_analysis  — per-device argument/output/temp bytes (proves it fits)
  cost_analysis    — HLO FLOPs + bytes accessed (roofline compute/memory terms)
  collectives      — per-op-kind traffic parsed from the optimized HLO
                     (roofline collective term)

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def artifact_path(arch: str, shape: str, multi_pod: bool) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    return os.path.abspath(os.path.join(
        ART_DIR, f"{arch}__{shape}__{_mesh_tag(multi_pod)}.json"))


# --------------------------------------------------------------- collectives
_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_RESULT_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
# iota format: replica_groups=[num_groups,group_size]<=[total](T(perm))?
_IOTA_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective traffic from optimized HLO (ring model):
      all-reduce: 2·R·(n-1)/n    all-gather: R·(n-1)/n  (R = result bytes)
      reduce-scatter: R·(n-1)    all-to-all: R·(n-1)/n  permute: R
    """
    per_kind_bytes: Dict[str, float] = {}
    per_kind_count: Dict[str, int] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line:
            continue
        op = m.group("op")
        # result may be a tuple — sum every shape token inside it
        r = 0
        for dtype, dims in _RESULT_SHAPE_RE.findall(m.group("result")):
            dt = _DTYPE_BYTES.get(dtype)
            if dt is None:
                continue
            numel = 1
            for d in dims.split(","):
                if d.strip():
                    numel *= int(d)
            r += numel * dt
        if r == 0:
            continue
        g = _GROUP_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            gi = _IOTA_GROUP_RE.search(line)
            n = int(gi.group(2)) if gi else 2
        if n <= 1:
            continue
        if op == "all-reduce":
            traffic = 2.0 * r * (n - 1) / n
        elif op == "all-gather":
            traffic = r * (n - 1) / n
        elif op == "reduce-scatter":
            traffic = r * (n - 1)
        elif op == "all-to-all":
            traffic = r * (n - 1) / n
        else:  # collective-permute
            traffic = float(r)
        per_kind_bytes[op] = per_kind_bytes.get(op, 0.0) + traffic
        per_kind_count[op] = per_kind_count.get(op, 0) + 1
        total += traffic
    return {"total_bytes": total, "by_kind_bytes": per_kind_bytes,
            "by_kind_count": per_kind_count}


# ------------------------------------------------------------- memory model
# The CPU backend barely fuses, so raw "bytes accessed" counts every convert/
# broadcast/multiply as HBM traffic — a TPU fuses those chains into their
# producing/consuming matmuls.  This model walks the optimized HLO and counts
# operand+result bytes ONLY for ops that genuinely materialize on TPU:
_MATERIALIZING = (
    "dot", "convolution", "fusion", "reduce", "reduce-window", "sort",
    "transpose", "copy", "concatenate", "pad", "reverse", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "all-reduce", "all-gather",
    "reduce-scatter", "all-to-all", "collective-permute", "select-and-scatter",
)
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    dt = _DTYPE_BYTES.get(dtype)
    if dt is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * dt


def tpu_memory_bytes(hlo_text: str) -> float:
    """Approximate per-device HBM traffic: sum of operand+result bytes over
    materializing ops (elementwise/convert/broadcast/bitcast assumed fused).

    Only ENTRY-computation instructions count: ops inside fusion bodies are
    VMEM/register-resident on TPU (counting them quadruple-billed the
    attention tiles — the fusion call site already carries its operand and
    result bytes)."""
    total = 0.0
    in_entry = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY "):
            in_entry = True
            continue
        if in_entry and stripped == "}":
            in_entry = False
            continue
        if not in_entry:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        if op not in _MATERIALIZING or "-done" in line:
            continue
        # result + operand shapes all appear as dtype[dims] tokens in the line
        for dtype, dims in _SHAPE_RE.findall(line):
            total += _shape_bytes(dtype, dims)
    return total


# ------------------------------------------------------------------ the cell
def _build_lowered(cfg, shape: str, mesh, *, grad_accum: int, loss_chunk: int,
                   sp: bool = False, dp: bool = False):
    """Build the jitted step for one cfg/shape/mesh and return lowered."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from ..configs import SHAPES, cache_len_for, input_specs
    from ..models.model import cache_defs, model_defs
    from ..models.params import abstract_params, param_specs
    from ..training.optim import opt_state_defs
    from ..training.steps import make_prefill_step, make_serve_step, make_train_step
    from .mesh import (input_shardings, make_constrain, mesh_axis_sizes,
                       sharding_rules)

    spec = SHAPES[shape]
    rules = sharding_rules(cfg, mesh, global_batch=spec.global_batch, dp=dp)
    sizes = mesh_axis_sizes(mesh)
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    pdefs = model_defs(cfg)
    pshard = named(param_specs(pdefs, rules, sizes))
    pabs = abstract_params(pdefs)
    bspecs = input_specs(cfg, shape)
    bshard = input_shardings(mesh, bspecs, dp=dp)

    if spec.kind == "train":
        odefs = opt_state_defs(cfg.optimizer, pdefs)
        oshard = named(param_specs(odefs, rules, sizes))
        oabs = abstract_params(odefs)
        step = make_train_step(cfg, loss_chunk=loss_chunk, grad_accum=grad_accum,
                               constrain=make_constrain(mesh, cfg,
                                                        spec.global_batch,
                                                        gather_weights=True,
                                                        seq_shard=sp,
                                                        seq_len=spec.seq_len,
                                                        dp=dp),
                               grad_shardings=pshard)
        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        return jitted.lower(pabs, oabs, bspecs)
    if spec.kind == "prefill":
        cdefs = cache_defs(cfg, spec.global_batch, cache_len_for(cfg, shape))
        cshard = named(param_specs(cdefs, rules, sizes))
        step = make_prefill_step(
            cfg, cache_len_for(cfg, shape),
            constrain=make_constrain(mesh, cfg, spec.global_batch,
                                     gather_weights=True, seq_shard=sp,
                                     seq_len=spec.seq_len, dp=dp))
        jitted = jax.jit(step, in_shardings=(pshard, bshard),
                         out_shardings=(None, cshard))
        return jitted.lower(pabs, bspecs)
    # decode
    cdefs = cache_defs(cfg, spec.global_batch, cache_len_for(cfg, shape))
    cshard = named(param_specs(cdefs, rules, sizes))
    cabs = abstract_params(cdefs)
    step = make_serve_step(cfg, constrain=make_constrain(
        mesh, cfg, spec.global_batch, gather_weights=True, dp=dp))
    jitted = jax.jit(step, in_shardings=(pshard, cshard, bshard["tokens"], None),
                     out_shardings=(None, None, cshard),
                     donate_argnums=(1,))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted.lower(pabs, cabs, bspecs["tokens"], pos)


def _costs_of(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    text = compiled.as_text()
    coll = parse_collectives(text)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": tpu_memory_bytes(text),
            "bytes_raw": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def _extrapolate(c1: Dict[str, Any], c2: Dict[str, Any], R: int) -> Dict[str, Any]:
    """XLA cost analysis counts while-loop bodies ONCE regardless of trip
    count (verified), so per-step costs are reconstructed from two reduced
    depths: cost(R) = cost(1) + (cost(2) - cost(1)) * (R - 1).  Everything
    per-layer (block compute, per-layer collectives, stacked-param optimizer
    work) is linear in R; everything else (embed, loss, step overhead) sits
    in the intercept."""
    lin = lambda a, b: a + (b - a) * (R - 1)
    kinds = set(c1["coll"]["by_kind_bytes"]) | set(c2["coll"]["by_kind_bytes"])
    coll_bytes = {k: lin(c1["coll"]["by_kind_bytes"].get(k, 0.0),
                         c2["coll"]["by_kind_bytes"].get(k, 0.0)) for k in kinds}
    coll_count = {k: round(lin(c1["coll"]["by_kind_count"].get(k, 0),
                               c2["coll"]["by_kind_count"].get(k, 0))) for k in kinds}
    return {"flops": lin(c1["flops"], c2["flops"]),
            "bytes": lin(c1["bytes"], c2["bytes"]),
            "bytes_raw": lin(c1["bytes_raw"], c2["bytes_raw"]),
            "coll": {"total_bytes": sum(coll_bytes.values()),
                     "by_kind_bytes": coll_bytes,
                     "by_kind_count": coll_count}}


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             grad_accum: int = 1, loss_chunk: int = 1024,
             overrides: Optional[Dict[str, Any]] = None,
             sp: bool = False, dp: bool = False) -> Dict[str, Any]:
    from ..configs import SHAPES, get_config, shape_applicable
    from .mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                       make_production_mesh)

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": "full-attention arch: 500k dense KV cache is the "
                          "quadratic wall (DESIGN.md §4)"}
    spec = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    # auto pure-DP (EXPERIMENTS.md §Perf cell 1): sub-3B models whose heads
    # don't divide the model axis replicate attention under TP — the model
    # axis is worth more as extra data parallelism (42x on musicgen train)
    model_n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if (not dp and cfg.n_heads > 0 and cfg.n_heads % model_n != 0
            and cfg.param_count() < 3e9
            and spec.global_batch % mesh.devices.size == 0):
        dp = True

    # ---- full-config compile: proves sharding coherence + memory fit
    t0 = time.time()
    lowered = _build_lowered(cfg, shape, mesh, grad_accum=grad_accum,
                             loss_chunk=loss_chunk, sp=sp, dp=dp)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # ---- cost terms via unrolled depth extrapolation.  XLA cost analysis
    # counts while-loop bodies ONCE (verified), so the production graph
    # (scanned layers, scanned KV chunks, scanned loss chunks) undercounts.
    # Cost variants therefore unroll everything scanned: layers moved to the
    # unrolled remainder, naive (scan-free) attention, single-chunk loss —
    # all FLOP-equivalent to the production graph — at depths r=1,2, then
    # extrapolate linearly to the full depth.
    P_len, rem = len(cfg.pattern), len(cfg.remainder)
    R = cfg.pattern_repeats
    costs = []
    for r in (1, 2):
        cfg_r = cfg.replace(num_layers=P_len * r + rem).unrolled().replace(
            unroll_scans=True)
        low_r = _build_lowered(cfg_r, shape, mesh, grad_accum=1,
                               loss_chunk=loss_chunk, sp=sp, dp=dp)
        costs.append(_costs_of(low_r.compile()))
    cost_full = _extrapolate(costs[0], costs[1], max(R, 1) if P_len else 1)
    coll = cost_full["coll"]
    flops_dev = cost_full["flops"]
    bytes_dev = cost_full["bytes"]
    # roofline terms (seconds, per device = per step for SPMD)
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll["total_bytes"] / ICI_BW

    # useful-FLOPs model (6·N_active·tokens for train, 2·N_active·tokens fwd)
    n_active = cfg.active_param_count()
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    mult = 6 if spec.kind == "train" else 2
    model_flops = mult * n_active * tokens
    hlo_flops_global = flops_dev * n_chips

    art = {
        "arch": arch, "shape": shape, "mesh": _mesh_tag(multi_pod),
        "n_chips": n_chips, "skipped": False,
        "grad_accum": grad_accum, "loss_chunk": loss_chunk,
        "overrides": overrides or {}, "seq_parallel": sp, "pure_dp": dp,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                           + mem.generated_code_size_in_bytes),
            "fits_16gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
                          < 16 * 1024**3,
        },
        "cost_analysis": {"flops_per_device": flops_dev,
                          "bytes_per_device": bytes_dev,
                          "bytes_per_device_unfused": cost_full["bytes_raw"]},
        "collectives": coll,
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_ratio": model_flops / max(hlo_flops_global, 1.0),
            "roofline_fraction": (min(compute_s / max(
                max(compute_s, memory_s, collective_s), 1e-30), 1.0)),
        },
    }
    return art


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--loss-chunk", type=int, default=1024)
    ap.add_argument("--overrides", type=str, default=None,
                    help="JSON dict of ModelConfig overrides (perf experiments)")
    args = ap.parse_args()

    from ..configs import all_cells
    cells = (all_cells() if args.all else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    overrides = json.loads(args.overrides) if args.overrides else None

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            path = artifact_path(arch, shape, mp)
            if os.path.exists(path) and not args.force:
                print(f"[skip] {arch} {shape} {_mesh_tag(mp)} (cached)")
                continue
            print(f"[cell] {arch} {shape} {_mesh_tag(mp)} ...", flush=True)
            try:
                art = run_cell(arch, shape, multi_pod=mp,
                               grad_accum=args.grad_accum,
                               loss_chunk=args.loss_chunk,
                               overrides=overrides)
            except Exception:
                failures += 1
                print(f"[FAIL] {arch} {shape} {_mesh_tag(mp)}")
                traceback.print_exc()
                continue
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
            if art.get("skipped"):
                print(f"[skip-cell] {arch} {shape}: {art['reason']}")
            else:
                r = art["roofline"]
                print(f"[ok] {arch} {shape} {_mesh_tag(mp)} "
                      f"compile={art['t_compile_s']}s "
                      f"compute={r['compute_s']*1e3:.1f}ms "
                      f"mem={r['memory_s']*1e3:.1f}ms "
                      f"coll={r['collective_s']*1e3:.1f}ms "
                      f"dom={r['dominant']} useful={r['useful_ratio']:.2f}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
