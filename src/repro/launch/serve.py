"""Batched serving driver: prefill a prompt batch, then decode tokens.

Same production code path as the dry-run's prefill/decode cells, runnable on
CPU with the smoke configs:

  python -m repro.launch.serve --arch smollm-135m --smoke --prompt-len 64 \
      --decode-steps 32 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from ..configs import get_config, get_smoke
    from ..models.model import model_defs
    from ..models.params import init_params, param_specs
    from ..training.steps import make_prefill_step, make_serve_step
    from .mesh import mesh_axis_sizes, sharding_rules
    from .train import build_mesh

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = build_mesh(args.mesh)
    B, S = args.batch, args.prompt_len
    max_len = S + args.decode_steps + 1

    rules = sharding_rules(cfg, mesh, global_batch=B)
    sizes = mesh_axis_sizes(mesh)
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    pdefs = model_defs(cfg)
    pshard = named(param_specs(pdefs, rules, sizes))
    params = jax.tree.map(lambda a, s: jax.device_put(a, s),
                          init_params(jax.random.PRNGKey(0), pdefs), pshard)

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompts),
             "segments": jnp.ones((B, S), jnp.int32),
             "positions": jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)}
    if "cross" in cfg.pattern + cfg.remainder:
        batch["encoder_embeds"] = jnp.zeros(
            (B, cfg.cross_attn_kv_len, cfg.d_model), cfg.activation_dtype)

    prefill = jax.jit(make_prefill_step(cfg, max_len))
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    jax.block_until_ready(nxt)
    t_prefill = time.time() - t0

    out_tokens = [np.asarray(nxt)]
    t0 = time.time()
    for i in range(args.decode_steps):
        pos = jnp.asarray(S + i, jnp.int32)
        nxt, logits, cache = serve(params, cache, nxt, pos)
        out_tokens.append(np.asarray(nxt))
    jax.block_until_ready(nxt)
    t_decode = (time.time() - t0) / max(1, args.decode_steps)

    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={S}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; "
          f"decode {t_decode*1e3:.1f} ms/token "
          f"({B/max(t_decode,1e-9):.1f} tok/s aggregate)")
    print(f"[serve] sample continuations: {gen[:2, :12].tolist()}")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
