"""Production mesh + sharding-rule derivation (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state.  Single pod = (16, 16) ("data", "model") — 256 chips; two
pods = (2, 16, 16) ("pod", "data", "model") — the pod axis extends data
parallelism across the DCN.

``sharding_rules`` maps logical parameter axes to mesh axes per arch:
  embed   -> data   (FSDP: params+optimizer sharded over the data axis;
                     gathers stay intra-pod on multi-pod meshes)
  ffn/heads/kv/vocab -> model  (TP)
  experts -> model  (EP) when num_experts divides the model axis, else the
                     expert dim is replicated and ffn stays TP (mixtral)
Divisibility is enforced per-parameter in ``param_specs`` (a 9-head dim never
shards 16 ways — it silently stays replicated, by design).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig

# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh, batch: int, include_model: bool = False
               ) -> Optional[Any]:
    """Longest ("pod","data"[,"model"]) prefix that divides ``batch``."""
    sizes = mesh_axis_sizes(mesh)
    names = ("pod", "data", "model") if include_model else ("pod", "data")
    cand = [a for a in names if a in sizes]
    kept, prod = [], 1
    for a in cand:
        prod *= sizes[a]
        if batch % prod == 0:
            kept.append(a)
        else:
            break
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def sharding_rules(cfg: ModelConfig, mesh: Mesh, *,
                   global_batch: int, dp: bool = False) -> Dict[str, Any]:
    sizes = mesh_axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    ep_ok = (cfg.moe is not None and cfg.moe.num_experts % model_n == 0)
    kv_shardable = cfg.n_kv_heads > 0 and cfg.n_kv_heads % model_n == 0
    if dp:
        # pure data parallelism (+ ZeRO-3 FSDP over every mesh axis): the
        # right regime for models too small to shard — a 16-way TP of a
        # 1.4 B model replicates un-shardable attention 16x (musicgen:
        # mem term 61.8 s -> the model axis becomes extra batch instead)
        return {
            "embed": ("data", "model"), "ffn": None, "heads": None,
            "kv": None, "vocab": None, "experts": None, "layers": None,
            "cache_batch": batch_axes(mesh, global_batch, include_model=True),
            "cache_len": None,
        }
    rules: Dict[str, Any] = {
        "embed": "data",
        "ffn": "model",
        "heads": "model",
        "kv": "model",
        "vocab": "model",
        "experts": "model" if ep_ok else None,
        "layers": None,
        "cache_batch": batch_axes(mesh, global_batch),
        # flash-decoding-style cache sharding: when kv heads don't divide the
        # model axis, shard the cache LENGTH dim instead (partial softmax +
        # tiny all-reduce of the m/l stats, done by GSPMD automatically)
        "cache_len": None if kv_shardable else "model",
    }
    rules.update(cfg.sharding_overrides)
    return rules


def make_constrain(mesh: Mesh, cfg: ModelConfig, global_batch: int,
                   *, gather_weights: bool = False, seq_shard: bool = False,
                   seq_len: int = 0, dp: bool = False):
    """Activation sharding-constraint callback for the step builders.

    Without these pins, GSPMD sometimes replicates the batch dim through the
    loss (a tied embedding's FSDP-sharded contracting dim confuses the
    propagation — verified on gemma-7b: 85 full-batch f32 logits tensors).

    ``gather_weights`` additionally pins the *gathered* (FSDP-unsharded) form
    of each block weight at its use site — on serve paths GSPMD otherwise
    reshards the 32k-token residual stream (2.1 GB f32 transpose+copy per
    matmul, verified on llama prefill) instead of all-gathering the 134 MB
    weight."""
    sizes = mesh_axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    bax = batch_axes(mesh, global_batch, include_model=dp)
    vocab_ax = ("model" if (cfg.vocab_size % model_n == 0 and not dp)
                else None)
    # sequence parallelism (long-prefill): residual stream sharded over the
    # model axis on the SEQ dim; per-layer weights are gathered instead of
    # activations all-reduced — 32k-token activations dwarf the weights.
    sp = (not dp) and seq_shard and seq_len > 0 and seq_len % model_n == 0
    seq_ax = "model" if sp else None

    def tp(dim: int):  # model axis only if the dim divides (and not used by SP)
        return "model" if (not sp and not dp and dim % model_n == 0) else None

    ep_ax = ("model" if (cfg.moe is not None and not dp
                         and cfg.moe.num_experts % model_n == 0) else None)
    weight_specs = {
        "w_q": P(None, tp(cfg.n_heads), None),
        "w_kv": P(None, tp(cfg.n_kv_heads), None),
        "w_o": P(tp(cfg.n_heads), None, None),
        "w_in": P(None, tp(cfg.d_ff) if cfg.d_ff else None),
        "w_out": P(tp(cfg.d_ff) if cfg.d_ff else None, None),
        # MoE: expert dim stays EP-sharded; embed/ffn dims gathered (in bf16,
        # at the use site — otherwise GSPMD gathers the f32 upcast: 2x bytes)
        "w_moe": P(ep_ax, None, None),
        "w_moe_out": P(ep_ax, None, None),
    }

    def constrain(name: str, x):
        if name == "moe_tokens":  # (n_groups, G, D) grouped token stream
            # NOTE: sharding n over (data, model) to force a2a dispatch was
            # tried and catastrophically refuted (54 s -> 3787 s: GSPMD falls
            # back to full rematerialization) — groups stay data-sharded.
            n_ax = bax if isinstance(bax, str) else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(n_ax, None, None)))
        if name == "moe_ecd":   # (n_groups, E, C, D) dispatch intermediates
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bax if isinstance(bax, str) else None,
                                         ep_ax, None, None)))
        if name == "logits":
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bax, None, vocab_ax)))
        if name == "hidden":
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bax, seq_ax, None)))
        if name in weight_specs:
            if not (gather_weights or sp):
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, weight_specs[name]))
        return x

    return constrain


def input_shardings(mesh: Mesh, specs: Dict[str, Any],
                    dp: bool = False) -> Dict[str, Any]:
    """NamedShardings for a batch dict: leading (batch) dim over pod+data
    (+model under pure DP)."""
    out = {}
    for k, v in specs.items():
        b = v.shape[0] if len(v.shape) else 1
        ax = batch_axes(mesh, b, include_model=dp)
        ndim = len(v.shape)
        out[k] = NamedSharding(mesh, P(*([ax] + [None] * (ndim - 1))) if ndim
                               else P())
    return out
