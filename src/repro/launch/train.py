"""End-to-end training driver: ingest -> feed -> pjit train -> checkpoint.

This is the production entry point; the same code path scales from the CPU
smoke configs (mesh 1x1) to the 256-chip pod (mesh 16x16) — only the mesh
and config change.  The data plane is INGESTBASE end to end:

  1. raw token documents are ingested once via the canonical LM plan
     (parse -> pack into device-shaped blocks -> serialize -> store),
  2. the BlockFeeder replays ingested blocks as train batches through
     ingestion-aware access (filterReplica("serialize","packed") +
     splitByKey over feeder tasks + projection pushdown),
  3. the train loop jits the step with production shardings, checkpoints
     asynchronously, and restores elastically (a checkpoint written on one
     mesh restores onto another).

Usage (CPU example — also examples/train_smollm.py):
  python -m repro.launch.train --arch smollm-135m --smoke --steps 200
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_mesh(spec: str):
    from .mesh import make_production_mesh
    if spec == "production":
        return make_production_mesh()
    if spec == "multipod":
        return make_production_mesh(multi_pod=True)
    shape = tuple(int(x) for x in spec.split("x"))
    return jax.make_mesh(shape, ("data", "model")[:len(shape)])


def make_batch(raw, seq_len: int, pad_id: int = 0):
    """BlockFeeder fields -> model batch (next-token labels from tokens)."""
    toks = raw["tokens"].astype(np.int32)
    seg = raw["segment_ids"].astype(np.int32)
    pos = raw["positions"].astype(np.int32)
    mask = raw["loss_mask"].astype(np.int32)
    labels = np.concatenate([toks[:, 1:], np.full((toks.shape[0], 1), -1,
                                                  np.int32)], axis=1)
    # don't predict across packing boundaries
    labels = np.where((seg == np.concatenate(
        [seg[:, 1:], np.zeros((seg.shape[0], 1), np.int32)], axis=1))
        & (mask > 0), labels, -1)
    return {"tokens": toks, "labels": labels, "segments": seg,
            "positions": pos}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--mesh", default="1x1",
                    help='"RxC", "production" (16x16) or "multipod"')
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data-dir", default="/tmp/repro_corpus")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from ..configs import get_config, get_smoke
    from ..core import DataStore
    from ..data.feeder import BlockFeeder, ingest_corpus
    from ..data.generators import gen_token_documents
    from ..models.model import model_defs
    from ..models.params import abstract_params, init_params, param_specs
    from ..training.checkpoint import CheckpointManager, place_on_mesh
    from ..training.optim import make_optimizer, opt_state_defs
    from ..training.steps import make_train_step
    from .mesh import (input_shardings, make_constrain, mesh_axis_sizes,
                       sharding_rules)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = build_mesh(args.mesh)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    # ------------------------------------------------------ 1. ingest corpus
    store = DataStore(args.data_dir, nodes=["n0", "n1", "n2", "n3"])
    if not store.blocks():
        docs = gen_token_documents(args.docs, vocab=cfg.vocab_size,
                                   max_len=args.seq_len)
        rep = ingest_corpus(docs, store, seq_len=args.seq_len,
                            rows_per_block=max(8, args.batch))
        print(f"[ingest] stages={rep.stage_items} wall={rep.wall_time_s:.2f}s")

    # ------------------------------------------------------ 2. feeder
    feeder = BlockFeeder(store, num_tasks=1, task=0, batch_rows=args.batch)
    print(f"[feed] {len(feeder)} packed blocks available")

    # ------------------------------------------------------ 3. jit the step
    rules = sharding_rules(cfg, mesh, global_batch=args.batch)
    sizes = mesh_axis_sizes(mesh)
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    pdefs = model_defs(cfg)
    pshard = named(param_specs(pdefs, rules, sizes))
    odefs = opt_state_defs(cfg.optimizer, pdefs)
    oshard = named(param_specs(odefs, rules, sizes))

    step_fn = make_train_step(
        cfg, loss_chunk=min(1024, args.seq_len), grad_accum=args.grad_accum,
        optimizer_kw={"lr": args.lr},
        constrain=make_constrain(mesh, cfg, args.batch),
        grad_shardings=pshard)
    jitted = jax.jit(step_fn, in_shardings=(pshard, oshard, None),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))

    # ------------------------------------------------------ 4. init / restore
    ckpt = CheckpointManager(args.ckpt_dir, keep=3, async_write=True)
    start = 0
    init_opt, _, _ = make_optimizer(cfg.optimizer, lr=args.lr)
    if args.resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
        pabs = abstract_params(pdefs)
        oabs = abstract_params(odefs)
        params = ckpt.restore(start, {"params": pabs})["params"]
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, s), params, pshard)
        opt_state = ckpt.restore(start, {"opt": oabs})["opt"]
        opt_state = jax.tree.map(lambda a, s: jax.device_put(a, s),
                                 opt_state, oshard)
        feeder.step = start
        print(f"[restore] resumed from step {start} (elastic across meshes)")
    else:
        params = init_params(jax.random.PRNGKey(0), pdefs)
        params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, pshard)
        opt_state = jax.device_put(init_opt(params))

    # ------------------------------------------------------ 5. train loop
    t0 = time.time()
    losses = []
    for i, raw in enumerate(feeder.batches(args.steps)):
        batch = make_batch(raw, args.seq_len)
        params, opt_state, metrics = jitted(params, opt_state, batch)
        step = start + i + 1
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            dt = (time.time() - t0) / (i + 1)
            print(f"[step {step:5d}] loss={losses[-1]:.4f} "
                  f"xent={float(metrics['xent']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:.0f} ms/step", flush=True)
        if step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
    ckpt.save(start + args.steps, {"params": params, "opt": opt_state},
              blocking=True)
    print(f"[done] {args.steps} steps; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
