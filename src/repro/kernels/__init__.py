"""Pallas TPU kernels for the ingest/serve hot spots (DESIGN.md §6).

Each kernel: <name>.py (pl.pallas_call + BlockSpec tiling), a pure oracle in
ref.py, and a jit'd wrapper in ops.py (interpret=True off-TPU).
"""
from .ops import flash_attention, gf256_matmul, pack_tokens

__all__ = ["flash_attention", "gf256_matmul", "pack_tokens"]
