"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CI;
on a TPU backend the real kernels run.  The dry-run/roofline path stays pure
XLA (Pallas custom-calls report no FLOPs to cost_analysis — DESIGN.md §6);
kernels are opt-in at run time.
"""
from __future__ import annotations

from functools import partial

import jax

from .flash_attention import flash_attention as _flash
from .gf256_matmul import gf256_matmul as _gf256
from .pack_tokens import pack_tokens as _pack


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def gf256_matmul(code, data, *, block_n: int = 2048, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _gf256(code, data, block_n=block_n, interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _flash(q, k, v, causal=causal, bq=bq, bk=bk, interpret=interpret)


@partial(jax.jit, static_argnames=("seq_len", "pad_id", "interpret"))
def pack_tokens(flat_tokens, starts, lens, seq_len: int, *, pad_id: int = 0,
                interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _pack(flat_tokens, starts, lens, seq_len, pad_id=pad_id,
                 interpret=interpret)
