"""Pallas TPU kernel: GF(2^8) matrix multiply for Reed-Solomon parity.

The paper's erasure-coding ingest operator is compute-bound: parity =
code_matrix @ data over GF(2^8), where data is a (K, N) stripe of K data
blocks of N bytes and code_matrix is (P, K) (P parity blocks).

TPU adaptation (DESIGN.md §6): table-based GF multiply (the CPU idiom) needs
per-element gathers, which the TPU vector unit hates.  Instead we use the
carry-less polynomial formulation — 8 shifted XOR steps for the product and
7 steps of modular reduction by 0x11B — entirely int32 shifts/ands/xors, which
map directly onto the VPU.  The stripe is tiled over N so each (K, bn) slab
of data and the (P, bn) accumulator live in VMEM.

Layout: grid = (N // block_n,); per step the kernel sees
  code (P, K) int32  (whole matrix, tiny)     VMEM
  data (K, bn) int32 (one byte per lane)      VMEM
  out  (P, bn) int32                          VMEM
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_POLY = 0x11B


def _gf_mul_vec(a: jax.Array, b: jax.Array) -> jax.Array:
    """Carry-less multiply + modular reduction, elementwise on int32 arrays
    holding bytes.  a, b broadcast together."""
    prod = jnp.zeros_like(jnp.broadcast_arrays(a, b)[0])
    for i in range(8):
        bit = (a >> i) & 1
        prod = prod ^ (bit * (b << i))
    # reduce the 15-bit carry-less product modulo x^8+x^4+x^3+x+1
    for i in range(14, 7, -1):
        bit = (prod >> i) & 1
        prod = prod ^ (bit * (_POLY << (i - 8)))
    return prod


def _kernel(code_ref, data_ref, out_ref, *, K: int):
    acc = jnp.zeros(out_ref.shape, jnp.int32)
    code = code_ref[...]                       # (P, K)
    for k in range(K):                         # K is small (stripe width)
        a = code[:, k][:, None]                # (P, 1)
        b = data_ref[k, :][None, :]            # (1, bn)
        acc = acc ^ _gf_mul_vec(a, b)
    out_ref[...] = acc


def gf256_matmul(code: jax.Array, data: jax.Array, *, block_n: int = 2048,
                 interpret: bool = False) -> jax.Array:
    """code (P, K) uint8, data (K, N) uint8 -> parity (P, N) uint8."""
    P, K = code.shape
    K2, N = data.shape
    assert K == K2, (code.shape, data.shape)
    pad = (-N) % block_n
    if pad:
        data = jnp.pad(data, ((0, 0), (0, pad)))
    Np = N + pad
    out = pl.pallas_call(
        functools.partial(_kernel, K=K),
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((P, K), lambda i: (0, 0)),        # code: replicated
            pl.BlockSpec((K, block_n), lambda i: (0, i)),  # data: tile over N
        ],
        out_specs=pl.BlockSpec((P, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((P, Np), jnp.int32),
        interpret=interpret,
    )(code.astype(jnp.int32), data.astype(jnp.int32))
    return out[:, :N].astype(jnp.uint8)
