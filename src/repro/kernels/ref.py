"""Pure-jnp/numpy oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..erasure.gf256 import GF256


# ---------------------------------------------------------------- gf256
def gf256_matmul_ref(code: np.ndarray, data: np.ndarray) -> np.ndarray:
    """Table-based GF(2^8) matmul oracle.  code (P,K), data (K,N) uint8."""
    P, K = code.shape
    N = data.shape[1]
    out = np.zeros((P, N), np.uint8)
    for p in range(P):
        acc = np.zeros(N, np.uint8)
        for k in range(K):
            acc ^= GF256.mul(np.full(N, code[p, k], np.uint8), data[k])
        out[p] = acc
    return out


# ------------------------------------------------------- flash attention
def flash_attention_ref(q, k, v, *, causal: bool = True) -> jax.Array:
    """Dense softmax attention oracle (fp32 math).  q (B,Sq,H,d),
    k/v (B,Sk,KV,d) with GQA repeat."""
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), Sk - Sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


# ------------------------------------------------------------ pack tokens
def pack_tokens_ref(flat_tokens: np.ndarray, starts: np.ndarray,
                    lens: np.ndarray, seq_len: int, *, pad_id: int = 0):
    R = len(starts)
    toks = np.full((R, seq_len), pad_id, np.int32)
    seg = np.zeros((R, seq_len), np.int32)
    pos = np.zeros((R, seq_len), np.int32)
    for r in range(R):
        ln = min(int(lens[r]), seq_len)
        toks[r, :ln] = flat_tokens[int(starts[r]):int(starts[r]) + ln]
        seg[r, :ln] = 1
        pos[r, :ln] = np.arange(ln)
    return toks, seg, pos
