"""Pallas TPU kernel: blockwise flash attention (online softmax).

The serving-path hot spot.  Unlike the pure-jnp chunked attention in
models/attention.py (which materializes (Sq, bk) logits tiles in HBM when Sq
is large), this kernel tiles BOTH the query and key dimensions so the live
working set is (bq, d) + (bk, d) + (bq, bk) in VMEM — the standard
flash-attention memory shape, adapted to the TPU hierarchy (HBM -> VMEM ->
VREG, MXU-aligned 128-multiple tiles).

Layout: grid = (B*H, Sq//bq); the kv loop is a fori_loop inside the kernel so
only causally-needed kv blocks are visited.  GQA is handled by the wrapper
(kv heads repeated logically via index maps, never materialized).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, scale: float,
            causal: bool):
    qi = pl.program_id(1)
    Sk = k_ref.shape[1]
    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)

    def body(j, carry):
        acc, m_prev, l_prev = carry
        # scalar leading index must be a (start, size) slice: raw Python ints
        # have no .shape and crash pl.load's NDIndexer on newer jax
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(j * bk, bk), slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(j * bk, bk), slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                      # (bq, bk) on the MXU
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_cur, l_cur

    d = q_ref.shape[-1]
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    # causal: only visit kv blocks up to (and including) this q block
    n_blocks = (qi + 1) * bq // bk if causal else Sk // bk
    acc, m, l = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    o_ref[0, ...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q (B, Sq, H, d), k/v (B, Sk, KV, d) -> (B, Sq, H, d).

    GQA: q head h reads kv head h // (H // KV) via the kv index map."""
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = d ** -0.5

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)

    def kv_map(bh, qi):
        return (bh // g, 0, 0)   # collapse q-head to its kv head

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=scale, causal=causal),
        grid=(B * H, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, Sk, d), kv_map),
            pl.BlockSpec((1, Sk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
