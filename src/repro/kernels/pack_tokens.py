"""Pallas TPU kernel: pack ragged documents into fixed-shape training rows.

Serialize/pack is the paper's hottest CPU ingest operator (Sec. VI-A runs it
multi-threaded).  On TPU the same transform is a tiled gather: given the flat
token stream and a (row -> [start, len)) table produced by the packer's
first-fit pass, emit the (R, S) packed token matrix plus the segment-id and
position planes, with padding masked — all fused in one VMEM pass per row.

Layout: grid = (R,); per step the kernel sees the whole flat stream (HBM ref,
sliced with pl.ds) and one (S,) output row in VMEM.  ``starts/lens`` arrive
as scalar-prefetch-style (1,) int32 blocks.

(A row's documents are contiguous in the flat stream by construction — the
packer writes them that way — so one dynamic slice per row suffices.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(starts_ref, lens_ref, toks_ref, out_ref, seg_ref, pos_ref, *,
            S: int, pad_id: int):
    start = starts_ref[0]
    ln = lens_ref[0]
    row = pl.load(toks_ref, (pl.ds(start, S),))          # padded stream: safe
    idx = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
    valid = idx < ln
    out_ref[0, :] = jnp.where(valid, row, pad_id)
    seg_ref[0, :] = jnp.where(valid, 1, 0)
    pos_ref[0, :] = jnp.where(valid, idx, 0)


def pack_tokens(flat_tokens: jax.Array, starts: jax.Array, lens: jax.Array,
                seq_len: int, *, pad_id: int = 0, interpret: bool = False):
    """flat_tokens (T,) int32; starts/lens (R,) int32 -> (tokens, seg, pos)
    each (R, seq_len) int32."""
    R = starts.shape[0]
    toks = jnp.pad(flat_tokens.astype(jnp.int32), (0, seq_len))  # over-read pad
    out, seg, pos = pl.pallas_call(
        functools.partial(_kernel, S=seq_len, pad_id=pad_id),
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1,), lambda r: (r,)),
            pl.BlockSpec((1,), lambda r: (r,)),
            pl.BlockSpec(toks.shape, lambda r: (0,)),    # whole stream
        ],
        out_specs=[
            pl.BlockSpec((1, seq_len), lambda r: (r, 0)),
            pl.BlockSpec((1, seq_len), lambda r: (r, 0)),
            pl.BlockSpec((1, seq_len), lambda r: (r, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((R, seq_len), jnp.int32)] * 3,
        interpret=interpret,
    )(starts.astype(jnp.int32), lens.astype(jnp.int32), toks)
    return out, seg, pos
