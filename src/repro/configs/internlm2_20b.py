"""internlm2-20b [dense] — GQA, arXiv:2403.17297.

48 layers, d_model 6144, 48 heads (GQA kv=8), d_ff 16384, vocab 92544
(padded to 92672 for 16-way TP of the unembed — recorded deviation).
"""
from ..models.config import ModelConfig
from .common import pad_vocab

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384,
    vocab_size=pad_vocab(92544),
    pattern=("attn",),
    mlp_kind="swiglu",
)

SMOKE = CONFIG.replace(
    name="internlm2-smoke", num_layers=2, d_model=64,
    n_heads=6, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
    dtype="float32", param_dtype="float32",
)
