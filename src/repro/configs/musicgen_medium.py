"""musicgen-medium [audio] — decoder-only over EnCodec tokens, arXiv:2306.05284.

48 layers, d_model 1536, 24 heads (MHA kv=24), d_ff 6144 (GELU), vocab 2048
(EnCodec codebook).  The assignment specifies the transformer BACKBONE only:
the EnCodec frontend is a stub — the ingestion plan performs the delay-pattern
flattening and the model consumes precomputed code tokens directly.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    pattern=("attn",),
    mlp_kind="gelu",
)

SMOKE = CONFIG.replace(
    name="musicgen-smoke", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
    dtype="float32", param_dtype="float32",
)
