"""smollm-135m [dense] — llama-arch small, hf:HuggingFaceTB/SmolLM-135M.

30 layers, d_model 576, 9 heads (GQA kv=3), d_ff 1536, vocab 49152, tied.
Also the end-to-end training example arch (examples/train_smollm.py).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    pattern=("attn",),
    mlp_kind="swiglu",
    tied_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="smollm-smoke", num_layers=3, d_model=48,
    n_heads=3, n_kv_heads=1, head_dim=16, d_ff=96, vocab_size=256,
    dtype="float32", param_dtype="float32",
)
