"""Shared config helpers."""
from __future__ import annotations


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a multiple of 256 so the TP-sharded unembed tiles the MXU
    (128-lane alignment per 16-way shard).  Deviations recorded per config."""
    return ((v + multiple - 1) // multiple) * multiple
