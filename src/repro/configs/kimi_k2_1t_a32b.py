"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8.

61 layers, d_model 7168, 64 heads (GQA kv=8), expert d_ff 2048, +1 shared
expert, vocab 163840.  head_dim set to 128 explicitly (decoupled from
d_model, as Kimi-K2 itself does) for MXU 128-alignment — recorded deviation:
the first dense layer of the real model is folded into the uniform MoE stack.

At ~1.04 T total / ~33 B active params this is the arch that forces the
1000+-node posture: Adafactor (factored optimizer state), 16-way expert
parallelism (384/16 = 24 experts per shard), FSDP over the data axis.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048,                      # = expert hidden dim
    vocab_size=163840,
    pattern=("attn",),
    mlp_kind="moe",
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25, num_shared_experts=1),
    optimizer="adafactor",
    remat_policy="save_layer_inputs",
)

SMOKE = CONFIG.replace(
    name="kimi-smoke", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=32, vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                  num_shared_experts=1),
    dtype="float32", param_dtype="float32",
)
