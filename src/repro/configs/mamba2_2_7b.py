"""mamba2-2.7b [ssm] — SSD (state-space duality), arXiv:2405.21060.

64 layers, d_model 2560, attention-free, no MLP (the Mamba-2 block *is* the
layer), vocab 50280 (padded to 50432 for 16-way TP of the unembed — recorded
deviation), ssm_state 128.  d_inner = 2×2560 = 5120, head_dim 64 -> 80 heads.
"""
from ..models.config import ModelConfig, SSMConfig
from .common import pad_vocab

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=pad_vocab(50280),
    pattern=("ssd",),
    mlp_kind="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, num_groups=1, expand=2,
                  conv_width=4, chunk_size=256),
    remat_policy="save_layer_inputs",
)

SMOKE = CONFIG.replace(
    name="mamba2-smoke", num_layers=2, d_model=64,
    vocab_size=256,
    ssm=SSMConfig(state_dim=16, head_dim=16, num_groups=1, expand=2,
                  conv_width=4, chunk_size=16),
    dtype="float32", param_dtype="float32",
)
