"""llama-3.2-vision-90b [vlm] — cross-attn image layers (hf:meta-llama).

100 layers = 20 super-blocks of (4 self-attn + 1 cross-attn), d_model 8192,
64 heads (GQA kv=8), d_ff 28672, vocab 128256.  The vision frontend is a STUB
per the assignment: ``input_specs`` provides precomputed patch embeddings
(B, 1024, d_model) consumed by the cross-attention layers.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    mlp_kind="swiglu",
    cross_attn_kv_len=1024,     # stubbed vision tokens
    rope_theta=500000.0,
)

SMOKE = CONFIG.replace(
    name="llama-vision-smoke", num_layers=5, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    cross_attn_kv_len=16, dtype="float32", param_dtype="float32",
)
