"""glm4-9b [dense] — RoPE, aggressive GQA (kv=2), hf:THUDM/glm-4-9b.

40 layers, d_model 4096, 32 heads (GQA kv=2), d_ff 13696, vocab 151552.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    pattern=("attn",),
    mlp_kind="swiglu",
)

SMOKE = CONFIG.replace(
    name="glm4-smoke", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    dtype="float32", param_dtype="float32",
)
