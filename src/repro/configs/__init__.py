"""Architecture registry: the 10 assigned archs as selectable configs.

``get_config(name)`` returns the FULL paper-table config (exercised only via
the AOT dry-run); ``get_smoke(name)`` returns the reduced same-family config
used by per-arch smoke tests and CPU examples.
"""
from __future__ import annotations

from typing import Dict, List

from ..models.config import ModelConfig
from . import (gemma_7b, glm4_9b, internlm2_20b, kimi_k2_1t_a32b,
               llama_3_2_vision_90b, mamba2_2_7b, mixtral_8x22b,
               musicgen_medium, recurrentgemma_2b, smollm_135m)
from .shapes import (LONG_CONTEXT_OK, SHAPES, ShapeSpec, cache_len_for,
                     input_specs, shape_applicable)

_MODULES = {
    "mamba2-2.7b": mamba2_2_7b,
    "llama-3.2-vision-90b": llama_3_2_vision_90b,
    "gemma-7b": gemma_7b,
    "glm4-9b": glm4_9b,
    "internlm2-20b": internlm2_20b,
    "smollm-135m": smollm_135m,
    "recurrentgemma-2b": recurrentgemma_2b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "mixtral-8x22b": mixtral_8x22b,
    "musicgen-medium": musicgen_medium,
}

ARCHS: List[str] = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    return _MODULES[name].CONFIG


def get_smoke(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    return _MODULES[name].SMOKE


def all_cells() -> List[tuple]:
    """Every applicable (arch, shape) dry-run cell."""
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            if shape_applicable(cfg, s):
                out.append((a, s))
    return out


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "all_cells", "cache_len_for",
           "get_config", "get_smoke", "input_specs", "shape_applicable",
           "LONG_CONTEXT_OK"]
