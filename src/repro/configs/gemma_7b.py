"""gemma-7b [dense] — GeGLU, head_dim 256, arXiv:2403.08295.

28 layers, d_model 3072, 16 heads (kv=16 — full MHA on 7b), d_ff 24576,
vocab 256000, tied embeddings with sqrt(d_model) embedding scale.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    pattern=("attn",),
    mlp_kind="geglu",
    tied_embeddings=True,
    embed_scale=True,
)

SMOKE = CONFIG.replace(
    name="gemma-smoke", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=32, d_ff=128, vocab_size=256,
    dtype="float32", param_dtype="float32",
)
