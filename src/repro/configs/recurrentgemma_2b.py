"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2, arXiv:2402.19427.

26 layers in (rec, rec, attn) blocks: 8 scanned super-blocks + 2 trailing
recurrent layers unrolled.  d_model 2560, 10 heads (MQA kv=1, head_dim 256),
d_ff 7680 (GeGLU), local-attention window 2048, vocab 256000.
The 500k-context decode cell runs here: RG-LRU state + 2048-token ring cache.
"""
from ..models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=("rec", "rec", "local"),
    remainder=("rec", "rec"),
    window=2048,
    mlp_kind="geglu",
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, c_exponent=8.0),
    tied_embeddings=True,
    embed_scale=True,
)

SMOKE = CONFIG.replace(
    name="recurrentgemma-smoke", num_layers=5, d_model=64,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
    window=16, rglru=RGLRUConfig(lru_width=64),
    pattern=("rec", "rec", "local"), remainder=("rec", "rec"),
    dtype="float32", param_dtype="float32",
)
