"""mixtral-8x22b [moe] — 8 experts top-2 + sliding-window attention,
arXiv:2401.04088.

56 layers, d_model 6144, 48 heads (GQA kv=8), expert d_ff 16384, vocab 32768,
SWA window 4096 (as assigned).  8 experts don't divide the 16-way model axis,
so experts are replicated and the expert hidden dim is tensor-parallel
instead (DESIGN.md §5) — the launch layer picks this automatically.
The 500k decode cell runs here: the SWA ring cache is bounded by the window.
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    pattern=("swa",),
    window=4096,
    mlp_kind="moe",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25),
    remat_policy="save_layer_inputs",
)

SMOKE = CONFIG.replace(
    name="mixtral-smoke", num_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=256,
    window=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
    dtype="float32", param_dtype="float32",
)
