"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Four shapes per arch (LM-family assignment):
  train_4k     seq 4096,    global_batch 256   -> train_step
  prefill_32k  seq 32768,   global_batch 32    -> prefill (serve)
  decode_32k   seq 32768,   global_batch 128   -> serve_step (1 token, KV=32k)
  long_500k    seq 524288,  global_batch 1     -> serve_step; sub-quadratic
                                                 archs only (SSM/hybrid/SWA)

``long_500k`` applicability (DESIGN.md §4): runs where decode state is O(1)
or attention is windowed — mamba2 (SSM), recurrentgemma (RG-LRU + local),
mixtral (4096-token SWA ring cache).  Pure full-attention archs are skipped
(a 500k dense KV cache is the *definition* of the quadratic wall).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs that can run the 500k-context decode cell
LONG_CONTEXT_OK = ("mamba2-2.7b", "recurrentgemma-2b", "mixtral-8x22b")


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.name in LONG_CONTEXT_OK or _sub_quadratic(cfg)
    return True


def _sub_quadratic(cfg: ModelConfig) -> bool:
    kinds = set(cfg.pattern) | set(cfg.remainder)
    attn_kinds = kinds & {"attn", "cross"}
    return not attn_kinds  # ssd/rec/swa/local only


def _i32(*shape: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    s = SHAPES[shape]
    B, S = s.global_batch, s.seq_len
    if s.kind == "train":
        specs = {"tokens": _i32(B, S), "labels": _i32(B, S),
                 "segments": _i32(B, S), "positions": _i32(B, S)}
    elif s.kind == "prefill":
        specs = {"tokens": _i32(B, S), "segments": _i32(B, S),
                 "positions": _i32(B, S)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {"tokens": _i32(B, 1)}
    if "cross" in cfg.pattern + cfg.remainder and s.kind != "decode":
        specs["encoder_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.cross_attn_kv_len, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def cache_len_for(cfg: ModelConfig, shape: str) -> int:
    """Decode cache depth: seq_len past tokens + a 128-step decode margin
    (full-attention caches); windowed/recurrent caches clamp internally."""
    s = SHAPES[shape]
    return s.seq_len + 128 if s.kind == "decode" else s.seq_len
