"""Physical block layouts — the serialize/deserialize library (paper Sec. II-C, VII).

The paper ships per-replica layouts (row, PAX/RCFile, compressed) and layout-
aware deserializers that push projection/selection down into the read path.
Here a *block layout* is how a columnar record batch is encoded into the bytes
stored by the DataStore, plus a deserializer that can read back only the
projected fields / selected rows.

Layouts:
  row        — array-of-structs: numpy structured array (good for full-record scans)
  columnar   — struct-of-arrays, one byte-section per field (PAX/RCFile analogue;
               projection reads only the requested sections)
  cpax       — columnar + zlib compression per section
  sorted     — columnar, rows ordered by a key field; selection on that field
               uses binary search (the paper's index access / GS layout)
  packed     — device-ready LM block: fixed (rows, seq) int32 token matrix +
               loss mask + positions, zero host-side work at train time
"""
from .blocks import (
    SerializedBlock,
    serialize_block,
    deserialize_block,
    available_layouts,
)

__all__ = [
    "SerializedBlock",
    "serialize_block",
    "deserialize_block",
    "available_layouts",
]
