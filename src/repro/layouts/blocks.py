"""Block serializers/deserializers with projection & selection pushdown."""
from __future__ import annotations

import io
import json
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.items import Columns, num_rows, take_rows

# A selection predicate: (field, op, value) with op in {"==","<","<=",">",">=","!="}
Selection = Tuple[str, str, Any]

_OPS: Dict[str, Callable[[np.ndarray, Any], np.ndarray]] = {
    "==": lambda a, v: a == v,
    "!=": lambda a, v: a != v,
    "<": lambda a, v: a < v,
    "<=": lambda a, v: a <= v,
    ">": lambda a, v: a > v,
    ">=": lambda a, v: a >= v,
}


def apply_selection(cols: Columns, selection: Optional[Selection]) -> Columns:
    if selection is None:
        return cols
    f, op, v = selection
    mask = _OPS[op](cols[f], v)
    return take_rows(cols, np.nonzero(mask)[0])


@dataclass
class SerializedBlock:
    """A physical block: layout id + payload bytes + self-describing header."""

    layout: str
    payload: bytes
    header: Dict[str, Any] = field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return len(self.payload)

    def tobytes(self) -> bytes:
        h = json.dumps({"layout": self.layout, **self.header}).encode()
        return len(h).to_bytes(4, "little") + h + self.payload

    @classmethod
    def frombytes(cls, raw: bytes) -> "SerializedBlock":
        hlen = int.from_bytes(raw[:4], "little")
        header = json.loads(raw[4 : 4 + hlen].decode())
        layout = header.pop("layout")
        return cls(layout=layout, payload=raw[4 + hlen :], header=header)


# --------------------------------------------------------------------------- util
def _col_meta(a: np.ndarray) -> Dict[str, Any]:
    return {"dtype": str(a.dtype), "shape": list(a.shape)}


def _col_from(meta: Dict[str, Any], raw: bytes) -> np.ndarray:
    return np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"]).copy()


def _sections(cols: Columns) -> Tuple[Dict[str, Any], bytes]:
    """Pack columns into one payload with per-field (offset, size) sections."""
    meta: Dict[str, Any] = {"fields": {}, "rows": num_rows(cols)}
    buf = io.BytesIO()
    for k, a in cols.items():
        raw = np.ascontiguousarray(a).tobytes()
        meta["fields"][k] = {**_col_meta(a), "off": buf.tell(), "len": len(raw)}
        buf.write(raw)
    return meta, buf.getvalue()


def _read_sections(
    header: Dict[str, Any], payload: bytes, projection: Optional[Sequence[str]]
) -> Columns:
    fields = header["fields"]
    keys = list(fields) if projection is None else [k for k in projection if k in fields]
    out: Columns = {}
    for k in keys:
        m = fields[k]
        out[k] = _col_from(m, payload[m["off"] : m["off"] + m["len"]])
    return out


# ------------------------------------------------------------------------ layouts
def _ser_row(cols: Columns, **kw: Any) -> SerializedBlock:
    """Array-of-structs: interleave fields into a numpy structured array."""
    n = num_rows(cols)
    dt = np.dtype([(k, a.dtype, a.shape[1:]) for k, a in cols.items()])
    rec = np.empty(n, dtype=dt)
    for k, a in cols.items():
        rec[k] = a
    return SerializedBlock(
        layout="row",
        payload=rec.tobytes(),
        header={"descr": np.lib.format.dtype_to_descr(dt), "rows": n},
    )


def _de_row(b: SerializedBlock, projection, selection) -> Columns:
    dt = np.dtype(np.lib.format.descr_to_dtype(b.header["descr"]))
    rec = np.frombuffer(b.payload, dtype=dt)
    keys = list(dt.names) if projection is None else [k for k in projection if k in dt.names]
    # row layout cannot avoid reading whole records: project after decode
    cols = {k: np.ascontiguousarray(rec[k]) for k in keys}
    if selection is not None and selection[0] not in cols:
        cols_sel = {selection[0]: np.ascontiguousarray(rec[selection[0]])}
        f, op, v = selection
        idx = np.nonzero(_OPS[op](cols_sel[f], v))[0]
        return take_rows(cols, idx)
    return apply_selection(cols, selection)


def _ser_columnar(cols: Columns, **kw: Any) -> SerializedBlock:
    meta, payload = _sections(cols)
    return SerializedBlock(layout="columnar", payload=payload, header=meta)


def _de_columnar(b: SerializedBlock, projection, selection) -> Columns:
    want = None
    if projection is not None:
        want = list(projection)
        if selection is not None and selection[0] not in want:
            want = want + [selection[0]]
    cols = _read_sections(b.header, b.payload, want)
    cols = apply_selection(cols, selection)
    if projection is not None:
        cols = {k: v for k, v in cols.items() if k in projection}
    return cols


def _ser_cpax(cols: Columns, level: int = 3, **kw: Any) -> SerializedBlock:
    """Compressed PAX: columnar sections, zlib per field section."""
    meta: Dict[str, Any] = {"fields": {}, "rows": num_rows(cols)}
    buf = io.BytesIO()
    for k, a in cols.items():
        raw = zlib.compress(np.ascontiguousarray(a).tobytes(), level)
        meta["fields"][k] = {**_col_meta(a), "off": buf.tell(), "len": len(raw)}
        buf.write(raw)
    return SerializedBlock(layout="cpax", payload=buf.getvalue(), header=meta)


def _de_cpax(b: SerializedBlock, projection, selection) -> Columns:
    fields = b.header["fields"]
    want = list(fields) if projection is None else [k for k in projection if k in fields]
    if selection is not None and selection[0] in fields and selection[0] not in want:
        want = want + [selection[0]]
    cols: Columns = {}
    for k in want:
        m = fields[k]
        cols[k] = _col_from(m, zlib.decompress(b.payload[m["off"] : m["off"] + m["len"]]))
    cols = apply_selection(cols, selection)
    if projection is not None:
        cols = {k: v for k, v in cols.items() if k in projection}
    return cols


def _ser_sorted(cols: Columns, key: Optional[str] = None, **kw: Any) -> SerializedBlock:
    """Columnar layout sorted on ``key``; selection on key is a binary search."""
    if key is None:
        key = next(iter(cols))
    order = np.argsort(cols[key], kind="stable")
    cols = take_rows(cols, order)
    meta, payload = _sections(cols)
    meta["sort_key"] = key
    return SerializedBlock(layout="sorted", payload=payload, header=meta)


def _de_sorted(b: SerializedBlock, projection, selection) -> Columns:
    key = b.header["sort_key"]
    if selection is not None and selection[0] == key and selection[1] in ("==", "<", "<=", ">", ">="):
        # index access: read only the key column, binary-search the row range
        kcol = _read_sections(b.header, b.payload, [key])[key]
        f, op, v = selection
        lo, hi = 0, len(kcol)
        if op == "==":
            lo, hi = np.searchsorted(kcol, v, "left"), np.searchsorted(kcol, v, "right")
        elif op == "<":
            hi = np.searchsorted(kcol, v, "left")
        elif op == "<=":
            hi = np.searchsorted(kcol, v, "right")
        elif op == ">":
            lo = np.searchsorted(kcol, v, "right")
        elif op == ">=":
            lo = np.searchsorted(kcol, v, "left")
        cols = _read_sections(b.header, b.payload, projection)
        return {k: a[lo:hi] for k, a in cols.items()}
    return _de_columnar(b, projection, selection)


def _ser_packed(cols: Columns, **kw: Any) -> SerializedBlock:
    """Device-ready packed LM block: fields are already fixed-shape 2-D arrays
    (tokens/mask/positions of shape (rows, seq)); stored as raw sections so the
    feeder can hand them to jax without any host-side transformation."""
    meta, payload = _sections(cols)
    return SerializedBlock(layout="packed", payload=payload, header=meta)


_SERIALIZERS: Dict[str, Callable[..., SerializedBlock]] = {
    "row": _ser_row,
    "columnar": _ser_columnar,
    "cpax": _ser_cpax,
    "sorted": _ser_sorted,
    "packed": _ser_packed,
}

_DESERIALIZERS: Dict[str, Callable[[SerializedBlock, Any, Any], Columns]] = {
    "row": _de_row,
    "columnar": _de_columnar,
    "cpax": _de_cpax,
    "sorted": _de_sorted,
    "packed": _de_columnar,  # packed uses plain sections
}


def available_layouts() -> List[str]:
    return sorted(_SERIALIZERS)


def serialize_block(cols: Columns, layout: str, **kw: Any) -> SerializedBlock:
    if layout not in _SERIALIZERS:
        raise KeyError(f"unknown layout {layout!r}; have {available_layouts()}")
    return _SERIALIZERS[layout](cols, **kw)


def deserialize_block(
    block: SerializedBlock,
    projection: Optional[Sequence[str]] = None,
    selection: Optional[Selection] = None,
) -> Columns:
    """Layout-aware read with projection/selection pushdown (paper Sec. VII)."""
    if block.layout not in _DESERIALIZERS:
        raise KeyError(f"unknown layout {block.layout!r}")
    return _DESERIALIZERS[block.layout](block, projection, selection)
