"""Data substrate: synthetic generators, tokenizer, and the train-time feeder.

The generators stand in for the paper's TPC-H / cloud-log inputs; the feeder
is the "upstream query processor" integration (paper Sec. VIII) — it consumes
ingested blocks through the ingestion-aware access layer and yields
device-ready batches aligned to the mesh data axis.
"""
from .generators import (gen_lineitem, gen_log_records, gen_token_documents,
                         gen_tax_records)
from .tokenizer import ByteTokenizer
from .feeder import BlockFeeder, ingest_corpus

__all__ = ["gen_lineitem", "gen_log_records", "gen_token_documents",
           "gen_tax_records", "ByteTokenizer", "BlockFeeder", "ingest_corpus"]
