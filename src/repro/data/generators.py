"""Synthetic record generators (the paper's TPC-H / log / tax inputs)."""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ..core.items import Columns, IngestItem
from ..core.items import Granularity


def gen_lineitem(n: int, seed: int = 0, violation_rate: float = 0.01) -> Columns:
    """TPC-H lineitem-like columns used by the paper's cleaning experiments:
    shipdate determines linestatus (FD) except for injected violations; the DC
    example is quantity < 3 => discount <= 9%."""
    rng = np.random.default_rng(seed)
    shipdate = rng.integers(0, 2526, size=n).astype(np.int32)       # days since epoch
    linestatus = (shipdate % 2).astype(np.int8)                      # FD: date -> status
    quantity = rng.integers(1, 51, size=n).astype(np.int32)
    discount = np.round(rng.uniform(0.0, 0.10, size=n), 2).astype(np.float32)
    extendedprice = np.round(rng.uniform(900, 105000, size=n), 2).astype(np.float32)
    orderkey = rng.integers(0, max(1, n // 4), size=n).astype(np.int64)
    partkey = rng.integers(0, 200_000, size=n).astype(np.int64)
    suppkey = rng.integers(0, 10_000, size=n).astype(np.int64)
    # inject FD violations: flip linestatus on a few rows
    nbad = int(n * violation_rate)
    if nbad:
        idx = rng.choice(n, size=nbad, replace=False)
        linestatus[idx] = 1 - linestatus[idx]
    # inject DC violations: small quantity + big discount
    if nbad:
        idx = rng.choice(n, size=nbad, replace=False)
        quantity[idx] = rng.integers(1, 3, size=nbad)
        discount[idx] = np.round(rng.uniform(0.091, 0.2, size=nbad), 3)
    return {"orderkey": orderkey, "partkey": partkey, "suppkey": suppkey,
            "quantity": quantity, "discount": discount,
            "extendedprice": extendedprice, "shipdate": shipdate,
            "linestatus": linestatus}


def gen_log_records(n: int, seed: int = 0, num_machines: int = 64) -> Columns:
    """Cloud-service log lines (paper Sec. IV-C): structured timestamp/machine
    plus an unstructured error payload (as a fixed-width byte field)."""
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, 86_400, size=n)).astype(np.int64)
    machine = rng.integers(0, num_machines, size=n).astype(np.int32)
    severity = rng.choice(np.array([0, 1, 2, 3], dtype=np.int8),
                          p=[0.7, 0.2, 0.08, 0.02], size=n)
    payload = rng.integers(32, 127, size=(n, 64)).astype(np.uint8)
    return {"ts": ts, "machine": machine, "severity": severity, "payload": payload}


def gen_tax_records(n: int, seed: int = 0, invalid_rate: float = 0.05) -> Columns:
    """Tax dataset with country_code values needing dictionary repair."""
    rng = np.random.default_rng(seed)
    valid = np.array(["MX", "US", "CA", "FR", "DE"])
    names = np.array(["mexico", "usa", "canada", "france", "germany"])
    idx = rng.integers(0, len(valid), size=n)
    codes = valid[idx].astype(object)
    bad = rng.random(n) < invalid_rate
    codes[bad] = names[idx[bad]]
    income = rng.uniform(1e4, 2e5, size=n).astype(np.float32)
    return {"country_code": np.array(codes, dtype=object), "income": income}


def gen_token_documents(n_docs: int, vocab: int = 50_000, seed: int = 0,
                        min_len: int = 32, max_len: int = 2048) -> Columns:
    """Synthetic LM corpus: documents of ragged token sequences drawn from a
    2-gram process so a trained model has learnable structure (loss decreases).
    """
    rng = np.random.default_rng(seed)
    # sparse bigram structure: each token prefers a small successor set
    succ = rng.integers(0, vocab, size=(256, 4))
    docs: List[np.ndarray] = []
    lens = rng.integers(min_len, max_len + 1, size=n_docs)
    for L in lens:
        t = np.empty(L, dtype=np.int32)
        t[0] = rng.integers(vocab)
        for i in range(1, L):
            prev = t[i - 1] % 256
            if rng.random() < 0.8:
                t[i] = succ[prev, rng.integers(4)]
            else:
                t[i] = rng.integers(vocab)
        docs.append(t)
    return {"tokens": np.array(docs, dtype=object),
            "length": lens.astype(np.int32),
            "doc_id": np.arange(n_docs, dtype=np.int64)}


def as_file_items(cols: Columns, shards: int, granularity=Granularity.FILE
                  ) -> List[IngestItem]:
    """Split a column set into shard items (the raw files arriving per node)."""
    from ..core.items import num_rows, take_rows
    n = num_rows(cols)
    out: List[IngestItem] = []
    per = -(-n // shards)
    for s in range(shards):
        idx = np.arange(s * per, min((s + 1) * per, n))
        if len(idx) == 0:
            continue
        out.append(IngestItem(take_rows(cols, idx), granularity))
    return out
