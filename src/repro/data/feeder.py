"""Ingestion-aware training feeder (the Spark/MapReduce integration analogue).

``ingest_corpus`` runs the canonical LM ingestion plan — parse, length-
partition, pack into device-shaped blocks, serialize, store — and
``BlockFeeder`` replays the ingested blocks as train batches:

* replica/layout choice via ``filterReplica`` (packed blocks for training),
* block->task assignment via ``splitByKey`` folded to the mesh data-axis size,
* deserialize with projection pushdown (only tokens/mask reach the host batch),
* resumable position (checkpoint/restart integration) and a work-stealing
  queue across feeder tasks (straggler mitigation).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core import (DataAccess, DataStore, IngestItem, IngestPlan, create_stage,
                    format_, ingest, select, store)
from ..core.items import Columns
from .generators import as_file_items


def build_lm_plan(data_store: DataStore, *, seq_len: int, rows_per_block: int,
                  pad_id: int = 0, replicas: int = 1,
                  length_partitions: Optional[Sequence[int]] = None,
                  name: str = "lm_corpus") -> IngestPlan:
    """The canonical LM ingestion plan (DESIGN.md §2 table)."""
    plan = IngestPlan(name)
    s1 = select(plan, replicate=replicas if replicas > 1 else None)
    fmt_kw: Dict[str, Any] = {
        "pack": {"seq_len": seq_len, "rows_per_block": rows_per_block, "pad_id": pad_id},
        "serialize": "packed",
    }
    if length_partitions is not None:
        fmt_kw["partition"] = {"key": "length", "scheme": "length",
                               "bounds": list(length_partitions)}
    s2 = format_(plan, s1, **fmt_kw)
    s3 = store(plan, s2, locate="roundrobin",
               locate_args={"num_locations": len(data_store.nodes)},
               upload=data_store)
    create_stage(plan, using=[s1, s2, s3], name="main")
    return plan


def ingest_corpus(docs: Columns, data_store: DataStore, *, seq_len: int,
                  rows_per_block: int, pad_id: int = 0, shards: int = 8,
                  replicas: int = 1,
                  length_partitions: Optional[Sequence[int]] = None):
    """Ingest a ragged-token corpus into packed blocks. Returns the RunReport."""
    plan = build_lm_plan(data_store, seq_len=seq_len, rows_per_block=rows_per_block,
                         pad_id=pad_id, replicas=replicas,
                         length_partitions=length_partitions)
    items = as_file_items(docs, shards)
    return ingest(plan, items, data_store)


class BlockFeeder:
    """Yields (tokens, loss_mask, positions, segment_ids) batches from ingested
    packed blocks, sharded across ``num_tasks`` feeder tasks (one per data-axis
    slot / host)."""

    FIELDS = ("tokens", "loss_mask", "positions", "segment_ids")

    def __init__(self, data_store: DataStore, *, num_tasks: int = 1, task: int = 0,
                 batch_rows: Optional[int] = None, seed: int = 0,
                 fields: Sequence[str] = FIELDS, start_step: int = 0,
                 start_offset: int = 0) -> None:
        self.store = data_store
        self.num_tasks, self.task = num_tasks, task
        self.batch_rows = batch_rows
        self.fields = tuple(fields)
        self.seed = seed
        # resumable position (checkpoint/restart): ``step`` is the first
        # block with unconsumed rows, ``offset`` how many of its rows earlier
        # batches already consumed — without the offset, the carry rows left
        # when batch_rows doesn't divide a block were dropped or replayed on
        # restart (bugfix, ISSUE 6)
        self.step = start_step
        self.offset = start_offset
        self.my_blocks = self._assigned_blocks()
        # deterministic per-epoch order shared by all tasks
        self._order = np.random.default_rng(seed).permutation(len(self.my_blocks))

    def _assigned_blocks(self):
        """This task's packed blocks: replica choice + block->task assignment
        (the one policy shared by construction and live refresh)."""
        self.access = DataAccess(self.store).filter_replica("serialize", "packed")
        splits = self.access.split_by_key("pack", num_tasks=self.num_tasks)
        return splits[self.task].blocks if self.task < len(splits) else []

    def __len__(self) -> int:
        return len(self.my_blocks)

    def _read(self, idx: int) -> Columns:
        e = self.my_blocks[int(self._order[idx % len(self._order)])]
        block = self.store.read_block(e.block_id)
        from ..layouts import deserialize_block
        return deserialize_block(block, projection=list(self.fields))

    def batches(self, num_steps: int) -> Iterator[Dict[str, np.ndarray]]:
        """Sequential, resumable batch stream.

        After every yielded batch, ``(self.step, self.offset)`` is the exact
        resume point: a fresh feeder constructed with
        ``start_step=step, start_offset=offset`` continues the stream with
        identical batches — no carry rows are lost or replayed."""
        if not self.my_blocks:
            return
        buf: Dict[str, List[np.ndarray]] = {f: [] for f in self.fields}
        rows = 0
        produced = 0
        idx = self.step
        skip = self.offset
        # blocks backing ``buf``: [block index, rows consumed, total rows]
        pending: List[List[int]] = []
        while produced < num_steps:
            cols = self._read(idx)
            total = len(cols[self.fields[0]])
            start = min(skip, total)
            skip = 0
            take = total - start
            if take > 0:
                for f in self.fields:
                    buf[f].append(cols[f][start:] if start else cols[f])
                pending.append([idx, start, total])
                rows += take
            idx += 1
            target = self.batch_rows or take
            while target > 0 and rows >= target and produced < num_steps:
                cat = {f: np.concatenate(buf[f]) for f in self.fields}
                out = {f: cat[f][:target] for f in self.fields}
                buf = {f: [cat[f][target:]] for f in self.fields}
                rows -= target
                # advance the consumed-row cursor through the backing blocks
                need = target
                while need > 0 and pending:
                    blk = pending[0]
                    used = min(blk[2] - blk[1], need)
                    blk[1] += used
                    need -= used
                    if blk[1] >= blk[2]:
                        pending.pop(0)
                if pending:
                    self.step, self.offset = pending[0][0], pending[0][1]
                else:
                    self.step, self.offset = idx, 0
                produced += 1
                yield out

    # ------------------------------------------------------------- live tailing
    def refresh(self) -> int:
        """Pick up blocks committed since construction (or the last refresh):
        the streaming engine commits epochs while training runs, and the
        feeder's view extends without re-shuffling what it already replayed.
        Returns the number of newly visible blocks for this task."""
        fresh = self._assigned_blocks()
        known = {e.block_id for e in self.my_blocks}
        added = [e for e in fresh if e.block_id not in known]
        if added:
            start = len(self.my_blocks)
            self.my_blocks.extend(added)
            # new blocks replay in commit order after the shuffled prefix
            self._order = np.concatenate(
                [self._order, np.arange(start, len(self.my_blocks))]).astype(np.int64)
        return len(added)

    def tail(self, num_steps: int, poll_s: float = 0.05,
             timeout_s: float = 10.0) -> Iterator[Columns]:
        """Follow a live store: read each packed block once, in order, waiting
        for newly committed epochs when caught up.  Stops after ``num_steps``
        blocks or when no new epoch commits within ``timeout_s``."""
        from ..layouts import deserialize_block
        pos = 0
        deadline = time.monotonic() + timeout_s
        while pos < num_steps:
            if pos >= len(self.my_blocks):
                if self.refresh() == 0:
                    if time.monotonic() > deadline:
                        return
                    time.sleep(poll_s)
                    continue
                deadline = time.monotonic() + timeout_s
            block = self.store.read_block(self.my_blocks[pos].block_id)
            yield deserialize_block(block, projection=list(self.fields))
            pos += 1

    # ------------------------------------------------------------ work stealing
    @staticmethod
    def stealing_queue(feeders: Sequence["BlockFeeder"], num_steps: int
                       ) -> "queue.Queue[Dict[str, np.ndarray]]":
        """Fan several feeder tasks into one queue; fast tasks pull more work —
        a straggling feeder merely contributes fewer batches (DESIGN.md §5).

        The returned queue carries two extras: ``q.stop()`` — the shutdown
        path a consumer abandoning the stream early MUST call so the workers
        unblock and exit (bugfix, ISSUE 6: workers used to block forever on a
        full queue, and the old ``done`` event was never set) — and
        ``q.delivered()``, the number of batches actually enqueued (a permit
        claimed for a batch that was never placed is returned, so the count
        no longer includes undelivered batches)."""
        q: "queue.Queue[Dict[str, np.ndarray]]" = queue.Queue(maxsize=8)
        remaining = threading.Semaphore(num_steps)
        done = threading.Event()
        lock = threading.Lock()
        enqueued = [0]

        def work(f: "BlockFeeder") -> None:
            for b in f.batches(num_steps):
                if done.is_set():
                    return
                if not remaining.acquire(blocking=False):
                    return   # global quota claimed by faster tasks
                placed = False
                while not done.is_set():
                    try:
                        q.put(b, timeout=0.05)   # bounded: re-check shutdown
                        placed = True
                        break
                    except queue.Full:
                        continue
                if not placed:
                    remaining.release()   # never delivered: return the permit
                    return
                with lock:
                    enqueued[0] += 1
                    if enqueued[0] >= num_steps:
                        done.set()   # quota delivered: stop every worker

        threads = [threading.Thread(target=work, args=(f,), daemon=True)
                   for f in feeders]
        for t in threads:
            t.start()
        q.stop = done.set                    # type: ignore[attr-defined]
        q.delivered = lambda: enqueued[0]    # type: ignore[attr-defined]
        q.workers = threads                  # type: ignore[attr-defined]
        return q
