"""Byte-level tokenizer (DESIGN.md §9: ingestion is layout-bound, not
tokenizer-bound; BPE training is out of scope for a synthetic corpus)."""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


class ByteTokenizer:
    """Bytes <-> token ids with a few special tokens at the top of the range."""

    def __init__(self, vocab_size: int = 512) -> None:
        assert vocab_size >= 260, "need 256 bytes + specials"
        self.vocab_size = vocab_size
        self.pad_id = 256
        self.bos_id = 257
        self.eos_id = 258

    def encode(self, text: str | bytes, add_special: bool = True) -> np.ndarray:
        raw = text.encode() if isinstance(text, str) else bytes(text)
        ids = np.frombuffer(raw, dtype=np.uint8).astype(np.int32)
        if add_special:
            ids = np.concatenate([[self.bos_id], ids, [self.eos_id]]).astype(np.int32)
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        body = [i for i in ids if i < 256]
        return bytes(body).decode(errors="replace")

    def encode_batch(self, texts: List[str]) -> np.ndarray:
        return np.array([self.encode(t) for t in texts], dtype=object)
