"""EnCodec-token ingest operators for the musicgen backbone (DESIGN.md §4).

The assignment stubs the audio frontend: the model consumes flat EnCodec code
tokens.  What the INGESTBASE plan owns is the *delay-pattern* transform
(MusicGen paper §2.1): K codebook streams are offset so codebook k is
predicted at step t from codebooks < k at step t — then flattened into the
single (B, S) stream the decoder-only backbone trains on.

    DelayPatternOp: CHUNK{codes (n, K, T)} -> CHUNK{tokens ragged}

Round-trip inverse provided for tests (undelay).
"""
from __future__ import annotations

from typing import Any, Iterable

import numpy as np

from ..core.items import Granularity, IngestItem
from ..core.operators import IngestOp, register_op


def apply_delay_pattern(codes: np.ndarray, pad_id: int = 0) -> np.ndarray:
    """codes (K, T) -> delayed (K, T + K - 1); row k shifted right by k."""
    K, T = codes.shape
    out = np.full((K, T + K - 1), pad_id, codes.dtype)
    for k in range(K):
        out[k, k : k + T] = codes[k]
    return out


def undo_delay_pattern(delayed: np.ndarray, pad_id: int = 0) -> np.ndarray:
    """Inverse of apply_delay_pattern."""
    K, TK = delayed.shape
    T = TK - K + 1
    out = np.empty((K, T), delayed.dtype)
    for k in range(K):
        out[k] = delayed[k, k : k + T]
    return out


@register_op("delay_pattern")
class DelayPatternOp(IngestOp):
    """Delay-pattern + interleave-flatten EnCodec codes into LM token docs.

    Input columns: ``codes`` — object array of (K, T) int arrays (one per
    clip).  Output columns: ``tokens`` (object array of flattened 1-D docs of
    length K*(T+K-1)) + ``length`` — exactly what PackOp consumes.

    Codebook identity is preserved by offsetting codebook k's vocabulary by
    ``k * codebook_size`` (vocab = K * codebook_size), matching the decoder's
    single softmax over the flattened stream.
    """

    name = "delay_pattern"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK
    cpu_heavy = True

    def __init__(self, codebook_size: int = 2048, pad_id: int = 0,
                 offset_codebooks: bool = False, **kw: Any) -> None:
        super().__init__(codebook_size=codebook_size, pad_id=pad_id,
                         offset_codebooks=offset_codebooks, **kw)
        self.codebook_size = codebook_size
        self.pad_id = pad_id
        self.offset_codebooks = offset_codebooks

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        docs = []
        lens = []
        for codes in item.data["codes"]:
            codes = np.asarray(codes)
            delayed = apply_delay_pattern(codes, self.pad_id)
            if self.offset_codebooks:
                delayed = delayed + (np.arange(codes.shape[0])[:, None]
                                     * self.codebook_size)
            flat = delayed.T.reshape(-1).astype(np.int32)  # time-major interleave
            docs.append(flat)
            lens.append(len(flat))
        cols = {"tokens": np.array(docs, dtype=object),
                "length": np.array(lens, np.int32)}
        yield IngestItem(cols, Granularity.CHUNK, item.labels,
                         dict(item.meta)).with_label(self.name, len(docs))


def gen_encodec_clips(n_clips: int, n_codebooks: int = 4,
                      codebook_size: int = 2048, min_t: int = 50,
                      max_t: int = 400, seed: int = 0):
    """Synthetic EnCodec code clips (the stubbed audio frontend's output)."""
    rng = np.random.default_rng(seed)
    clips = np.empty(n_clips, dtype=object)
    for i in range(n_clips):
        t = int(rng.integers(min_t, max_t + 1))
        clips[i] = rng.integers(0, codebook_size,
                                (n_codebooks, t)).astype(np.int32)
    return {"codes": clips}
