"""Process-based node backend: real CPU parallelism over ``launch_remote``.

The thread backend's ``NodeExecutor`` lanes share one Python process, so on a
GIL-bound host the pipelined core overlaps latency but cannot multiply
CPU-heavy operator throughput (DESIGN.md §6).  This module realizes the
``launch_remote`` seam with real OS processes:

* **One long-lived worker process per logical node** (``ProcessNodeExecutor``
  spawns it once per engine), hosting the node's plan clone and the same
  named-lane model as the thread backend — the pipelined streaming engine's
  ``"ingest"`` / ``"store"`` lanes run as threads *inside* the worker, so
  epoch overlap and core-parallelism compose.
* **Plans ship once, by pickle** — ``IngestOp.__reduce__`` reduces operators
  to (type, params), exactly the catalog contract, so the worker re-creates
  fresh operator state that then persists across epochs (dummy substitutions
  survive, like in a long-running per-node JVM).  Closure params fail fast
  with a named operator (``plan.serialize_plans``).
* **Shared-memory data plane** — item batches cross the process boundary via
  ``items.encode_items``: one ``multiprocessing.shared_memory`` segment per
  hop, zero-copy numpy views on the worker side, inline pickle for small
  batches (see items.py).
* **Commit routing** — upload operators run *in the worker*, which performs
  the serialization/compression and the disk write locally (a ``.tmp`` name
  the orphan GC ignores), then registers the block's metadata with the
  coordinator over a dedicated store-RPC pipe
  (``DataStore.register_block_file``).  The manifest, the epoch staging
  index, and the commit sequencer therefore live only in the coordinator:
  epoch begin/commit/abort work unchanged.
* **Death detection** — the coordinator's receiver thread treats pipe EOF
  (worker crash, ``kill()``) as the node dying: every in-flight and future
  stage job on that node fails with ``NodeFailure``, which is exactly what
  the existing fault path consumes (batch shard reassignment, streaming
  epoch-granular abort + replay).

* **Worker-to-worker shuffle** (ISSUE 4) — a shuffle-boundary stage's output
  never returns to the coordinator: the worker partitions it locally by the
  plan's routing key (``ctx["shuffle"]``), encodes each peer-bound partition
  into its own shared-memory segment (``exchange.encode_partition`` — pickle
  meta *inside* the segment, so the reply manifest carries only names and
  sizes), spills oversized partitions to peer-readable DFS files, and keeps
  its own slice resident in the in-worker ``PartitionExchange``.  The
  consuming stage's job receives fetch refs (``ctx["fetch"]``) and maps the
  segments zero-copy / reads the files / pops its resident bucket.  The
  coordinator's ``ShuffleCoordinator`` relays only the manifests — zero item
  bytes cross its pipes on the shuffle path.  A ``("drop", xids)`` control
  message invalidates rounds of an aborted epoch.
"""
from __future__ import annotations

import itertools
import os
import pickle
import queue
import threading
import time
import uuid
from collections import defaultdict
from concurrent.futures import Future
from dataclasses import asdict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import multiprocessing as mp

from .exchange import (PartitionExchange, build_manifest, columnar_file_name,
                       decode_partition, encode_columnar_partition,
                       encode_partition, exchange_file_name,
                       fetch_stream_partition, read_partition_file,
                       resident_file_name, write_columnar_file,
                       write_partition_file)
from .items import (ColumnarBatch, IngestItem, ShmLease, decode_items,
                    encode_items, items_nbytes, sweep_pid_segments)
from .liveness import retry_call
from .transport import (ChaosProxy, FrameListener, PartitionStreamServer,
                        connect_framed)
from .operators import OperatorFailure, PassThroughOp, run_ops_batched
from .plan import StagePlan, failed_op_index, route_items, serialize_plans
from .store import BlockEntry, DataStore, prepare_block_payload


class WorkerDeath(RuntimeError):
    """Raised coordinator-side when a node's worker process is gone; the
    runtime maps it onto ``NodeFailure`` (the existing fault path)."""


#: the host label meaning "this machine" — executors without an explicit
#: host, and every pre-ISSUE-9 caller, run here
LOCAL_HOST = "local"


class _StoreToken:
    """Picklable placeholder swapped for a ``DataStore`` param while a plan
    crosses the process boundary; the worker swaps in its store client."""

    def __repr__(self) -> str:
        return "<store@coordinator>"


_TOKEN = _StoreToken()
_ship_lock = threading.Lock()   # serializes the param swap on shared plans


def _mp_context():
    """fork by default (fast spawn, inherited imports); override with
    REPRO_MP_START_METHOD=spawn|forkserver on platforms or runtimes where
    forking a threaded parent is unsafe.  Workers only run ingestion
    operators — never JAX/XLA — so fork-after-jax-import is benign here."""
    methods = mp.get_all_start_methods()
    want = os.environ.get("REPRO_MP_START_METHOD",
                          "fork" if "fork" in methods else "spawn")
    if want not in methods:
        want = "spawn"
    return mp.get_context(want)


def serialize_plans_for_worker(stage_plans: Sequence[StagePlan],
                               store: DataStore) -> bytes:
    """Pickle a stage DAG with DataStore params tokenized for the worker."""
    with _ship_lock:
        swapped = []
        for sp in stage_plans:
            for op in sp.ops:
                s = op.params.get("store")
                if isinstance(s, DataStore):
                    if s is not store:
                        raise ValueError(
                            f"stage {sp.name!r}: upload target is not the "
                            f"engine's store — the process backend routes "
                            f"commits through the coordinator's store only")
                    swapped.append(op)
                    op.params["store"] = _TOKEN
        try:
            return serialize_plans(stage_plans)
        finally:
            for op in swapped:
                op.params["store"] = store


# ---------------------------------------------------------------------------
# Worker-process side
# ---------------------------------------------------------------------------
class _WorkerStoreClient:
    """The worker's stand-in for ``DataStore``: local payload prep + disk
    write, metadata registration RPC'd to the coordinator (DESIGN.md §6)."""

    def __init__(self, node: str, conn: Any, spec: Dict[str, Any]) -> None:
        self.node = node
        self._conn = conn
        self._rpc_lock = threading.Lock()
        self.root = spec["root"]
        self.nodes = list(spec["nodes"])
        self.durable = spec["durable"]
        self.compress = spec["compress"]
        self.compress_level = spec["compress_level"]
        self.journal_commits = spec["journal_commits"]
        #: columnar data plane (ISSUE 10): UploadOp.process_batch funnels
        #: the batch through ONE put_batch RPC when this is on; off keeps
        #: the per-block protocol (the PR-9 item-at-a-time baseline)
        self.bulk_registration = bool(spec.get("bulk_registration", False))
        self._live: List[str] = list(self.nodes)
        self._epoch = threading.local()

    # ------------------------------------------------------------- job scope
    def bind_live(self, live: Optional[Sequence[str]]) -> None:
        if live is not None:
            self._live = list(live)

    def set_epoch(self, epoch: Optional[int]) -> Any:
        prev = getattr(self._epoch, "value", None)
        self._epoch.value = epoch
        return prev

    # ------------------------------------------------- DataStore duck-typing
    def live_nodes(self) -> List[str]:
        live = set(self._live)
        return [n for n in self.nodes if n in live]

    def _rpc(self, *msg: Any) -> Any:
        with self._rpc_lock:
            self._conn.send(msg)
            status, val = self._conn.recv()
        if status == "err":
            raise RuntimeError(f"store RPC {msg[0]!r} failed: {val}")
        return val

    def staging_epoch_ids(self) -> List[int]:
        return self._rpc("staging")

    def flush_manifest(self) -> None:
        self._rpc("flush")

    def _put_record(self, item: IngestItem, node: str, *,
                    logical_id: str = "", replica_index: int = 0,
                    stripe_id: str = "", stripe_pos: int = -1,
                    is_parity: bool = False) -> Dict[str, Any]:
        """The heavy, local half of a block put: physical payload write (to
        a name gc never scans) plus the registration record for the RPC."""
        payload, layout, raw_nbytes = prepare_block_payload(
            item.data, self.compress, self.compress_level)
        tmp = os.path.join(self.root, "nodes", node, f".{uuid.uuid4().hex}.tmp")
        os.makedirs(os.path.dirname(tmp), exist_ok=True)
        with open(tmp, "wb") as f:
            f.write(payload)
            if self.durable:
                f.flush()
                os.fsync(f.fileno())
        epoch = getattr(self._epoch, "value", None)
        return {
            "node": node, "tmp_path": tmp, "base": item.lineage_name(),
            "checksum": item.checksum(), "nbytes": len(payload),
            "raw_nbytes": raw_nbytes, "compressed": self.compress,
            "labels": [[l.op, l.value] for l in item.labels],
            "layout": layout,
            "logical_id": logical_id or DataStore._logical_id(item),
            "replica_index": replica_index, "stripe_id": stripe_id,
            "stripe_pos": stripe_pos, "is_parity": is_parity,
            "meta": dict(item.meta),
            "epoch": -1 if epoch is None else epoch,
        }

    def put_block(self, item: IngestItem, node: str, *, logical_id: str = "",
                  replica_index: int = 0, stripe_id: str = "",
                  stripe_pos: int = -1, is_parity: bool = False) -> BlockEntry:
        rec = self._rpc("put", self._put_record(
            item, node, logical_id=logical_id, replica_index=replica_index,
            stripe_id=stripe_id, stripe_pos=stripe_pos, is_parity=is_parity))
        return BlockEntry(**rec)

    def put_block_batch(self, reqs: Sequence[Dict[str, Any]]
                        ) -> List[BlockEntry]:
        """Columnar data plane (ISSUE 10): register a whole block batch in
        ONE coordinator round trip.  The physical writes happen here first
        (order-preserving, same tmp-name protocol as ``put_block``); only
        the registration records cross the pipe.  At the pre-ISSUE-10
        per-block protocol's ~ms-per-RPC, a 512-block run spends more wall
        on registration chatter than on the writes themselves."""
        if not reqs:
            return []
        recs = [self._put_record(r["item"], r["node"],
                                 **{k: v for k, v in r.items()
                                    if k not in ("item", "node")})
                for r in reqs]
        out: List[BlockEntry] = []
        # slim reply: the coordinator assigns only (block_id, path); the
        # rest of each entry is the record this client just authored
        for rec, (block_id, path) in zip(recs, self._rpc("put_batch", recs)):
            kw = dict(rec)
            kw.pop("tmp_path")
            base = kw.pop("base")
            kw["logical_id"] = kw["logical_id"] or base
            out.append(BlockEntry(block_id=block_id, path=path, **kw))
        return out


class _WorkerLane:
    """FIFO worker thread inside the node process (same model as the thread
    backend's lanes: "ingest" and "store" jobs overlap within the worker)."""

    def __init__(self, name: str) -> None:
        self.jobs: "queue.Queue[Optional[Callable[[], None]]]" = queue.Queue()
        self.thread = threading.Thread(target=self._loop, daemon=True,
                                       name=f"lane-{name}")
        self.thread.start()

    def _loop(self) -> None:
        while True:
            job = self.jobs.get()
            if job is None:
                return
            job()


def _run_stage_ops(sp: StagePlan, items: List[IngestItem],
                   injections: Dict[int, int], max_retries: int
                   ) -> Tuple[List[IngestItem], Dict[str, Any]]:
    """The worker-side twin of ``RuntimeEngine._run_stage``: pipeline blocks
    as checkpoints, retry from the previous materialization, dummy
    substitution after ``max_retries`` (paper Sec. VI-C1).  Substitutions
    mutate the worker's resident plan, so they persist across epochs exactly
    like the thread backend's node clones."""
    stats: Dict[str, Any] = {"op_failures": {}, "dummy": [],
                             "vectorized_rows": 0, "batch_fallbacks": 0,
                             "kernel_ms": 0.0}
    counts: Dict[int, int] = defaultdict(int)
    current = items
    blocks = sp.pipeline_blocks or [[i] for i in range(len(sp.ops))]
    for bi, block in enumerate(blocks):
        batched = (bool(sp.batch_blocks[bi])
                   if bi < len(sp.batch_blocks) else False)
        checkpoint = current
        while True:
            try:
                out = checkpoint
                if batched:
                    # batch tier (ISSUE 7): same vectorized block execution
                    # as the thread backend; counters ride back to the
                    # coordinator in the stage stats payload
                    for oi in block:
                        if injections.get(oi, 0) > 0:
                            injections[oi] -= 1
                            raise OperatorFailure(
                                f"injected @ {sp.name}[{oi}]")
                    out, bstats = run_ops_batched(
                        [sp.ops[oi] for oi in block], out)
                    stats["vectorized_rows"] += bstats["vectorized_rows"]
                    stats["batch_fallbacks"] += bstats["batch_fallbacks"]
                    stats["kernel_ms"] += bstats["kernel_ms"]
                else:
                    for oi in block:
                        if injections.get(oi, 0) > 0:
                            injections[oi] -= 1
                            raise OperatorFailure(
                                f"injected @ {sp.name}[{oi}]")
                        out = sp.ops[oi].run(out)
                current = out
                break
            except OperatorFailure as e:
                oi = block[0] if len(block) == 1 else failed_op_index(sp, block, e)
                counts[oi] += 1
                stats["op_failures"][f"{sp.name}[{oi}]"] = counts[oi]
                if counts[oi] >= max_retries:
                    failing = sp.ops[oi]
                    sp.ops[oi] = PassThroughOp(replaces=failing.name)
                    stats["dummy"].append(
                        f"{sp.name}[{oi}]:{type(failing).__name__}")
                continue
    return current, stats


def _worker_main(node: str, conn: Any, store_conn: Any,
                 store_spec: Dict[str, Any],
                 stream_server: Optional[PartitionStreamServer] = None
                 ) -> None:
    """Worker process entry: recv loop dispatching stage jobs onto lanes.

    ``conn``/``store_conn`` are duck-typed (``send``/``recv``/``close``):
    ``multiprocessing.Connection`` pipes on the default transport, framed
    sockets (``transport.FramedConnection``) on the socket fabric — the
    loop below is medium-agnostic.  ``stream_server`` is the socket
    transport's degraded-exchange endpoint: when a peer is not
    shm-reachable (another host), this worker's spill files stream to it
    from here (ISSUE 9)."""
    client = _WorkerStoreClient(node, store_conn, store_spec)
    exchange = PartitionExchange()   # resident partitions + fetch caches
    plans: Dict[str, Any] = {}
    lanes: Dict[str, _WorkerLane] = {}
    send_lock = threading.Lock()

    def send(msg: Any) -> bool:
        with send_lock:
            try:
                conn.send(msg)
                return True
            except (BrokenPipeError, OSError):
                return False

    def fetch_partitions(refs: List[Dict[str, Any]],
                         held: List[ShmLease]) -> List[IngestItem]:
        """Pull this node's incoming shuffle partitions: map peer segments
        zero-copy (leases land in ``held`` for the caller to release after
        the stage is done with the items), read spill files consume-on-read,
        pop the resident bucket.  ``keep`` retains the batch locally for a
        later consuming stage instead of destroying the source."""
        fetched: List[IngestItem] = []
        # bucket reads first: a peer batch cached below (keep) lands in the
        # same bucket, and collecting after the deposit would double-count it
        order = sorted(refs, key=lambda r: r["kind"] not in ("resident",
                                                             "cached"))
        for ref in order:
            kind = ref["kind"]
            keep = bool(ref.get("keep"))
            if kind in ("resident", "cached"):
                got, leases = exchange.collect(ref["xid"], node,
                                               last=not keep)
                held.extend(leases)
            elif kind == "shm":
                if keep:
                    got, _ = decode_partition(ref, copy=True)
                    exchange.deposit(ref["xid"], node, got,
                                     int(ref.get("nbytes", 0)))
                else:
                    got, lease = decode_partition(ref)   # zero-copy views
                    if lease is not None:
                        held.append(lease)
            elif kind == "file":
                # always consume-on-read: with keep, later consuming stages
                # are served from the cached bucket, never the file again
                got = read_partition_file(ref["path"], remove=True)
                if keep:
                    exchange.deposit(ref["xid"], node, got,
                                     int(ref.get("nbytes", 0)))
            elif kind == "stream":
                # degraded exchange (ISSUE 9): the producer is not
                # shm-reachable — stream its spill file worker-to-worker
                # over the framed protocol (the server deletes on a
                # successful send; the shared-dir direct read is the
                # single-host fallback, also consume-on-read)
                got = fetch_stream_partition(ref)
                if keep:
                    exchange.deposit(ref["xid"], node, got,
                                     int(ref.get("nbytes", 0)))
            else:
                raise ValueError(f"unknown exchange ref kind {kind!r}")
            fetched.extend(got)
        return fetched

    def deal_partitions(xs: Dict[str, Any], out: List[IngestItem],
                        input_leases: List[ShmLease],
                        peer_leases: List[ShmLease]) -> Dict[str, Any]:
        """Partition an exchange-boundary stage's output and hand it out:
        the node's own slice stays resident (holding shares of the input
        leases it may alias) — for a narrow round (``key=None``, ISSUE 5)
        that is the *entire* output — each peer slice crosses via its own
        segment or, past the per-edge spill share, a DFS spill file; an
        oversized resident slice spills under the ``resident_*`` naming.
        Returns the metadata-only manifest.

        On a columnar round (ISSUE 10) the output packs into one
        ColumnarBatch up front: each slice then crosses as a raw column
        buffer — straight into the shm segment, spill file, or stream
        source with no per-item pickling.  Sub-batches own their payload
        (``select`` copies), so resident deposits need no input-lease
        shares.  An output that doesn't pack falls back to the scalar
        path and flags the manifest."""
        hosts = xs.get("hosts") or {}
        my_host = hosts.get(node)

        def columnar_fn(dst: str, batch: ColumnarBatch, nb: int
                        ) -> Dict[str, Any]:
            if dst == node:
                if nb > xs["spill_share"]:
                    path = os.path.join(
                        xs["spill_dir"],
                        columnar_file_name(xs["epoch"], xs["xid"], node, node))
                    write_columnar_file(path, batch)
                    exchange.deposit(xs["xid"], node, None, nb, path=path)
                    return {"kind": "resident", "count": len(batch),
                            "nbytes": nb, "spilled": path, "columnar": True}
                exchange.deposit_batch(xs["xid"], node, batch)
                return {"kind": "resident", "count": len(batch),
                        "nbytes": nb, "columnar": True}
            cross_host = (my_host is not None and hosts.get(dst) is not None
                          and hosts.get(dst) != my_host)
            if cross_host or nb > xs["spill_share"]:
                path = os.path.join(
                    xs["spill_dir"],
                    columnar_file_name(xs["epoch"], xs["xid"], node, dst))
                desc = write_columnar_file(path, batch)
                if cross_host and stream_server is not None:
                    desc = {**desc, "kind": "stream",
                            "endpoint": list(stream_server.endpoint)}
                return desc
            desc, pl = encode_columnar_partition(batch)
            peer_leases.append(pl)
            return desc

        def part_fn(dst: str, its: Any, nb: int) -> Dict[str, Any]:
            if isinstance(its, ColumnarBatch):
                return columnar_fn(dst, its, nb)
            if dst == node:
                if nb > xs["spill_share"]:
                    path = os.path.join(
                        xs["spill_dir"],
                        resident_file_name(xs["epoch"], xs["xid"], node))
                    write_partition_file(path, its)
                    exchange.deposit(xs["xid"], node, None, nb, path=path)
                    return {"kind": "resident", "count": len(its),
                            "nbytes": nb, "spilled": path}
                shares = [l.share() for l in input_leases]
                exchange.deposit(xs["xid"], node, its, nb, leases=shares)
                return {"kind": "resident", "count": len(its), "nbytes": nb}
            if (my_host is not None and hosts.get(dst) is not None
                    and hosts.get(dst) != my_host):
                # degraded mode (ISSUE 9): the consumer cannot map this
                # worker's shm segments — write the partition as an
                # ordinary exchange spill (same naming, same gc_orphans
                # coverage) and advertise the stream endpoint so the peer
                # pulls the bytes worker-to-worker over the framed fabric
                path = os.path.join(
                    xs["spill_dir"],
                    exchange_file_name(xs["epoch"], xs["xid"], node, dst))
                desc = write_partition_file(path, its)
                if stream_server is not None:
                    desc = {**desc, "kind": "stream",
                            "endpoint": list(stream_server.endpoint)}
                return desc
            if nb > xs["spill_share"]:
                path = os.path.join(
                    xs["spill_dir"],
                    exchange_file_name(xs["epoch"], xs["xid"], node, dst))
                return write_partition_file(path, its)
            desc, pl = encode_partition(its)
            peer_leases.append(pl)
            return desc

        payload: Any = out
        fallback = False
        if xs.get("columnar") and out:
            batch = ColumnarBatch.from_items(out)
            if batch is None:
                fallback = True
            else:
                payload = batch
        manifest = build_manifest(payload, xs["key"], xs["targets"], part_fn,
                                  self_node=node)
        if fallback:
            manifest["columnar_fallback"] = True
        return manifest

    def run_job(jid: int, plan_key: str, si: int, payload: Dict[str, Any],
                ctx: Dict[str, Any]) -> None:
        lease = out_lease = None
        held: List[ShmLease] = []        # fetched-partition leases
        peer_leases: List[ShmLease] = []  # outgoing partition segments
        try:
            installed = plans.get(plan_key)
            if isinstance(installed, BaseException):
                raise installed
            if installed is None:
                raise KeyError(f"worker {node}: plan {plan_key!r} not installed")
            sp = installed[si]
            items, lease = decode_items(payload)   # zero-copy shm views
            src = ctx.get("source")
            src_stats: Optional[Tuple[int, int]] = None
            if src is not None:
                # worker-pull source (ISSUE 6): the coordinator shipped only
                # shard descriptors — open/read/parse them here, then route
                # with the source stage's predicates exactly as the
                # coordinator would have routed pushed items
                pulled: List[IngestItem] = []
                for d in src["descs"]:
                    pulled.extend(src["adapter"].read(d))
                src_stats = (len(pulled), items_nbytes(pulled))
                items = items + route_items(pulled, sp.predicates)
                del pulled
            refs = ctx.get("fetch")
            if refs:
                # incoming shuffle partitions merge with the pipe inputs;
                # the stage's label predicates apply to them here, exactly
                # as the coordinator applied them to the pipe inputs
                items = items + route_items(fetch_partitions(refs, held),
                                            sp.predicates)
            client.bind_live(ctx.get("live_nodes"))
            prev = client.set_epoch(ctx.get("epoch"))
            t0 = time.perf_counter()
            try:
                out, stats = _run_stage_ops(
                    sp, items, dict(ctx.get("injections") or {}),
                    int(ctx.get("max_retries", 3)))
            finally:
                client.set_epoch(prev)
            stats["worker_s"] = time.perf_counter() - t0
            if src_stats is not None:
                stats["source_items"], stats["source_bytes"] = src_stats
            xs = ctx.get("shuffle")
            if xs is not None:
                # exchange boundary (shuffle or narrow): partitions go
                # peer-to-peer or stay resident, the reply carries only the
                # manifest (metadata — zero item bytes cross the
                # coordinator pipe)
                input_leases = [l for l in [lease, *held] if l is not None]
                manifest = deal_partitions(xs, out, input_leases, peer_leases)
                out_payload: Dict[str, Any] = {"kind": "xmanifest",
                                               "manifest": manifest}
            elif ctx.get("sink"):
                # terminal stage: outputs die here — only the count returns
                out_payload = {"kind": "sink", "count": len(out),
                               "nbytes": items_nbytes(out)}
            else:
                # encode before releasing input leases: outputs may alias
                out_payload, out_lease = encode_items(out)
            del items, out
            for l in held:
                l.release()
            held = []
            if lease is not None:
                lease.release()
                lease = None
            if send(("done", jid, out_payload, stats)):
                if out_lease is not None:
                    out_lease.detach()
                for pl in peer_leases:   # consumers (or invalidation) unlink
                    pl.detach()
            else:
                if out_lease is not None:
                    out_lease.release()  # coordinator gone: don't leak segs
                for pl in peer_leases:
                    pl.release()
            out_lease = None
            peer_leases = []
        except BaseException as e:
            for l in held:
                l.release()
            if lease is not None:
                lease.release()
            if out_lease is not None:
                out_lease.release()
            for pl in peer_leases:
                pl.release()
            import traceback
            tb = traceback.format_exc()
            if isinstance(e, StopIteration):
                # a StopIteration must not cross into Future.result() —
                # inside a generator frame it would silently end iteration
                # instead of surfacing; carry the worker traceback instead
                e = RuntimeError(f"worker {node}: StopIteration escaped a "
                                 f"stage job\n{tb}")
            else:
                try:
                    pickle.dumps(e)
                except Exception:
                    # unpicklable: ship the worker-side traceback, which the
                    # pickled exception would have dropped anyway
                    e = RuntimeError(f"{type(e).__name__}: {e}\n{tb}")
            send(("fail", jid, e))

    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        kind = msg[0]
        if kind == "stop":
            break
        if kind == "ping":
            # heartbeat (ISSUE 8): answered inline from the recv loop — stage
            # jobs run on lanes, so a *busy* worker still pongs; only a dead
            # or wedged (SIGSTOP'd) process goes silent, which is exactly the
            # condition the coordinator's LivenessMonitor wants to observe
            send(("pong", msg[1]))
        elif kind == "install":
            _, key, blob = msg
            try:
                sps = pickle.loads(blob)
                for sp in sps:
                    for op in sp.ops:
                        if isinstance(op.params.get("store"), _StoreToken):
                            op.params["store"] = client
                            op.store = client
                plans[key] = sps
            except BaseException as e:      # surfaced when a job needs it
                plans[key] = e
        elif kind == "drop":
            # epoch invalidation: clear resident/cached exchange rounds
            exchange.drop(msg[1])
        elif kind == "stall":
            # test hook (ISSUE 9 satellite): block THIS recv loop for
            # ``seconds`` — the exact starvation a long decode or a fork of
            # the GIL inflicts on a healthy worker — while (optionally)
            # issuing store RPCs every ``rpc_every`` seconds, the way a busy
            # stage job does.  Store traffic must keep the worker alive even
            # though no pong can be answered here.
            _, seconds, rpc_every = msg
            deadline = time.monotonic() + seconds
            while time.monotonic() < deadline:
                step = min(rpc_every or 0.05,
                           max(deadline - time.monotonic(), 0.0))
                time.sleep(step)
                if rpc_every:
                    try:
                        client.staging_epoch_ids()
                    except RuntimeError:
                        break
        elif kind == "run":
            _, jid, plan_key, si, lane, payload, ctx = msg
            ln = lanes.get(lane)
            if ln is None:
                ln = lanes[lane] = _WorkerLane(f"{node}:{lane}")
            ln.jobs.put(lambda j=jid, k=plan_key, s=si, p=payload, c=ctx:
                        run_job(j, k, s, p, c))
    exchange.close()
    for ln in lanes.values():
        ln.jobs.put(None)


def _socket_worker_main(node: str, address: Tuple[str, int], token: str,
                        store_spec: Dict[str, Any]) -> None:
    """Socket-transport worker entry (ISSUE 9): instead of inheriting pipe
    ends, the worker *dials back* to its executor's listener — twice, once
    per channel (``role="ctrl"`` / ``"store"``), authenticated by the
    per-executor token — then runs the identical ``_worker_main`` loop over
    the framed connections.  It also stands up its own
    ``PartitionStreamServer`` over the exchange spill dir and advertises
    the endpoint in the ctrl hello, so peers on other hosts can pull this
    worker's partitions in degraded mode."""
    stream_server = PartitionStreamServer(
        store_spec.get("dfs_dir") or store_spec["root"])
    conn = store_conn = None
    try:
        conn = connect_framed(
            address, role="ctrl", node=node, token=token,
            info={"exchange_endpoint": list(stream_server.endpoint)})
        store_conn = connect_framed(address, role="store", node=node,
                                    token=token)
        _worker_main(node, conn, store_conn, store_spec, stream_server)
    finally:
        for c in (conn, store_conn):
            if c is not None:
                c.close()
        stream_server.close()


# ---------------------------------------------------------------------------
# Coordinator side
# ---------------------------------------------------------------------------
class ProcessNodeExecutor:
    """Coordinator handle for one node's worker process.

    Mirrors ``NodeExecutor``'s surface (install once, lane-addressed jobs,
    shutdown) but jobs are stage descriptors shipped over a control pipe, and
    results come back on a receiver thread that resolves Futures by job id.
    A second pipe services the worker's store RPCs (put_block metadata,
    flush) against the coordinator's ``DataStore``.
    """

    #: test hook (ISSUE 8): called once per spawn attempt before the fork —
    #: raising OSError from here simulates a transient fork/shm failure
    spawn_fault: Optional[Callable[[str, int], None]] = None
    #: spawn retry policy (bounded backoff + jitter via liveness.retry_call)
    spawn_attempts: int = 3
    spawn_base_delay_s: float = 0.05
    #: socket-transport handshake window (both channels must dial back)
    accept_timeout_s: float = 15.0

    def __init__(self, node: str, store: DataStore, *,
                 transport: str = "pipe",
                 host: Optional[str] = None,
                 chaos_shim: bool = False,
                 local_worker: bool = True,
                 bulk_registration: bool = False) -> None:
        if transport not in ("pipe", "socket"):
            raise ValueError(f"unknown transport {transport!r} "
                             f"(expected 'pipe' or 'socket')")
        self.node = node
        self.store = store
        self.transport = transport
        #: which machine the worker runs on — drives the liveness monitor's
        #: per-host quorum and the degraded-exchange routing (ISSUE 9);
        #: purely a label here, the fork is local either way in this repo
        self.host = host if host is not None else LOCAL_HOST
        #: whether THIS coordinator spawned the worker pid locally — only
        #: then may the pid-prefix /dev/shm sweep run (ISSUE 9 satellite:
        #: a remote worker's pid names some unrelated local process)
        self.local_worker = local_worker
        #: sweep passes skipped because the worker is not local (reported
        #: as ``sweep_skipped_remote`` — we cannot see a remote /dev/shm,
        #: so we count the skip honestly instead of pretending we swept)
        self.sweep_skips = 0
        #: the worker's PartitionStreamServer address (socket transport)
        self.exchange_endpoint: Optional[Tuple[str, int]] = None
        self._listener: Optional[FrameListener] = None
        self._proxy: Optional[ChaosProxy] = None
        ctx = _mp_context()
        spec = {"root": store.root, "nodes": list(store.nodes),
                "durable": store.durable, "compress": store.compress,
                "compress_level": store.compress_level,
                "journal_commits": store.journal_commits,
                "dfs_dir": store.dfs_dir,
                # columnar data plane (ISSUE 10): the store stage registers
                # a whole block batch in ONE put_batch RPC instead of one
                # synchronous round trip per block; off reproduces the
                # per-block PR-9 protocol exactly
                "bulk_registration": bulk_registration}
        attempt_no = itertools.count(1)

        def spawn_pipe() -> None:
            """One spawn attempt: pipes + fork + start, atomically retried —
            a transient fork/pipe failure used to abort the whole run on
            first try (satellite of ISSUE 8)."""
            n = next(attempt_no)
            if ProcessNodeExecutor.spawn_fault is not None:
                ProcessNodeExecutor.spawn_fault(node, n)
            self._conn, child_conn = ctx.Pipe()
            self._store_conn, child_store = ctx.Pipe()
            self._proc = ctx.Process(target=_worker_main,
                                     args=(node, child_conn, child_store, spec),
                                     daemon=True, name=f"ingest-node-{node}")
            self._proc.start()
            child_conn.close()
            child_store.close()

        def spawn_socket() -> None:
            """One socket-fabric spawn attempt: bind a listener, fork the
            worker with the dial-back address + token, accept both framed
            channels.  Any failure tears the half-built transport down and
            re-raises OSError so ``retry_call`` retries the whole attempt.
            With ``chaos_shim`` the worker dials a :class:`ChaosProxy` in
            front of the listener — the seam the chaos harness's network
            events (partition/drop/delay_conn) render onto."""
            n = next(attempt_no)
            if ProcessNodeExecutor.spawn_fault is not None:
                ProcessNodeExecutor.spawn_fault(node, n)
            self._listener = FrameListener()
            worker_addr = self._listener.address
            if chaos_shim:
                self._proxy = ChaosProxy(self._listener.address)
                worker_addr = self._proxy.address
            token = uuid.uuid4().hex
            self._proc = ctx.Process(target=_socket_worker_main,
                                     args=(node, worker_addr, token, spec),
                                     daemon=True, name=f"ingest-node-{node}")
            self._proc.start()
            try:
                conns: Dict[str, Any] = {}
                deadline = time.monotonic() + self.accept_timeout_s
                while not ("ctrl" in conns and "store" in conns):
                    left = deadline - time.monotonic()
                    if left <= 0:
                        raise TimeoutError(
                            f"worker {node}: handshake incomplete "
                            f"(got {sorted(conns)})")
                    c, role, _n, info = self._listener.accept_framed(
                        token, timeout_s=left)
                    conns[role] = c
                    if role == "ctrl":
                        ep = info.get("exchange_endpoint")
                        if ep:
                            self.exchange_endpoint = (ep[0], int(ep[1]))
                self._conn = conns["ctrl"]
                self._store_conn = conns["store"]
            except (OSError, TimeoutError) as e:
                self._close_transport()
                try:
                    self._proc.kill()
                except (ProcessLookupError, OSError):
                    pass
                raise OSError(f"socket spawn of {node} failed: {e}") from e

        _, used = retry_call(
            spawn_socket if transport == "socket" else spawn_pipe,
            attempts=self.spawn_attempts,
            base_delay_s=self.spawn_base_delay_s,
            retry_on=(OSError,))
        self.spawn_retries = used - 1   # attempts beyond the first
        self._last_beat = time.monotonic()
        self._ping_seq = itertools.count()
        self._send_lock = threading.Lock()
        self._lock = threading.Lock()
        self._pending: Dict[int, Future] = {}
        self._inflight_shm: Dict[int, str] = {}   # jid -> input segment name
        self._plans: Dict[int, Tuple[Any, str]] = {}   # id(orig) -> (pin, key)
        self._jid = itertools.count()
        self._dead = False
        self._recv_thread = threading.Thread(target=self._recv_loop,
                                             daemon=True,
                                             name=f"recv-{node}")
        self._store_thread = threading.Thread(target=self._store_loop,
                                              daemon=True,
                                              name=f"store-rpc-{node}")
        self._recv_thread.start()
        self._store_thread.start()

    # --------------------------------------------------------------- liveness
    @property
    def alive(self) -> bool:
        return not self._dead and self._proc.is_alive()

    def kill(self) -> None:
        """Test hook: simulated machine failure (SIGTERM the worker)."""
        self._proc.terminate()

    def hang(self) -> None:
        """Test hook: wedge the worker (SIGSTOP) — the process freezes with
        its pipe still open, the exact blind spot heartbeat liveness covers."""
        import signal
        os.kill(self._proc.pid, signal.SIGSTOP)

    def resume(self) -> None:
        """Undo :meth:`hang` (SIGCONT).  No-op on an exited process."""
        import signal
        try:
            os.kill(self._proc.pid, signal.SIGCONT)
        except (ProcessLookupError, OSError):
            pass

    # ------------------------------------------------- heartbeats (ISSUE 8)
    def send_ping(self) -> None:
        """Best-effort heartbeat probe.  Any reply — the pong, or whatever
        job traffic beats it — refreshes ``heartbeat_age``.  Send failures
        are swallowed: a closed pipe is the EOF path's business."""
        if self._dead:
            return
        try:
            self._send(("ping", next(self._ping_seq)))
        except WorkerDeath:
            pass

    def heartbeat_age(self) -> float:
        """Seconds since the worker last said anything on its pipe."""
        return time.monotonic() - self._last_beat

    def fail_unresponsive(self) -> None:
        """Declare a silent worker dead: SIGKILL (a SIGSTOP'd process never
        delivers SIGTERM — kill is the only signal a stopped process cannot
        hold off) and fail every in-flight future with WorkerDeath so the
        runtime's NodeFailure recovery takes over immediately instead of
        waiting on an EOF that may never come.  The transport is closed
        too: under a network partition the proxy never forwards the dead
        worker's EOF, so a blocked receiver thread must be unblocked from
        this side."""
        try:
            self._proc.kill()
        except (ProcessLookupError, OSError):
            pass
        self._mark_dead()
        self._close_transport()
        self._sweep_segments()

    # ------------------------------------------------ network chaos (ISSUE 9)
    def net_partition(self) -> None:
        """Chaos hook: go dark on this worker's link — the proxy stops
        pumping both directions, heartbeats die, and the liveness monitor's
        per-host quorum declares the host partitioned.  No-op without the
        chaos shim (pipe transport, or shim disabled)."""
        if self._proxy is not None:
            self._proxy.partition()

    def net_heal(self) -> None:
        if self._proxy is not None:
            self._proxy.heal()

    def net_drop(self, n: int = 64) -> None:
        """Chaos hook: discard the next ``n`` bytes worker->coordinator —
        the next coordinator recv sees a garbled/torn frame (FrameError ->
        WorkerDeath), never a hang."""
        if self._proxy is not None:
            self._proxy.drop_bytes(n)

    def net_delay(self, seconds: float) -> None:
        """Chaos hook: one-shot forwarding stall (slow link)."""
        if self._proxy is not None:
            self._proxy.delay(seconds)

    def stall_recv(self, seconds: float, rpc_every: float = 0.0) -> None:
        """Test hook (ISSUE 9 satellite): make the worker's recv loop go
        silent for ``seconds`` — no pongs — while issuing store RPCs every
        ``rpc_every`` seconds, reproducing a saturated-but-healthy worker
        deterministically."""
        try:
            self._send(("stall", float(seconds), float(rpc_every)))
        except WorkerDeath:
            pass

    # ------------------------------------------------------------------- send
    def _send(self, msg: Any) -> None:
        if self._dead:
            raise WorkerDeath(self.node)
        with self._send_lock:
            try:
                self._conn.send(msg)
            except (BrokenPipeError, OSError) as e:
                raise WorkerDeath(self.node) from e

    # ------------------------------------------------------------------ plans
    def install_plan(self, stage_plans: List[StagePlan]) -> str:
        """Ship the compiled plan once (the launch_remote seam, realized:
        the pickled DAG crosses to the worker, which keeps it resident)."""
        key_id = id(stage_plans)
        with self._lock:
            cached = self._plans.get(key_id)
            if cached is not None and cached[0] is stage_plans:
                return cached[1]
        blob = serialize_plans_for_worker(stage_plans, self.store)
        key = f"plan-{key_id:x}"
        self._send(("install", key, blob))
        with self._lock:
            self._plans[key_id] = (stage_plans, key)
        return key

    # ------------------------------------------------------------------- jobs
    def run_stage(self, plan_key: str, stage_idx: int,
                  items: List[IngestItem], *, lane: str = "main",
                  epoch: Optional[int] = None,
                  live_nodes: Optional[Sequence[str]] = None,
                  injections: Optional[Dict[int, int]] = None,
                  max_retries: int = 3,
                  shuffle_ctx: Optional[Dict[str, Any]] = None,
                  fetch_refs: Optional[List[Dict[str, Any]]] = None,
                  sink: bool = False,
                  source_ctx: Optional[Dict[str, Any]] = None) -> Future:
        """Run one stage over ``items`` on the worker; resolves to
        ``(output_items, stats)`` — or ``(manifest_payload, stats)`` when
        ``shuffle_ctx`` marks the stage a shuffle boundary (the worker dealt
        its partitions to the peers and replied metadata only).
        ``fetch_refs`` are the incoming partition descriptors the worker
        must merge into the stage's inputs.  ``source_ctx`` carries a
        worker-pull source: ``{"adapter", "descs"}`` shard descriptors the
        worker reads itself (ISSUE 6) — metadata on the pipe, never item
        bytes.  Fails with WorkerDeath if the node dies mid-flight (mapped
        to NodeFailure by the runtime)."""
        fut: Future = Future()
        if self._dead:
            fut.set_exception(WorkerDeath(self.node))
            return fut
        payload, lease = encode_items(items)
        jid = next(self._jid)
        with self._lock:
            self._pending[jid] = fut
            if payload.get("shm"):
                # registered before the send: a worker dying at any point
                # after this cannot leak the segment (_mark_dead reclaims)
                self._inflight_shm[jid] = payload["shm"]
        ctx = {"epoch": epoch,
               "live_nodes": list(live_nodes) if live_nodes else None,
               "injections": dict(injections or {}),
               "max_retries": max_retries,
               "shuffle": dict(shuffle_ctx) if shuffle_ctx else None,
               "fetch": list(fetch_refs) if fetch_refs else None,
               "sink": sink,
               "source": dict(source_ctx) if source_ctx else None}
        try:
            self._send(("run", jid, plan_key, stage_idx, lane, payload, ctx))
            if lease is not None:
                lease.detach()   # disown: consumer (or _mark_dead) unlinks
        except WorkerDeath as e:
            with self._lock:
                known = self._pending.pop(jid, None)
                self._inflight_shm.pop(jid, None)
            if lease is not None:
                lease.release()
            if known is not None:
                # still ours to fail; otherwise _mark_dead raced us here and
                # already failed the future with WorkerDeath
                fut.set_exception(e)
        return fut

    # -------------------------------------------------------------- receivers
    def _recv_loop(self) -> None:
        try:
            while True:
                msg = self._conn.recv()
                self._last_beat = time.monotonic()   # any traffic is a beat
                kind = msg[0]
                if kind == "pong":
                    continue
                if kind == "done":
                    _, jid, payload, stats = msg
                    with self._lock:
                        fut = self._pending.pop(jid, None)
                        self._inflight_shm.pop(jid, None)
                    if fut is None:
                        continue
                    try:
                        if (isinstance(payload, dict)
                                and payload.get("kind") in ("xmanifest",
                                                            "sink")):
                            # exchange manifest / sink count: metadata only
                            fut.set_result((payload, stats))
                        else:
                            # copy=True: results outlive the hop (retained
                            # epoch outputs) — the segment dies here
                            items, _ = decode_items(payload, copy=True)
                            fut.set_result((items, stats))
                    except BaseException as e:
                        fut.set_exception(e)
                elif kind == "fail":
                    _, jid, exc = msg
                    with self._lock:
                        fut = self._pending.pop(jid, None)
                        self._inflight_shm.pop(jid, None)
                    if fut is not None:
                        fut.set_exception(
                            exc if isinstance(exc, BaseException)
                            else RuntimeError(str(exc)))
        except (EOFError, OSError):
            pass
        finally:
            self._mark_dead()

    def _mark_dead(self) -> None:
        """Pipe EOF == the sentinel: the worker process is gone.  Every
        pending and future job fails with WorkerDeath, which the runtime's
        stage barrier converts into the NodeFailure fault path.  Input
        segments the dead worker never consumed are reclaimed here."""
        with self._lock:
            self._dead = True
            pending, self._pending = list(self._pending.values()), {}
            orphans, self._inflight_shm = list(self._inflight_shm.values()), {}
        for name in orphans:
            try:
                from multiprocessing import shared_memory
                seg = shared_memory.SharedMemory(name=name)
                seg.close()
                seg.unlink()
            except (FileNotFoundError, OSError):
                pass
        for fut in pending:
            fut.set_exception(WorkerDeath(self.node))

    def _sweep_segments(self) -> None:
        """Reclaim every segment the dead worker *created* (named
        ``psm_ing<pid>_*``, see ``items.create_segment``), announced or not.
        A SIGKILLed worker cannot clean up after itself, and a segment it
        created mid-produce was never registered anywhere the coordinator's
        bookkeeping could find it.  Two callers, both past the point where a
        live reader could race the unlink: the liveness declaration path
        (the worker was frozen for the whole miss window, so consumers of
        its announced segments have long attached) and ``shutdown`` (the
        engine is closing — no jobs in flight, nothing will attach again).
        The latter also catches survivors' orphans: a job result carrying a
        manifest can be preempted by a peer's NodeFailure before the
        coordinator records it, leaving segments only the producing worker's
        pid prefix still names.

        Remote workers (``local_worker=False``) are *skipped*, not swept:
        their ``/dev/shm`` is another machine's, and their pid can name an
        unrelated local process — unlinking by that prefix here would be
        both useless and dangerous.  The skip is counted (``sweep_skips``,
        surfaced as ``sweep_skipped_remote`` in run reports) so the old
        silent no-op can't masquerade as a clean sweep."""
        if not self.local_worker:
            self.sweep_skips += 1
            return
        pid = getattr(self._proc, "pid", None)
        if pid is None:
            return
        self._proc.join(timeout=2)   # let the SIGKILL land first
        sweep_pid_segments(pid)

    def _store_loop(self) -> None:
        try:
            while True:
                msg = self._store_conn.recv()
                # satellite fix (ISSUE 9): store RPCs are proof of life too.
                # A worker saturated in a long batch block starves its ctrl
                # recv loop (no pongs) while actively committing blocks —
                # without this refresh the liveness monitor would SIGKILL a
                # healthy, working node.
                self._last_beat = time.monotonic()
                kind = msg[0]
                try:
                    if kind == "put":
                        kw = dict(msg[1])
                        entry = self.store.register_block_file(
                            kw.pop("node"), kw.pop("tmp_path"), **kw)
                        reply = ("ok", asdict(entry))
                    elif kind == "put_batch":
                        # columnar data plane (ISSUE 10): one round trip
                        # registers the whole block batch, order preserved —
                        # each record is exactly a "put" payload, so the
                        # store-side semantics (and retry story) are the
                        # per-block path's, minus the per-block latency.
                        # The reply carries only what the coordinator
                        # assigned (block id + final path); the worker holds
                        # everything else in the records it just sent
                        ents = self.store.register_block_batch(msg[1])
                        reply = ("ok", [(e.block_id, e.path) for e in ents])
                    elif kind == "staging":
                        reply = ("ok", self.store.staging_epoch_ids())
                    elif kind == "flush":
                        self.store.flush_manifest()
                        reply = ("ok", None)
                    else:
                        reply = ("err", f"unknown store RPC {kind!r}")
                except BaseException as e:
                    reply = ("err", f"{type(e).__name__}: {e}")
                self._store_conn.send(reply)
        except (EOFError, OSError):
            pass
        finally:
            # the worker never closes its store channel while alive, so a
            # dead store loop means a dead (or garbled-link) worker: fail
            # in-flight work now instead of waiting for the ctrl channel
            # to notice.  Idempotent, so the orderly-shutdown call is free.
            self._mark_dead()

    # --------------------------------------------------------------- exchange
    def drop_exchange(self, xids: Sequence[int]) -> None:
        """Best-effort: tell the worker to drop invalidated exchange rounds
        (epoch abort/replay).  A dead worker's buckets died with it."""
        if self._dead or not xids:
            return
        try:
            self._send(("drop", list(xids)))
        except WorkerDeath:
            pass

    # --------------------------------------------------------------- shutdown
    def _close_transport(self) -> None:
        """Close both channels plus the socket fabric's listener/proxy.
        Safe on a half-built executor (spawn-attempt cleanup) and
        idempotent; closing unblocks receiver threads whose peer is
        partitioned and will never deliver an EOF."""
        for conn in (getattr(self, "_conn", None),
                     getattr(self, "_store_conn", None)):
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
        if self._proxy is not None:
            self._proxy.close()
        if self._listener is not None:
            self._listener.close()

    def shutdown(self) -> None:
        if not self._dead:
            try:
                self._send(("stop",))
            except WorkerDeath:
                pass
        self._proc.join(timeout=5)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=5)
        self._mark_dead()
        self._sweep_segments()
        self._close_transport()
