"""STORE-side ingestion operators: locate / upload (+ erasure-coding store ops).

Paper Sec. IV-A: ``STORE s LOCATE USING locator UPLOAD TO target``.  The
locator maps items to *location IDs* (logical placement, Sec. VI-B); upload
binds to the registered storage target and publishes physical blocks with
lineage-encoded names.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..erasure import ReedSolomon
from ..layouts import SerializedBlock, serialize_block
from .items import Granularity, IngestItem
from .operators import BatchFallback, IngestOp, register_op
from .store import DataStore


# --------------------------------------------------------------------- locate
@register_op("locate")
class LocateOp(IngestOp):
    """Assign a logical location ID to each item (paper Sec. VI-B Placement).

    Schemes:
      random    — uniform random location
      roundrobin— cycle locations in order
      disjoint  — replicas of the same logical item get different locations
                  (anti-location; the paper's disjointLocator)
      content   — location = value of an upstream label (content-based placement,
                  e.g. the range-partition id), ``by=<label op>``
      colocate  — same as content but hashing the label value into num_locations
                  (co-location of equal keys across datasets)
    """

    name = "locate"
    batch_capable = True

    def __init__(self, scheme: str = "roundrobin", num_locations: int = 4,
                 by: Optional[str] = None, seed: int = 0, **kw: Any) -> None:
        super().__init__(scheme=scheme, num_locations=num_locations, by=by, seed=seed, **kw)
        self.scheme, self.num_locations, self.by = scheme, num_locations, by
        self._rng = np.random.default_rng(seed)
        self._rr = itertools.count()
        self._replica_seen: Dict[str, int] = {}

    def _loc(self, item: IngestItem) -> int:
        if self.scheme == "random":
            return int(self._rng.integers(self.num_locations))
        if self.scheme == "roundrobin":
            return next(self._rr) % self.num_locations
        if self.scheme == "disjoint":
            key = DataStore._logical_id(item)
            idx = self._replica_seen.get(key, 0)
            self._replica_seen[key] = idx + 1
            return idx % self.num_locations
        if self.scheme == "content":
            return int(item.label_value(self.by, 0)) % self.num_locations
        if self.scheme == "colocate":
            return hash(item.label_value(self.by, 0)) % self.num_locations
        raise ValueError(f"unknown locator scheme {self.scheme!r}")

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        yield item.with_label(self.name, self._loc(item))


# --------------------------------------------------------------------- erasure
@register_op("erasure")
class ErasureOp(IngestOp):
    """BLOCK -> BLOCK* Reed-Solomon striping (paper Sec. II-D / VI-C2).

    Collects ``k`` data blocks into a stripe and emits them unchanged plus
    ``m`` parity blocks (labelled ``erasure=p<i>``); stripe membership is
    recorded in item.meta for the upload operator.  Different FORMAT stages
    can use different (k, m) — the paper's *flexible erasure coding*.
    """

    name = "erasure"
    granularity_in = Granularity.BLOCK
    granularity_out = Granularity.BLOCK
    # NOT parallel-mode despite being CPU-heavy: stripe accumulation is
    # stateful (self._stripe) — thread-pool processing interleaved items
    # from different stripes (found by benchmarks/bench_recovery)
    cpu_heavy = False
    # the batch path keeps stripes in arrival order, so it IS safe to
    # vectorize: one stacked GF(256) matmul over all of a batch's stripes
    batch_capable = True
    expansion = 1.3

    def __init__(self, k: int = 10, m: int = 3, use_pallas: bool = False, **kw: Any) -> None:
        super().__init__(k=k, m=m, use_pallas=use_pallas, **kw)
        import uuid
        self.k, self.m = k, m
        self.rs = ReedSolomon(k, m, use_pallas=use_pallas)
        self._stripe: List[IngestItem] = []
        self._stripe_idx = 0
        # unique per operator instance: every node clones its own instance,
        # and stripe ids must not collide across nodes in the shared manifest
        self._nonce = uuid.uuid4().hex[:8]
        self.expansion = (k + m) / k

    def _payload(self, item: IngestItem) -> bytes:
        d = item.data
        if isinstance(d, SerializedBlock):
            return d.tobytes()
        if isinstance(d, (bytes, bytearray)):
            return bytes(d)
        if isinstance(d, np.ndarray):
            return d.tobytes()
        raise TypeError(f"erasure needs BLOCK payloads, got {type(d)}")

    def _emit_encoded(self, stripe: List[IngestItem], parity: np.ndarray,
                      pad_len: int) -> Iterable[IngestItem]:
        """Emit one encoded stripe: the data items labelled in place plus the
        ``m`` parity items.  Shared by the scalar and batch paths — the only
        difference between them is who computed ``parity``."""
        stripe_id = f"stripe-{self._nonce}-{self._stripe_idx}"
        self._stripe_idx += 1
        for pos, it in enumerate(stripe):
            out = it.with_label(self.name, f"d{pos}")
            out.meta.update(stripe_id=stripe_id, stripe_pos=pos, is_parity=False,
                            stripe_k=self.k, stripe_m=self.m, stripe_pad=pad_len)
            yield out
        for j in range(self.m):
            pit = IngestItem(parity[j].tobytes(), Granularity.BLOCK,
                             stripe[0].labels, {})
            pit = pit.with_label(self.name, f"p{j}")
            pit.meta.update(stripe_id=stripe_id, stripe_pos=self.k + j, is_parity=True,
                            stripe_k=self.k, stripe_m=self.m, stripe_pad=pad_len)
            yield pit

    def _emit_stripe(self) -> Iterable[IngestItem]:
        payloads = [self._payload(it) for it in self._stripe]
        parity, pad_len = self.rs.encode_payloads(payloads)
        yield from self._emit_encoded(self._stripe, parity, pad_len)
        self._stripe = []

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        self._stripe.append(item)
        if len(self._stripe) == self.k:
            yield from self._emit_stripe()

    # ------------------------------------------------- batch tier (ISSUE 7)
    def _payload_view(self, item: IngestItem) -> np.ndarray:
        """Flat uint8 view of a BLOCK payload, without a copy where the
        buffer protocol allows (bytes, contiguous arrays)."""
        d = item.data
        if isinstance(d, (bytes, bytearray)):
            return np.frombuffer(d, dtype=np.uint8)
        if isinstance(d, np.ndarray):
            return np.ascontiguousarray(d).view(np.uint8).ravel()
        if isinstance(d, SerializedBlock):
            return np.frombuffer(d.tobytes(), dtype=np.uint8)
        raise BatchFallback(f"erasure batch: unsupported payload {type(d)}")

    def process_batch(self, items: Sequence[IngestItem]) -> List[IngestItem]:
        """Encode S stripes in one stacked GF(256) matmul (``(m x k) @
        (k x sum L_s)``) instead of S per-stripe encodes.  Stripe grouping,
        per-stripe padding, labels, and metadata are byte-identical to the
        scalar iterator path; a trailing partial stripe is drained with
        virtual zero blocks exactly like the scalar ``set_input`` drain."""
        pending = self._stripe + list(items)
        self._stripe = []
        if not pending:
            return []
        stripes = [pending[i:i + self.k]
                   for i in range(0, len(pending), self.k)]
        views = [[self._payload_view(it) for it in s] for s in stripes]
        encoded = self.rs.encode_payload_batch(views)
        self.kernel_ms_total += self.rs.last_kernel_s * 1000.0
        out: List[IngestItem] = []
        for stripe, (parity, pad_len) in zip(stripes, encoded):
            out.extend(self._emit_encoded(stripe, parity, pad_len))
        return out

    def finalize(self) -> None:
        # NOTE: trailing partial stripe is encoded with the same (k, m) by
        # zero-padding virtual blocks; handled in set_input drain below.
        super().finalize()

    def set_input(self, items: Sequence[IngestItem]) -> None:  # drain partial stripe
        super().set_input(items)
        base = self._outputs

        def drained():
            yield from base
            if self._stripe:
                yield from self._emit_stripe()

        self._outputs = drained()


# ---------------------------------------------------------------------- upload
@register_op("upload")
class UploadOp(IngestOp):
    """BLOCK -> BLOCK publish into the DataStore target (paper Sec. VIII-A).

    * maps each physical partition/block to a store file named by its lineage,
    * honours the replication already present in the plan (replica labels),
    * maps location IDs to nodes (user map or round-robin over the slaves list),
    * records stripe metadata for erasure-coded blocks.
    """

    name = "upload"
    granularity_in = Granularity.BLOCK
    granularity_out = Granularity.BLOCK
    commit_side = True  # publishes into the DataStore -> store-segment stage
    # store registration is per-item and order-preserving either way; capable
    # so the store stage's first block anchors columnar edges (ISSUE 10)
    batch_capable = True

    def __init__(self, store: Optional[DataStore] = None,
                 location_map: Optional[Dict[int, str]] = None,
                 serialize_default: str = "columnar", **kw: Any) -> None:
        super().__init__(store=store, location_map=location_map,
                         serialize_default=serialize_default, **kw)
        self.store = store
        self.location_map = location_map
        self.serialize_default = serialize_default
        self._replica_counter: Dict[str, int] = {}

    def _node_for(self, item: IngestItem) -> str:
        # location IDs map over the *live* slaves: a node the runtime marked
        # dead takes no new blocks — its location ids flow to the survivors
        # (paper Sec. VI-C1)
        nodes = self.store.live_nodes() or self.store.nodes
        loc = item.label_value("locate")
        if loc is None:
            loc = abs(hash(item.lineage_name()))
        if self.location_map and loc in self.location_map:
            return self.location_map[loc]
        return nodes[int(loc) % len(nodes)]  # round-robin over slaves (Sec. VI-B)

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        if self.store is None:
            raise RuntimeError("UploadOp has no bound DataStore target")
        if isinstance(item.data, dict):  # un-serialized chunk: apply default layout
            item = IngestItem(serialize_block(item.data, self.serialize_default),
                              Granularity.BLOCK, item.labels, dict(item.meta))
            item = item.with_label("serialize", self.serialize_default)
        logical = DataStore._logical_id(item)
        ridx = self._replica_counter.get(logical, 0)
        self._replica_counter[logical] = ridx + 1
        entry = self.store.put_block(
            item, self._node_for(item),
            logical_id=logical, replica_index=ridx,
            stripe_id=item.meta.get("stripe_id", ""),
            stripe_pos=item.meta.get("stripe_pos", -1),
            is_parity=item.meta.get("is_parity", False),
        )
        yield item.with_label(self.name, entry.node)

    def process_batch(self, items: Sequence[IngestItem]) -> List[IngestItem]:
        """Columnar data plane (ISSUE 10): publish the whole batch through
        ONE ``put_block_batch`` call.  Replica counting, node mapping, and
        registration order are exactly the serial iterator's, so the store
        entries are byte-identical; what changes is the control plane — a
        worker-side store registers N blocks in one coordinator round trip
        instead of N synchronous per-block RPCs.  Stores without bulk
        registration (or with it switched off: the item-at-a-time oracle)
        keep the per-block protocol."""
        if self.store is None:
            raise RuntimeError("UploadOp has no bound DataStore target")
        if not (getattr(self.store, "bulk_registration", False)
                and hasattr(self.store, "put_block_batch")):
            return super().process_batch(items)
        reqs = []
        prepped: List[IngestItem] = []
        for item in items:
            if isinstance(item.data, dict):  # un-serialized chunk
                item = IngestItem(
                    serialize_block(item.data, self.serialize_default),
                    Granularity.BLOCK, item.labels, dict(item.meta))
                item = item.with_label("serialize", self.serialize_default)
            logical = DataStore._logical_id(item)
            ridx = self._replica_counter.get(logical, 0)
            self._replica_counter[logical] = ridx + 1
            prepped.append(item)
            reqs.append({
                "item": item, "node": self._node_for(item),
                "logical_id": logical, "replica_index": ridx,
                "stripe_id": item.meta.get("stripe_id", ""),
                "stripe_pos": item.meta.get("stripe_pos", -1),
                "is_parity": item.meta.get("is_parity", False),
            })
        entries = self.store.put_block_batch(reqs)
        return [it.with_label(self.name, e.node)
                for it, e in zip(prepped, entries)]

    def finalize(self) -> None:
        # while an epoch stages, a manifest flush publishes nothing (staged
        # blocks are withheld) — skip the O(store) rewrite; the epoch commit
        # is the publish point.  Batch runs still flush per stage, and
        # snapshot-commit stores (journal_commits=False) keep the manifest
        # continuously current, as before ISSUE 2.
        if self.store is not None and (
                not getattr(self.store, "journal_commits", True)
                or not self.store.staging_epoch_ids()):
            self.store.flush_manifest()
        super().finalize()
