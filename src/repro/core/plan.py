"""Ingestion plans: statements, dataflow stages, and the compiled stage DAG.

Paper Sec. IV: declarative statements (SELECT/FORMAT/STORE) build operator
chains; CREATE STAGE / CHAIN STAGE compose them into an operator DAG with
label-predicate routing ("ingestion data flow").  Sec. V: the optimizer
rewrites the DAG; Sec. VI: the runtime executes it.
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .items import IngestItem, matches
from .operators import IngestOp, MaterializeOp


@dataclass
class Statement:
    """A named linear chain of ingestion operators (one s<i> in the paper)."""

    sid: str
    ops: List[IngestOp] = field(default_factory=list)
    kind: str = "select"  # select | format | store
    inputs: List[str] = field(default_factory=list)  # upstream statement ids

    def __repr__(self) -> str:
        return f"Statement({self.sid}: {' -> '.join(type(o).__name__ for o in self.ops)})"


@dataclass
class Stage:
    """A dataflow stage: a set of statements applied to the label-filtered
    subset of upstream items (paper Sec. IV-B)."""

    name: str
    statements: List[str]                      # statement ids, applied in order
    upstream: List[str] = field(default_factory=list)  # stage names (CHAIN ... TO)
    predicates: Dict[str, Any] = field(default_factory=dict)  # l_op -> value/callable

    def __repr__(self) -> str:
        ups = ",".join(self.upstream) or "<source>"
        return f"Stage({self.name} <- {ups} using {self.statements} where {self.predicates})"


@dataclass
class StagePlan:
    """A stage with its concrete, optimizer-rewritten operator chain.

    ``pipeline_blocks`` partitions the chain into pipelined groups; a
    materialization barrier (= in-flight checkpoint) sits after each block
    (paper Sec. V pipelining, Sec. VI-C1 recovery).

    ``commit_side`` marks stages whose operators publish into the DataStore
    (upload).  The pipelined streaming runtime may overlap a new epoch's
    execution only with the *commit-side* suffix of the previous epoch
    (DESIGN.md §4) — this metadata is what drives that split.

    ``shuffle_key`` names the routing key of a shuffle-boundary stage (the
    last ``shuffle_by`` param in the chain, or None): with the key in the
    plan metadata, node workers partition their own output locally and
    exchange partitions peer-to-peer — the coordinator never has to inspect
    operator params or touch item bytes (DESIGN.md §4).

    ``edge_kinds`` is the compiled per-edge routing taxonomy (DESIGN.md §4,
    ISSUE 5): consumer stage name -> ``"narrow"`` (identity routing — the
    producer's output stays resident on its own node), ``"shuffle"``
    (partitioned across peers by ``shuffle_key``), or ``"cross-segment"``
    (the consumer lies in the other pipeline segment, so the exchange round
    is pinned across ``_execute`` slices).  Set by ``compile()`` and
    recomputed by the optimizer after rule rewrites.

    ``replay_cone`` classifies this stage's recovery lineage (ISSUE 8):
    ``"self"`` means a node's partial state at this stage derives only from
    its own input shards (every ancestor edge is identity-routed), so on
    that node's death the minimal replay cone is just its shards;
    ``"peers"`` means a shuffle edge somewhere upstream mixed other nodes'
    lineages into this stage — the cone widens to the shuffle consumers'
    inputs, i.e. in practice the whole-epoch fallback.
    """

    name: str
    ops: List[IngestOp]
    upstream: List[str]
    predicates: Dict[str, Any]
    pipeline_blocks: List[List[int]] = field(default_factory=list)
    commit_side: bool = False
    shuffle_key: Optional[str] = None
    edge_kinds: Dict[str, str] = field(default_factory=dict)
    replay_cone: str = "self"
    # per-pipeline-block batch-mode selection (ISSUE 7): ``batch_blocks[b]``
    # is True when the VectorizeRule rewrote block ``b`` to run through the
    # operators' vectorized ``process_batch`` path; empty = all-scalar (plans
    # that never went through the optimizer are untouched)
    batch_blocks: List[bool] = field(default_factory=list)
    # columnar-capable edges (ISSUE 10): consumer stage name -> True when the
    # batch may cross this edge as a ColumnarBatch (producer's last pipeline
    # block and the consumer's first block are both batch-mode, so neither
    # side needs per-item materialization).  Annotated by the optimizer after
    # ``annotate_edges``; empty = scalar item-at-a-time everywhere (hand-built
    # or unoptimized plans — the correctness oracle).
    columnar_edges: Dict[str, bool] = field(default_factory=dict)

    def block_of(self, op_idx: int) -> int:
        for b, idxs in enumerate(self.pipeline_blocks):
            if op_idx in idxs:
                return b
        return 0

    def clone(self) -> "StagePlan":
        """Fresh operator instances, same structure — what shipping the plan
        to a node means (thread backend: in-process clone; process backend:
        pickled across the control pipe, see ``serialize_plans``)."""
        return StagePlan(self.name, [op.clone() for op in self.ops],
                         list(self.upstream), dict(self.predicates),
                         [list(b) for b in self.pipeline_blocks],
                         commit_side=self.commit_side,
                         shuffle_key=self.shuffle_key,
                         edge_kinds=dict(self.edge_kinds),
                         replay_cone=self.replay_cone,
                         batch_blocks=list(self.batch_blocks),
                         columnar_edges=dict(self.columnar_edges))

    def compute_commit_side(self) -> bool:
        """A stage is commit-side iff any of its operators writes the store."""
        return any(getattr(op, "commit_side", False) for op in self.ops)

    def compute_shuffle_key(self) -> Optional[str]:
        """Routing key of the stage's shuffle boundary (last wins), if any."""
        return shuffle_key_of(self.ops)


def coerce_bool(value: Any) -> bool:
    """Boolean knob coercion shared by the language surface and
    ``EpochPolicy`` (``adaptive=1`` / ``"true"`` literals): plans store the
    coerced value in ``stream_config`` so every layer agrees."""
    if isinstance(value, str):
        return value.strip().lower() in ("1", "true", "yes", "on")
    return bool(value)


def annotate_edges(stage_plans: Sequence["StagePlan"]) -> List["StagePlan"]:
    """Compile the per-edge routing taxonomy into the stage DAG (ISSUE 5).

    For every producer stage the edge to each consuming stage is classified:

    * ``"cross-segment"`` — producer in the ingest segment, consumer in the
      store segment (the first commit-side stage starts the store segment):
      the exchange round for this edge must be *pinned* across ``_execute``
      slices so the pipelined streaming engine's store segment can consume
      node-resident buckets the ingest segment left behind.
    * ``"shuffle"`` — the producer has a routing key (``shuffle_key``): its
      output is partitioned across the peers.
    * ``"narrow"`` — identity routing: the producer's output stays resident
      on its own node and the consumer reads it in place; no item bytes
      cross the coordinator.

    Runs after optimizer rewrites too (rules can fuse/reorder the op that
    carries ``shuffle_by``), so the runtime always sees current metadata.

    Alongside the edge taxonomy the per-stage ``replay_cone`` is compiled
    (ISSUE 8): walking the DAG in topological order, a stage is ``"peers"``
    if any upstream edge carries a shuffle key or any upstream stage is
    already ``"peers"`` — a shuffle ancestor mixed other nodes' lineages
    into it — and ``"self"`` otherwise (the node's partials derive from its
    own shards alone, so death recovery can replay just that node's cone).
    """
    plans = list(stage_plans)
    split = segment_split(plans)
    cones: Dict[str, str] = {}
    for i, sp in enumerate(plans):
        kinds: Dict[str, str] = {}
        shuffles = bool(sp.shuffle_key or sp.compute_shuffle_key())
        cone = "self"
        for up in sp.upstream:
            producer = next((p for p in plans if p.name == up), None)
            if producer is None:
                continue
            if (cones.get(up) == "peers"
                    or producer.shuffle_key or producer.compute_shuffle_key()):
                cone = "peers"
        cones[sp.name] = sp.replay_cone = cone
        for j in range(i + 1, len(plans)):
            if sp.name not in plans[j].upstream:
                continue
            if i < split <= j:
                kinds[plans[j].name] = "cross-segment"
            else:
                kinds[plans[j].name] = "shuffle" if shuffles else "narrow"
        sp.edge_kinds = kinds
    return plans


def segment_split(stage_plans: Sequence["StagePlan"]) -> int:
    """Index of the first commit-side stage — the ingest/store segment
    boundary the pipelined streaming engine overlaps across (DESIGN.md §4).
    ``len(stage_plans)`` when no stage publishes to the store."""
    for i, sp in enumerate(stage_plans):
        if sp.commit_side or sp.compute_commit_side():
            return i
    return len(stage_plans)


def cone_replay_capable(stage_plans: Sequence["StagePlan"],
                        split: Optional[int] = None) -> bool:
    """Can a single node death during the ingest segment be repaired by
    replaying only that node's lineage cone (ISSUE 8)?

    True iff every ingest-segment stage is identity-routed: no stage before
    the segment split carries a shuffle key and every such stage's
    ``replay_cone`` is ``"self"``.  A shuffle anywhere in the segment
    commingles producers inside one exchange round, so per-producer
    invalidation cannot separate the dead node's contribution — the
    whole-epoch fallback handles those plans.
    """
    plans = list(stage_plans)
    if split is None:
        split = segment_split(plans)
    if split <= 0:
        return False
    for sp in plans[:split]:
        if sp.shuffle_key or sp.compute_shuffle_key():
            return False
        if getattr(sp, "replay_cone", "peers") != "self":
            return False
    return True


def shuffle_key_of(ops: Sequence[IngestOp]) -> Optional[str]:
    """The chain's shuffle routing key: the last ``shuffle_by`` op param."""
    key: Optional[str] = None
    for op in ops:
        if "shuffle_by" in op.params:
            key = op.params["shuffle_by"]
    return key


def stage_consumers(stage_plans: Sequence["StagePlan"], si: int,
                    downstream_only: bool = True) -> List[str]:
    """Names of the stages consuming stage ``si``'s output: the compiled
    ``edge_kinds`` consumer map when :func:`annotate_edges` ran, an
    ``upstream`` scan for hand-built plans that never did.  The runtime's
    exchange planner and its cohort-replay gate both need this — one
    definition, so they can never disagree about who consumes an edge.
    ``downstream_only=False`` scans the whole DAG (malformed hand-built
    plans may declare a backward edge; the replay gate must still see it)."""
    sp = stage_plans[si]
    if sp.edge_kinds:
        return list(sp.edge_kinds)
    pool = stage_plans[si + 1:] if downstream_only else stage_plans
    return [sq.name for sq in pool if sp.name in sq.upstream]


class IngestPlan:
    """The full ingestion plan: statements + stages, compiled to a stage DAG."""

    def __init__(self, name: str = "plan") -> None:
        self.name = name
        self.statements: Dict[str, Statement] = {}
        self.stages: Dict[str, Stage] = {}
        # streaming epoch-cut config (None = batch-only plan); set by the
        # declarative ``STREAM WITH EPOCHS(...)`` / ``with_epochs`` surface
        self.stream_config: Optional[Dict[str, Any]] = None
        # worker-pull source spec ({"kind": ..., **adapter kwargs}); set by
        # the declarative ``SOURCE kind(...)`` / ``with_source`` surface and
        # compiled to a SourceAdapter by the engines (ISSUE 6)
        self.source_spec: Optional[Dict[str, Any]] = None
        self._auto_sid = 0
        self._auto_stage = 0

    # ------------------------------------------------------------------ build
    def add_statement(self, ops: Sequence[IngestOp], kind: str = "select",
                      sid: Optional[str] = None, inputs: Sequence[str] = ()) -> str:
        if sid is None:
            self._auto_sid += 1
            sid = f"s{self._auto_sid}"
        self.statements[sid] = Statement(sid, list(ops), kind, list(inputs))
        return sid

    def create_stage(self, using: Sequence[str], where: Optional[Dict[str, Any]] = None,
                     name: Optional[str] = None) -> str:
        """CREATE STAGE name USING s1..sm WHERE l_op=v..."""
        return self._stage(name, list(using), [], where or {})

    def chain_stage(self, to: Sequence[str], using: Sequence[str],
                    where: Optional[Dict[str, Any]] = None,
                    name: Optional[str] = None) -> str:
        """CHAIN STAGE name TO a1..ak USING s1..sm WHERE ... (union-all of inputs)."""
        return self._stage(name, list(using), list(to), where or {})

    def _stage(self, name: Optional[str], using: List[str], to: List[str],
               where: Dict[str, Any]) -> str:
        if name is None:
            self._auto_stage += 1
            name = f"stage{self._auto_stage}"
        for sid in using:
            if sid not in self.statements:
                raise KeyError(f"stage {name}: unknown statement {sid!r}")
        for up in to:
            if up not in self.stages:
                raise KeyError(f"stage {name}: unknown upstream stage {up!r}")
        self.stages[name] = Stage(name, using, to, where)
        return name

    # ---------------------------------------------------------------- compile
    def compile(self) -> List[StagePlan]:
        """Flatten statements into per-stage operator chains, in topological
        order, with default materialization barriers marked (one block per op
        until the pipelining rule merges them)."""
        if not self.stages:
            # implicit single stage using all statements in insertion order
            self.create_stage(list(self.statements), name="main")
        order = self._topo_order()
        plans: List[StagePlan] = []
        for name in order:
            st = self.stages[name]
            ops: List[IngestOp] = []
            for sid in st.statements:
                ops.extend(self.statements[sid].ops)
            self._validate_chain(name, ops)
            blocks = [[i] for i in range(len(ops))]  # default: materialize everywhere
            sp = StagePlan(name, ops, list(st.upstream), dict(st.predicates), blocks)
            sp.commit_side = sp.compute_commit_side()
            sp.shuffle_key = sp.compute_shuffle_key()
            plans.append(sp)
        return annotate_edges(plans)

    @staticmethod
    def _validate_chain(stage: str, ops: Sequence[IngestOp]) -> None:
        """Paper Sec. IV-A: consecutive operators' ingest-data-item
        granularities must match (None = polymorphic)."""
        cur = None
        for op in ops:
            gin = op.granularity_in
            if gin is not None and cur is not None and gin != cur:
                raise ValueError(
                    f"stage {stage!r}: {type(op).__name__} consumes "
                    f"{gin.name} items but upstream produces {cur.name}")
            if op.granularity_out is not None:
                cur = op.granularity_out
            elif gin is not None:
                cur = gin

    def _topo_order(self) -> List[str]:
        seen: Dict[str, int] = {}
        order: List[str] = []

        def visit(n: str) -> None:
            state = seen.get(n, 0)
            if state == 1:
                raise ValueError(f"cycle through stage {n!r}")
            if state == 2:
                return
            seen[n] = 1
            for up in self.stages[n].upstream:
                visit(up)
            seen[n] = 2
            order.append(n)

        for n in self.stages:
            visit(n)
        return order

    # ------------------------------------------------------------------ intro
    def describe(self) -> str:
        lines = [f"IngestPlan {self.name!r}"]
        for sid, s in self.statements.items():
            lines.append(f"  {s!r}")
        for st in self.stages.values():
            lines.append(f"  {st!r}")
        return "\n".join(lines)

    def signature(self) -> Dict[str, Any]:
        """Serializable description (catalog stores params, not instances)."""
        return {
            "name": self.name,
            "stream": dict(self.stream_config) if self.stream_config else None,
            "source": dict(self.source_spec) if self.source_spec else None,
            "statements": {
                sid: {"kind": s.kind, "inputs": s.inputs,
                      "ops": [o.signature() for o in s.ops]}
                for sid, s in self.statements.items()
            },
            "stages": {
                st.name: {"using": st.statements, "to": st.upstream,
                          "where": {k: repr(v) for k, v in st.predicates.items()}}
                for st in self.stages.values()
            },
        }


def route_items(items: Iterable[IngestItem], predicates: Dict[str, Any]) -> List[IngestItem]:
    """Label-predicate routing into a stage (paper Sec. IV-B WHERE clause)."""
    return [it for it in items if matches(it, predicates)]


def failed_op_index(sp: StagePlan, block: Sequence[int], exc: Exception) -> int:
    """Recover which op in a multi-op pipeline block failed from the failure
    message (shared by the thread and process backends' retry machinery)."""
    msg = str(exc)
    for oi in block:
        if f"[{oi}]" in msg or sp.ops[oi].name in msg:
            return oi
    return block[0]


def serialize_plans(stage_plans: Sequence[StagePlan]) -> bytes:
    """Pickle a compiled stage DAG for shipping to a worker process.

    Operators reduce to (type, params) — see ``IngestOp.__reduce__`` — so a
    closure-valued param (a lambda predicate / map fn) cannot cross the
    boundary.  This wrapper names the offending operator instead of leaking a
    bare PicklingError: swap the closure for a spec the worker can rebuild
    (FilterOp tuple predicates, MapOp/ParserOp ``"module:attr"`` strings)."""
    try:
        return pickle.dumps(list(stage_plans), protocol=pickle.HIGHEST_PROTOCOL)
    except Exception as exc:
        for sp in stage_plans:
            for oi, op in enumerate(sp.ops):
                try:
                    pickle.dumps(op, protocol=pickle.HIGHEST_PROTOCOL)
                except Exception:
                    raise TypeError(
                        f"stage {sp.name!r} op [{oi}] ({type(op).__name__}) is "
                        f"not picklable for the process backend — replace "
                        f"closure params with importable specs (e.g. "
                        f"fn='pkg.module:attr' or a (field, op, value) "
                        f"predicate tuple); offending params: "
                        f"{sorted(op.params)}") from exc
        raise
