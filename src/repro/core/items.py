"""Ingest data items — the unit of data flowing through an ingestion plan.

The paper (Sec. III) defines *ingest data items* as raw files that may be broken
into smaller items (chunks, records, blocks) for fine-grained ingestion logic,
each carrying a list of *labels* denoting its lineage.

TPU-era adaptation (DESIGN.md §2): an item's payload is columnar — a dict of
equal-length numpy arrays — so operators are vectorized over whole chunks while
the item remains the paper's unit of control flow.  A RECORD-granularity item is
simply a chunk of length 1; a BLOCK is a device-ready, fixed-size packed array.
"""
from __future__ import annotations

import enum
import hashlib
import itertools
import os
import pickle
import threading
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Attributable shared-memory segments (ISSUE 8)
# ---------------------------------------------------------------------------
_SEG_SEQ = itertools.count()


def create_segment(size: int):
    """Create a shared-memory segment named ``psm_ing<pid>_<seq>``.

    The default anonymous ``psm_<random>`` names are unattributable: when
    the liveness monitor SIGKILLs a wedged worker (the only signal a
    SIGSTOP'd process cannot hold off), any segment it created but had not
    yet announced to the coordinator would leak forever.  Encoding the
    creating pid into the name lets the coordinator sweep a dead worker's
    leftovers by prefix (see ``ProcessNodeExecutor._sweep_segments``).
    The ``psm_`` prefix is kept so existing leak detectors still match."""
    from multiprocessing import shared_memory
    while True:
        name = f"psm_ing{os.getpid()}_{next(_SEG_SEQ)}"
        try:
            return shared_memory.SharedMemory(create=True, size=size,
                                              name=name)
        except FileExistsError:
            continue   # stale leftover from a recycled pid: try the next seq


def sweep_pid_segments(pid: int) -> int:
    """Unlink every ``/dev/shm`` segment a (dead) worker pid created —
    the coordinator-side safety net behind the attributable naming above.
    Returns how many segments were reclaimed.

    This glob only sees the *local* host's ``/dev/shm``: a worker running
    on another machine leaves its segments in that machine's tmpfs, where
    this sweep cannot reach.  Callers with remote workers must therefore
    not call this and pretend the sweep happened — see
    ``ProcessNodeExecutor._sweep_segments``, which counts the skip into
    the run report instead (ISSUE 9 satellite)."""
    import glob
    swept = 0
    for path in glob.glob(f"/dev/shm/psm_ing{pid}_*"):
        try:
            os.unlink(path)
            swept += 1
        except OSError:
            pass
    return swept


class Granularity(enum.IntEnum):
    """Granularity ladder of ingest data items (paper Sec. III)."""

    FILE = 0      # raw input file (bytes, unparsed)
    CHUNK = 1     # parsed slice of a file: columnar record batch
    RECORD = 2    # single record (chunk of length 1)
    BLOCK = 3     # packed, serialized block — the storage/consumption unit


# Columnar payload: field name -> equal-length np.ndarray.
Columns = Dict[str, np.ndarray]


def num_rows(columns: Columns) -> int:
    if not columns:
        return 0
    return len(next(iter(columns.values())))


def concat_columns(parts: List[Columns]) -> Columns:
    parts = [p for p in parts if p and num_rows(p) > 0]
    if not parts:
        return {}
    keys = list(parts[0].keys())
    return {k: np.concatenate([p[k] for p in parts]) for k in keys}


def take_rows(columns: Columns, idx: np.ndarray) -> Columns:
    return {k: v[idx] for k, v in columns.items()}


# ------------------------------------------------------------------ device I/O
def as_device_array(arr: np.ndarray) -> Any:
    """Map a host array into a JAX device array for a kernel-backed stage,
    without a copy where the backend allows (ISSUE 7).

    The shm item codec lands contiguous buffers, so on the CPU backend the
    DLPack import aliases the segment directly — decoded batch -> device
    array with zero copies.  Read-only views (``np.frombuffer`` of a bytes
    payload) and accelerator backends fall back to a ``device_put`` copy.
    JAX itself is imported lazily: the scalar tier never pays for it.
    """
    import jax
    a = np.ascontiguousarray(arr)
    try:
        return jax.dlpack.from_dlpack(a)
    except Exception:
        return jax.device_put(a)


def as_device_columns(columns: Columns) -> Dict[str, Any]:
    """``as_device_array`` over a decoded batch's columnar dict; non-array
    values (object columns) pass through untouched."""
    return {k: as_device_array(v) if isinstance(v, np.ndarray)
            and v.dtype != object else v
            for k, v in columns.items()}


@dataclass(frozen=True)
class Label:
    """One lineage entry: the operator that touched the item and the value it assigned."""

    op: str
    value: Any

    def __str__(self) -> str:  # used in lineage-encoded filenames
        return f"{self.op}-{self.value}"


@dataclass
class IngestItem:
    """A labelled ingest data item.

    ``data`` is payload whose type depends on granularity:
      FILE   -> bytes or str (path-like raw content)
      CHUNK  -> Columns (dict of equal-length numpy arrays)
      RECORD -> Columns with a single row
      BLOCK  -> SerializedBlock (see layouts/) or raw ndarray/bytes
    ``labels`` is the ordered lineage (paper Sec. VII: filename-encoded).
    """

    data: Any
    granularity: Granularity = Granularity.FILE
    labels: Tuple[Label, ...] = ()
    meta: Dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------------ labels
    def with_label(self, op: str, value: Any) -> "IngestItem":
        return replace(self, labels=self.labels + (Label(op, value),))

    def label_value(self, op: str, default: Any = None) -> Any:
        """Latest label value assigned by operator ``op`` (None if never touched)."""
        for lab in reversed(self.labels):
            if lab.op == op:
                return lab.value
        return default

    def label_values(self, op: str) -> List[Any]:
        return [l.value for l in self.labels if l.op == op]

    def lineage_name(self) -> str:
        """The paper's label-encoded physical file name: label1_label2_..._labeln."""
        return "_".join(str(l) for l in self.labels) or "raw"

    # ------------------------------------------------------------------- sizes
    def nbytes(self) -> int:
        d = self.data
        if isinstance(d, (bytes, bytearray, str)):
            return len(d)
        if isinstance(d, np.ndarray):
            return int(d.nbytes)
        if isinstance(d, dict):
            return int(sum(v.nbytes for v in d.values() if isinstance(v, np.ndarray)))
        if hasattr(d, "nbytes"):
            return int(d.nbytes)
        return 0

    def nrows(self) -> int:
        if isinstance(self.data, dict):
            return num_rows(self.data)
        if isinstance(self.data, np.ndarray):
            return len(self.data)
        return 1

    def checksum(self) -> str:
        h = hashlib.sha256()
        d = self.data
        if isinstance(d, (bytes, bytearray)):
            h.update(d)
        elif isinstance(d, str):
            h.update(d.encode())
        elif isinstance(d, np.ndarray):
            h.update(np.ascontiguousarray(d).tobytes())
        elif isinstance(d, dict):
            for k in sorted(d):
                h.update(k.encode())
                h.update(np.ascontiguousarray(d[k]).tobytes())
        elif hasattr(d, "tobytes"):
            h.update(d.tobytes())
        return h.hexdigest()[:16]


def items_nbytes(items: Sequence["IngestItem"]) -> int:
    """Total payload bytes of an item batch — the unit every dataflow byte
    counter (`stage_coordinator_bytes`, `shuffle_peer_bytes`,
    `stage_resident_bytes`) accounts in, so thread- and process-backend
    numbers are comparable.  Accepts a ColumnarBatch (same accounting:
    payload bytes only)."""
    if isinstance(items, ColumnarBatch):
        return items.nbytes
    return sum(it.nbytes() for it in items)


# ---------------------------------------------------------------------------
# Shared-memory item codec (DESIGN.md §6: the process backend's data plane)
# ---------------------------------------------------------------------------
# Item batches crossing a process boundary are encoded with pickle protocol 5:
# every C-contiguous numpy buffer is exported out-of-band and packed into ONE
# ``multiprocessing.shared_memory`` segment, so the receiving process rebuilds
# the arrays as zero-copy views over the mapped segment (numpy's protocol-5
# ``_frombuffer`` path).  Small batches (< ``shm_min_bytes`` of array payload)
# skip the segment and ship fully inline — a pipe write is cheaper than a
# segment create/map for tiny epochs.  Object-dtype columns and non-array
# payloads ride in the in-band pickle either way.
#
# Lifetime: each segment has exactly one producer and one consumer.  The
# producer copies buffers in, then ``ShmLease.detach()``-es (close + drop the
# resource-tracker registration so the consumer's unlink is authoritative);
# the consumer maps it, uses the views, and ``release()``-s (close + unlink)
# when the decoded items are no longer referenced.

SHM_MIN_BYTES = 64 << 10   # below this, inline pickle beats a segment


class ShmLease:
    """Owns one shared-memory segment end-to-end of a transfer leg.

    A lease starts with one holder; ``share()`` adds one.  ``release()``
    drops a holder and only the *last* release unmaps/unlinks the segment —
    the multi-consumer lifetime rule of the worker-side partition exchange
    (DESIGN.md §4): a worker's resident partition may alias the segment a
    stage's input rode in on, so the stage job and the resident buffer each
    hold a share and the segment dies deterministically when the final
    consumer lets go."""

    def __init__(self, shm: Any) -> None:
        self._shm = shm
        self._refs = 1
        self._lock = threading.Lock()

    @property
    def name(self) -> Optional[str]:
        return self._shm.name if self._shm is not None else None

    @property
    def holders(self) -> int:
        with self._lock:
            return self._refs if self._shm is not None else 0

    def share(self) -> "ShmLease":
        """Add a holder (returns self): the segment now needs one more
        ``release()`` before it is unmapped and unlinked."""
        with self._lock:
            if self._shm is None:
                raise ValueError("cannot share a released/detached lease")
            self._refs += 1
        return self

    def detach(self) -> None:
        """Producer side: unmap and disown (the consumer will unlink)."""
        shm, self._shm = self._shm, None
        if shm is None:
            return
        try:
            from multiprocessing import resource_tracker
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:
            pass
        shm.close()

    def release(self, unlink: bool = True) -> None:
        """Consumer side: drop one holder; the last release unmaps and (by
        default) destroys the segment."""
        with self._lock:
            if self._shm is not None:
                self._refs -= 1
                if self._refs > 0:
                    return
            shm, self._shm = self._shm, None
        if shm is None:
            return
        try:
            shm.close()
        except BufferError:
            # a view still points into the mapping: the unlink below frees
            # the name now and the memory when the last view dies
            pass
        finally:
            if unlink:
                try:
                    shm.unlink()
                except FileNotFoundError:
                    pass


def encode_items(items: Sequence["IngestItem"],
                 shm_min_bytes: int = SHM_MIN_BYTES
                 ) -> Tuple[Dict[str, Any], Optional[ShmLease]]:
    """Encode an item batch for a process hop.

    Returns ``(payload, lease)``; ``lease`` is None for the inline-pickle
    fallback, else the producer must ``detach()`` it once the payload has been
    handed to the transport.  ``payload`` is a plain picklable dict.

    Columnar fast path (ISSUE 10): a :class:`ColumnarBatch` writes its one
    contiguous column buffer straight into the segment — no per-item
    pickling; ``decode_items`` hands back the batch.
    """
    if isinstance(items, ColumnarBatch):
        header = pickle.dumps(items.header(), protocol=5)
        pay = np.ascontiguousarray(items.payload)
        if pay.nbytes < shm_min_bytes:
            return {"kind": "pickle", "columnar": True, "meta": header,
                    "buffers": [bytearray(memoryview(pay).cast("B"))]}, None
        shm = create_segment(max(pay.nbytes, 1))
        shm.buf[:pay.nbytes] = memoryview(pay).cast("B")
        return {"kind": "shm", "columnar": True, "meta": header,
                "shm": shm.name, "payload_nbytes": pay.nbytes}, ShmLease(shm)
    buffers: List[pickle.PickleBuffer] = []
    meta = pickle.dumps(list(items), protocol=5,
                        buffer_callback=buffers.append)
    views = [b.raw() for b in buffers]
    total = sum(v.nbytes for v in views)
    if total < shm_min_bytes:
        # inline fast path, one pickle pass: ship the out-of-band buffers
        # next to the meta stream (bytearray: reconstructed arrays must stay
        # writable, like the shm path's views)
        inline = [bytearray(v) for v in views]
        for b in buffers:
            b.release()
        return {"kind": "pickle", "meta": meta, "buffers": inline}, None
    shm = create_segment(max(total, 1))
    offsets: List[Tuple[int, int]] = []
    off = 0
    for v in views:
        shm.buf[off:off + v.nbytes] = v.cast("B")
        offsets.append((off, v.nbytes))
        off += v.nbytes
    for b in buffers:
        b.release()
    return {"kind": "shm", "meta": meta, "shm": shm.name,
            "offsets": offsets}, ShmLease(shm)


def decode_items(payload: Dict[str, Any], copy: bool = False
                 ) -> Tuple[List["IngestItem"], Optional[ShmLease]]:
    """Decode a batch produced by :func:`encode_items`.

    With ``copy=False`` the arrays are zero-copy views over the mapped
    segment: the caller must hold the returned lease alive while the items
    are in use and ``release()`` it afterwards.  With ``copy=True`` the
    arrays are materialized and the segment is released (and unlinked)
    before returning — the safe mode when decoded items outlive the call.

    A payload carrying ``columnar=True`` (see the ``encode_items`` fast
    path) decodes to the :class:`ColumnarBatch` itself instead of an item
    list — same ``(value, lease)`` contract.
    """
    if payload.get("columnar"):
        header = pickle.loads(payload["meta"])
        if payload["kind"] == "pickle":
            pay = np.frombuffer(payload["buffers"][0], np.uint8)
            return ColumnarBatch.from_header(header, pay), None
        from multiprocessing import shared_memory
        shm = shared_memory.SharedMemory(name=payload["shm"])
        lease = ShmLease(shm)
        pay = np.frombuffer(shm.buf, np.uint8,
                            count=payload["payload_nbytes"])
        batch = ColumnarBatch.from_header(header, pay)
        if not copy:
            return batch, lease
        batch.payload = pay.copy()
        del pay
        lease.release()
        return batch, None
    if payload["kind"] == "pickle":
        return pickle.loads(payload["meta"],
                            buffers=payload.get("buffers") or ()), None
    from multiprocessing import shared_memory
    shm = shared_memory.SharedMemory(name=payload["shm"])
    lease = ShmLease(shm)
    base = memoryview(shm.buf)
    items = pickle.loads(payload["meta"],
                         buffers=[base[o:o + l] for o, l in payload["offsets"]])
    if not copy:
        return items, lease
    # comprehension scope: no loop variable may outlive the release below,
    # or the segment unmaps with exported views (BufferError at GC)
    out = [_materialize_item(it) for it in items]
    del items, base
    lease.release()
    return out, None


def _materialize_item(item: "IngestItem") -> "IngestItem":
    """Deep-copy any array payload out of a shared-memory view."""
    d = item.data
    if isinstance(d, np.ndarray):
        d = d.copy()
    elif isinstance(d, dict):
        d = {k: (v.copy() if isinstance(v, np.ndarray) else v)
             for k, v in d.items()}
    else:
        return item
    return replace(item, data=d)


# ---------------------------------------------------------------------------
# Columnar batch plane (ISSUE 10): the unit that crosses stage edges
# ---------------------------------------------------------------------------
# A ColumnarBatch is one contiguous uint8 payload buffer + an int64 offsets
# vector + struct-of-arrays label/meta columns.  It represents a batch of
# IngestItems whose payload type and label shape are uniform — the common case
# between two batch-mode pipeline blocks — without any per-item pickling.
# ``from_items`` returns None for anything non-uniform: the scalar
# item-at-a-time path stays the fallback and correctness oracle everywhere.
#
# Payload kinds:
#   "bytes"   — raw byte payloads; ``offsets`` are byte offsets per item
#   "array"   — same-dtype ndarrays; byte offsets + per-item shapes in aux
#   "columns" — dict-of-arrays chunks sharing a schema; payload is
#               column-major (one region per field, regions in schema order)
#               and ``offsets`` are ROW offsets per item
#   "block"   — SerializedBlock payload bytes; layouts/headers in aux


def _label_column(vals: List[Any]) -> np.ndarray:
    """One label position across the batch as a column.  Tight numpy dtypes
    only when every value is exactly the same scalar type (``np.asarray``
    would silently stringify mixed lists and overflow huge ints); everything
    else rides an object column and round-trips through pickle faithfully."""
    t0 = type(vals[0])
    if t0 in (int, bool, float, str) and all(type(v) is t0 for v in vals):
        try:
            col = np.asarray(vals)
            if col.shape == (len(vals),) and col.dtype.kind in "biufU":
                return col
        except (OverflowError, ValueError):
            pass
    col = np.empty(len(vals), dtype=object)
    col[:] = vals
    return col


def _label_at(col: np.ndarray, i: int) -> Any:
    v = col[i]
    return v.item() if isinstance(v, np.generic) else v


class ColumnarBatch:
    """A batch of uniform IngestItems as column buffers (ISSUE 10)."""

    __slots__ = ("payload", "offsets", "kind", "aux",
                 "label_ops", "label_cols", "grans", "metas")

    def __init__(self, payload: np.ndarray, offsets: np.ndarray, kind: str,
                 aux: Dict[str, Any], label_ops: Tuple[str, ...],
                 label_cols: Tuple[np.ndarray, ...], grans: np.ndarray,
                 metas: Optional[List[Dict[str, Any]]]) -> None:
        self.payload = payload        # 1-D uint8, may view a shm segment
        self.offsets = offsets        # int64, len == count + 1
        self.kind = kind
        self.aux = aux
        self.label_ops = label_ops    # uniform per-item label op sequence
        self.label_cols = label_cols  # one value column per label position
        self.grans = grans            # int8 Granularity codes
        self.metas = metas            # None == every item's meta was empty

    def __len__(self) -> int:
        return len(self.grans)

    @property
    def nbytes(self) -> int:
        """Payload bytes only — exactly ``sum(it.nbytes())`` of the items, so
        manifest byte accounting is identical columnar on/off."""
        return int(self.payload.nbytes)

    # -------------------------------------------------------------- building
    @classmethod
    def from_items(cls, items: Sequence["IngestItem"]
                   ) -> Optional["ColumnarBatch"]:
        """Column-pack a batch; None when the batch is not uniform enough
        (mixed payload types/dtypes/schemas or divergent label shapes) — the
        caller falls back to the scalar path silently."""
        items = list(items)
        n = len(items)
        if n == 0:
            return cls(np.empty(0, np.uint8), np.zeros(1, np.int64), "bytes",
                       {}, (), (), np.empty(0, np.int8), None)
        try:
            ops0 = tuple(l.op for l in items[0].labels)
            for it in items[1:]:
                if tuple(l.op for l in it.labels) != ops0:
                    return None
            d0 = items[0].data
            if type(d0) is bytes:
                packed = cls._pack_bytes(items)
            elif type(d0) is np.ndarray:
                packed = cls._pack_arrays(items)
            elif type(d0) is dict:
                packed = cls._pack_columns(items)
            else:
                from ..layouts.blocks import SerializedBlock
                if type(d0) is SerializedBlock:
                    packed = cls._pack_blocks(items)
                else:
                    return None
            if packed is None:
                return None
            kind, payload, offsets, aux = packed
            label_cols = tuple(
                _label_column([it.labels[j].value for it in items])
                for j in range(len(ops0)))
            grans = np.fromiter((int(it.granularity) for it in items),
                                np.int8, n)
            metas = (None if all(not it.meta for it in items)
                     else [dict(it.meta) for it in items])
            return cls(payload, offsets, kind, aux, ops0, label_cols,
                       grans, metas)
        except Exception:
            return None   # fallback is sacred: never fail a uniformity probe

    @staticmethod
    def _byte_offsets(lens: List[int]) -> np.ndarray:
        offsets = np.zeros(len(lens) + 1, np.int64)
        np.cumsum(np.asarray(lens, np.int64), out=offsets[1:])
        return offsets

    @classmethod
    def _pack_bytes(cls, items):
        for it in items:
            if type(it.data) is not bytes:
                return None
        offsets = cls._byte_offsets([len(it.data) for it in items])
        payload = np.empty(int(offsets[-1]), np.uint8)
        for it, o in zip(items, offsets[:-1]):
            if it.data:
                payload[int(o):int(o) + len(it.data)] = \
                    np.frombuffer(it.data, np.uint8)
        return "bytes", payload, offsets, {}

    @classmethod
    def _pack_arrays(cls, items):
        d0 = items[0].data
        if d0.dtype.kind not in "biufSU":
            return None
        arrs = []
        for it in items:
            if type(it.data) is not np.ndarray or it.data.dtype != d0.dtype:
                return None
            arrs.append(np.ascontiguousarray(it.data))
        offsets = cls._byte_offsets([a.nbytes for a in arrs])
        payload = np.empty(int(offsets[-1]), np.uint8)
        for a, o in zip(arrs, offsets[:-1]):
            if a.nbytes:
                payload[int(o):int(o) + a.nbytes] = \
                    a.reshape(-1).view(np.uint8)
        return "array", payload, offsets, {
            "dtype": d0.dtype.str, "shapes": tuple(a.shape for a in arrs)}

    @classmethod
    def _pack_columns(cls, items):
        d0 = items[0].data
        keys = tuple(d0.keys())
        schema = []
        for k in keys:
            a0 = d0[k]
            if type(a0) is not np.ndarray or a0.dtype.kind not in "biufSU":
                return None
            schema.append((k, a0.dtype.str, a0.shape[1:]))
        rows = []
        for it in items:
            if type(it.data) is not dict or tuple(it.data.keys()) != keys:
                return None
            r = None
            for k, dstr, ts in schema:
                a = it.data[k]
                if (type(a) is not np.ndarray or a.dtype.str != dstr
                        or a.shape[1:] != ts):
                    return None
                if r is None:
                    r = a.shape[0]
                elif a.shape[0] != r:
                    return None
            rows.append(0 if r is None else r)
        offsets = cls._byte_offsets(rows)
        total_rows = int(offsets[-1])
        sizes = [np.dtype(dstr).itemsize * int(np.prod(ts, dtype=np.int64))
                 for _, dstr, ts in schema]
        payload = np.empty(total_rows * sum(sizes), np.uint8)
        pos = 0
        for (k, dstr, ts), rowbytes in zip(schema, sizes):
            size = total_rows * rowbytes
            region = payload[pos:pos + size].view(np.dtype(dstr)) \
                .reshape((total_rows,) + ts)
            r = 0
            for it in items:
                a = it.data[k]
                region[r:r + a.shape[0]] = a
                r += a.shape[0]
            pos += size
        return "columns", payload, offsets, {"schema": tuple(schema)}

    @classmethod
    def _pack_blocks(cls, items):
        from ..layouts.blocks import SerializedBlock
        for it in items:
            if type(it.data) is not SerializedBlock:
                return None
        offsets = cls._byte_offsets([len(it.data.payload) for it in items])
        payload = np.empty(int(offsets[-1]), np.uint8)
        for it, o in zip(items, offsets[:-1]):
            if it.data.payload:
                payload[int(o):int(o) + len(it.data.payload)] = \
                    np.frombuffer(it.data.payload, np.uint8)
        return "block", payload, offsets, {
            "layouts": tuple(it.data.layout for it in items),
            "headers": tuple(dict(it.data.header) for it in items)}

    # ------------------------------------------------------------- accessors
    def columns(self) -> Columns:
        """The whole batch's fields as full-length column views over the
        payload buffer — zero-copy, and the direct feed for
        :func:`as_device_columns` (ingest -> accelerator without a gather)."""
        if self.kind != "columns":
            raise ValueError(f"columns() on kind {self.kind!r}")
        total_rows = int(self.offsets[-1])
        out: Columns = {}
        pos = 0
        for k, dstr, ts in self.aux["schema"]:
            dt = np.dtype(dstr)
            size = total_rows * dt.itemsize * int(np.prod(ts, dtype=np.int64))
            out[k] = self.payload[pos:pos + size].view(dt) \
                .reshape((total_rows,) + tuple(ts))
            pos += size
        return out

    def device_columns(self) -> Dict[str, Any]:
        """Device arrays straight from the (possibly shm-backed) column
        buffers — :func:`as_device_array` DLPack-imports each field view."""
        return as_device_columns(self.columns())

    def label_col(self, op: str) -> Optional[np.ndarray]:
        """Value column of the LAST label written by ``op`` (mirrors
        ``IngestItem.label_value``'s last-wins scan), or None."""
        for j in range(len(self.label_ops) - 1, -1, -1):
            if self.label_ops[j] == op:
                return self.label_cols[j]
        return None

    # ----------------------------------------------------------- round trips
    def to_items(self) -> List["IngestItem"]:
        """Rebuild the IngestItems.  Array/columns payloads come back as
        views over the batch payload — the caller keeps the batch (or its
        shm lease) alive while the items are in use, exactly the
        ``decode_items(copy=False)`` contract."""
        n = len(self)
        labels = [tuple(Label(op, _label_at(col, i))
                        for op, col in zip(self.label_ops, self.label_cols))
                  for i in range(n)]
        metas = self.metas or [{} for _ in range(n)]
        pay, off = self.payload, self.offsets
        datas: List[Any]
        if self.kind == "bytes":
            datas = [pay[int(off[i]):int(off[i + 1])].tobytes()
                     for i in range(n)]
        elif self.kind == "array":
            dt = np.dtype(self.aux["dtype"])
            datas = [pay[int(off[i]):int(off[i + 1])].view(dt)
                     .reshape(self.aux["shapes"][i]) for i in range(n)]
        elif self.kind == "columns":
            cols = self.columns()
            datas = [{k: v[int(off[i]):int(off[i + 1])]
                      for k, v in cols.items()} for i in range(n)]
        else:
            from ..layouts.blocks import SerializedBlock
            datas = [SerializedBlock(self.aux["layouts"][i],
                                     pay[int(off[i]):int(off[i + 1])]
                                     .tobytes(),
                                     dict(self.aux["headers"][i]))
                     for i in range(n)]
        return [IngestItem(datas[i], Granularity(int(self.grans[i])),
                           labels[i], dict(metas[i])) for i in range(n)]

    def select(self, idx: np.ndarray) -> "ColumnarBatch":
        """Order-preserving item selection into a fresh, self-owned batch
        (the vectorized-partition building block)."""
        idx = np.asarray(idx, np.int64)
        n2 = len(idx)
        off = self.offsets
        lens = off[idx + 1] - off[idx] if n2 else np.empty(0, np.int64)
        new_off = np.zeros(n2 + 1, np.int64)
        np.cumsum(lens, out=new_off[1:])
        label_cols = tuple(col[idx] for col in self.label_cols)
        grans = self.grans[idx]
        metas = (None if self.metas is None
                 else [dict(self.metas[int(i)]) for i in idx])
        aux = self.aux
        if self.kind == "columns":
            if n2:
                row_idx = np.concatenate(
                    [np.arange(int(off[i]), int(off[i + 1])) for i in idx])
            else:
                row_idx = np.empty(0, np.int64)
            cols = self.columns()
            total2 = len(row_idx)
            sizes = [np.dtype(d).itemsize * int(np.prod(ts, dtype=np.int64))
                     for _, d, ts in aux["schema"]]
            payload = np.empty(total2 * sum(sizes), np.uint8)
            pos = 0
            for (k, dstr, ts), rowbytes in zip(aux["schema"], sizes):
                size = total2 * rowbytes
                region = payload[pos:pos + size].view(np.dtype(dstr)) \
                    .reshape((total2,) + tuple(ts))
                region[:] = cols[k][row_idx]
                pos += size
        else:
            if n2:
                payload = np.concatenate(
                    [self.payload[int(off[i]):int(off[i + 1])] for i in idx])
            else:
                payload = np.empty(0, np.uint8)
            if self.kind == "array":
                aux = {"dtype": aux["dtype"],
                       "shapes": tuple(aux["shapes"][int(i)] for i in idx)}
            elif self.kind == "block":
                aux = {"layouts": tuple(aux["layouts"][int(i)] for i in idx),
                       "headers": tuple(dict(aux["headers"][int(i)])
                                        for i in idx)}
        return ColumnarBatch(payload, new_off, self.kind, aux,
                             self.label_ops, label_cols, grans, metas)

    # ----------------------------------------------------------------- codec
    def header(self) -> Dict[str, Any]:
        """Everything but the payload buffer, as one picklable dict."""
        return {"kind": self.kind, "offsets": self.offsets, "aux": self.aux,
                "label_ops": self.label_ops, "label_cols": self.label_cols,
                "grans": self.grans, "metas": self.metas,
                "nbytes": self.nbytes}

    @classmethod
    def from_header(cls, header: Dict[str, Any], payload: np.ndarray
                    ) -> "ColumnarBatch":
        if payload.nbytes != header["nbytes"]:
            raise ValueError(
                f"columnar payload is {payload.nbytes} bytes, header "
                f"says {header['nbytes']}")
        return cls(payload, header["offsets"], header["kind"], header["aux"],
                   header["label_ops"], header["label_cols"],
                   header["grans"], header["metas"])


def matches(item: IngestItem, predicates: Dict[str, Any]) -> bool:
    """Label-predicate match used by the dataflow stages (paper Sec. IV-B).

    ``predicates`` maps operator name -> required label value; a predicate
    value may also be a callable for inequality predicates such as the
    paper's ``l_parser > now-1``.
    """
    for op, want in predicates.items():
        have = item.label_value(op)
        if callable(want):
            if not want(have):
                return False
        elif have != want:
            return False
    return True
