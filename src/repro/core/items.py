"""Ingest data items — the unit of data flowing through an ingestion plan.

The paper (Sec. III) defines *ingest data items* as raw files that may be broken
into smaller items (chunks, records, blocks) for fine-grained ingestion logic,
each carrying a list of *labels* denoting its lineage.

TPU-era adaptation (DESIGN.md §2): an item's payload is columnar — a dict of
equal-length numpy arrays — so operators are vectorized over whole chunks while
the item remains the paper's unit of control flow.  A RECORD-granularity item is
simply a chunk of length 1; a BLOCK is a device-ready, fixed-size packed array.
"""
from __future__ import annotations

import enum
import hashlib
import itertools
import os
import pickle
import threading
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Attributable shared-memory segments (ISSUE 8)
# ---------------------------------------------------------------------------
_SEG_SEQ = itertools.count()


def create_segment(size: int):
    """Create a shared-memory segment named ``psm_ing<pid>_<seq>``.

    The default anonymous ``psm_<random>`` names are unattributable: when
    the liveness monitor SIGKILLs a wedged worker (the only signal a
    SIGSTOP'd process cannot hold off), any segment it created but had not
    yet announced to the coordinator would leak forever.  Encoding the
    creating pid into the name lets the coordinator sweep a dead worker's
    leftovers by prefix (see ``ProcessNodeExecutor._sweep_segments``).
    The ``psm_`` prefix is kept so existing leak detectors still match."""
    from multiprocessing import shared_memory
    while True:
        name = f"psm_ing{os.getpid()}_{next(_SEG_SEQ)}"
        try:
            return shared_memory.SharedMemory(create=True, size=size,
                                              name=name)
        except FileExistsError:
            continue   # stale leftover from a recycled pid: try the next seq


def sweep_pid_segments(pid: int) -> int:
    """Unlink every ``/dev/shm`` segment a (dead) worker pid created —
    the coordinator-side safety net behind the attributable naming above.
    Returns how many segments were reclaimed.

    This glob only sees the *local* host's ``/dev/shm``: a worker running
    on another machine leaves its segments in that machine's tmpfs, where
    this sweep cannot reach.  Callers with remote workers must therefore
    not call this and pretend the sweep happened — see
    ``ProcessNodeExecutor._sweep_segments``, which counts the skip into
    the run report instead (ISSUE 9 satellite)."""
    import glob
    swept = 0
    for path in glob.glob(f"/dev/shm/psm_ing{pid}_*"):
        try:
            os.unlink(path)
            swept += 1
        except OSError:
            pass
    return swept


class Granularity(enum.IntEnum):
    """Granularity ladder of ingest data items (paper Sec. III)."""

    FILE = 0      # raw input file (bytes, unparsed)
    CHUNK = 1     # parsed slice of a file: columnar record batch
    RECORD = 2    # single record (chunk of length 1)
    BLOCK = 3     # packed, serialized block — the storage/consumption unit


# Columnar payload: field name -> equal-length np.ndarray.
Columns = Dict[str, np.ndarray]


def num_rows(columns: Columns) -> int:
    if not columns:
        return 0
    return len(next(iter(columns.values())))


def concat_columns(parts: List[Columns]) -> Columns:
    parts = [p for p in parts if p and num_rows(p) > 0]
    if not parts:
        return {}
    keys = list(parts[0].keys())
    return {k: np.concatenate([p[k] for p in parts]) for k in keys}


def take_rows(columns: Columns, idx: np.ndarray) -> Columns:
    return {k: v[idx] for k, v in columns.items()}


# ------------------------------------------------------------------ device I/O
def as_device_array(arr: np.ndarray) -> Any:
    """Map a host array into a JAX device array for a kernel-backed stage,
    without a copy where the backend allows (ISSUE 7).

    The shm item codec lands contiguous buffers, so on the CPU backend the
    DLPack import aliases the segment directly — decoded batch -> device
    array with zero copies.  Read-only views (``np.frombuffer`` of a bytes
    payload) and accelerator backends fall back to a ``device_put`` copy.
    JAX itself is imported lazily: the scalar tier never pays for it.
    """
    import jax
    a = np.ascontiguousarray(arr)
    try:
        return jax.dlpack.from_dlpack(a)
    except Exception:
        return jax.device_put(a)


def as_device_columns(columns: Columns) -> Dict[str, Any]:
    """``as_device_array`` over a decoded batch's columnar dict; non-array
    values (object columns) pass through untouched."""
    return {k: as_device_array(v) if isinstance(v, np.ndarray)
            and v.dtype != object else v
            for k, v in columns.items()}


@dataclass(frozen=True)
class Label:
    """One lineage entry: the operator that touched the item and the value it assigned."""

    op: str
    value: Any

    def __str__(self) -> str:  # used in lineage-encoded filenames
        return f"{self.op}-{self.value}"


@dataclass
class IngestItem:
    """A labelled ingest data item.

    ``data`` is payload whose type depends on granularity:
      FILE   -> bytes or str (path-like raw content)
      CHUNK  -> Columns (dict of equal-length numpy arrays)
      RECORD -> Columns with a single row
      BLOCK  -> SerializedBlock (see layouts/) or raw ndarray/bytes
    ``labels`` is the ordered lineage (paper Sec. VII: filename-encoded).
    """

    data: Any
    granularity: Granularity = Granularity.FILE
    labels: Tuple[Label, ...] = ()
    meta: Dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------------ labels
    def with_label(self, op: str, value: Any) -> "IngestItem":
        return replace(self, labels=self.labels + (Label(op, value),))

    def label_value(self, op: str, default: Any = None) -> Any:
        """Latest label value assigned by operator ``op`` (None if never touched)."""
        for lab in reversed(self.labels):
            if lab.op == op:
                return lab.value
        return default

    def label_values(self, op: str) -> List[Any]:
        return [l.value for l in self.labels if l.op == op]

    def lineage_name(self) -> str:
        """The paper's label-encoded physical file name: label1_label2_..._labeln."""
        return "_".join(str(l) for l in self.labels) or "raw"

    # ------------------------------------------------------------------- sizes
    def nbytes(self) -> int:
        d = self.data
        if isinstance(d, (bytes, bytearray, str)):
            return len(d)
        if isinstance(d, np.ndarray):
            return int(d.nbytes)
        if isinstance(d, dict):
            return int(sum(v.nbytes for v in d.values() if isinstance(v, np.ndarray)))
        if hasattr(d, "nbytes"):
            return int(d.nbytes)
        return 0

    def nrows(self) -> int:
        if isinstance(self.data, dict):
            return num_rows(self.data)
        if isinstance(self.data, np.ndarray):
            return len(self.data)
        return 1

    def checksum(self) -> str:
        h = hashlib.sha256()
        d = self.data
        if isinstance(d, (bytes, bytearray)):
            h.update(d)
        elif isinstance(d, str):
            h.update(d.encode())
        elif isinstance(d, np.ndarray):
            h.update(np.ascontiguousarray(d).tobytes())
        elif isinstance(d, dict):
            for k in sorted(d):
                h.update(k.encode())
                h.update(np.ascontiguousarray(d[k]).tobytes())
        elif hasattr(d, "tobytes"):
            h.update(d.tobytes())
        return h.hexdigest()[:16]


def items_nbytes(items: Sequence["IngestItem"]) -> int:
    """Total payload bytes of an item batch — the unit every dataflow byte
    counter (`stage_coordinator_bytes`, `shuffle_peer_bytes`,
    `stage_resident_bytes`) accounts in, so thread- and process-backend
    numbers are comparable."""
    return sum(it.nbytes() for it in items)


# ---------------------------------------------------------------------------
# Shared-memory item codec (DESIGN.md §6: the process backend's data plane)
# ---------------------------------------------------------------------------
# Item batches crossing a process boundary are encoded with pickle protocol 5:
# every C-contiguous numpy buffer is exported out-of-band and packed into ONE
# ``multiprocessing.shared_memory`` segment, so the receiving process rebuilds
# the arrays as zero-copy views over the mapped segment (numpy's protocol-5
# ``_frombuffer`` path).  Small batches (< ``shm_min_bytes`` of array payload)
# skip the segment and ship fully inline — a pipe write is cheaper than a
# segment create/map for tiny epochs.  Object-dtype columns and non-array
# payloads ride in the in-band pickle either way.
#
# Lifetime: each segment has exactly one producer and one consumer.  The
# producer copies buffers in, then ``ShmLease.detach()``-es (close + drop the
# resource-tracker registration so the consumer's unlink is authoritative);
# the consumer maps it, uses the views, and ``release()``-s (close + unlink)
# when the decoded items are no longer referenced.

SHM_MIN_BYTES = 64 << 10   # below this, inline pickle beats a segment


class ShmLease:
    """Owns one shared-memory segment end-to-end of a transfer leg.

    A lease starts with one holder; ``share()`` adds one.  ``release()``
    drops a holder and only the *last* release unmaps/unlinks the segment —
    the multi-consumer lifetime rule of the worker-side partition exchange
    (DESIGN.md §4): a worker's resident partition may alias the segment a
    stage's input rode in on, so the stage job and the resident buffer each
    hold a share and the segment dies deterministically when the final
    consumer lets go."""

    def __init__(self, shm: Any) -> None:
        self._shm = shm
        self._refs = 1
        self._lock = threading.Lock()

    @property
    def name(self) -> Optional[str]:
        return self._shm.name if self._shm is not None else None

    @property
    def holders(self) -> int:
        with self._lock:
            return self._refs if self._shm is not None else 0

    def share(self) -> "ShmLease":
        """Add a holder (returns self): the segment now needs one more
        ``release()`` before it is unmapped and unlinked."""
        with self._lock:
            if self._shm is None:
                raise ValueError("cannot share a released/detached lease")
            self._refs += 1
        return self

    def detach(self) -> None:
        """Producer side: unmap and disown (the consumer will unlink)."""
        shm, self._shm = self._shm, None
        if shm is None:
            return
        try:
            from multiprocessing import resource_tracker
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:
            pass
        shm.close()

    def release(self, unlink: bool = True) -> None:
        """Consumer side: drop one holder; the last release unmaps and (by
        default) destroys the segment."""
        with self._lock:
            if self._shm is not None:
                self._refs -= 1
                if self._refs > 0:
                    return
            shm, self._shm = self._shm, None
        if shm is None:
            return
        try:
            shm.close()
        except BufferError:
            # a view still points into the mapping: the unlink below frees
            # the name now and the memory when the last view dies
            pass
        finally:
            if unlink:
                try:
                    shm.unlink()
                except FileNotFoundError:
                    pass


def encode_items(items: Sequence["IngestItem"],
                 shm_min_bytes: int = SHM_MIN_BYTES
                 ) -> Tuple[Dict[str, Any], Optional[ShmLease]]:
    """Encode an item batch for a process hop.

    Returns ``(payload, lease)``; ``lease`` is None for the inline-pickle
    fallback, else the producer must ``detach()`` it once the payload has been
    handed to the transport.  ``payload`` is a plain picklable dict.
    """
    buffers: List[pickle.PickleBuffer] = []
    meta = pickle.dumps(list(items), protocol=5,
                        buffer_callback=buffers.append)
    views = [b.raw() for b in buffers]
    total = sum(v.nbytes for v in views)
    if total < shm_min_bytes:
        # inline fast path, one pickle pass: ship the out-of-band buffers
        # next to the meta stream (bytearray: reconstructed arrays must stay
        # writable, like the shm path's views)
        inline = [bytearray(v) for v in views]
        for b in buffers:
            b.release()
        return {"kind": "pickle", "meta": meta, "buffers": inline}, None
    shm = create_segment(max(total, 1))
    offsets: List[Tuple[int, int]] = []
    off = 0
    for v in views:
        shm.buf[off:off + v.nbytes] = v.cast("B")
        offsets.append((off, v.nbytes))
        off += v.nbytes
    for b in buffers:
        b.release()
    return {"kind": "shm", "meta": meta, "shm": shm.name,
            "offsets": offsets}, ShmLease(shm)


def decode_items(payload: Dict[str, Any], copy: bool = False
                 ) -> Tuple[List["IngestItem"], Optional[ShmLease]]:
    """Decode a batch produced by :func:`encode_items`.

    With ``copy=False`` the arrays are zero-copy views over the mapped
    segment: the caller must hold the returned lease alive while the items
    are in use and ``release()`` it afterwards.  With ``copy=True`` the
    arrays are materialized and the segment is released (and unlinked)
    before returning — the safe mode when decoded items outlive the call.
    """
    if payload["kind"] == "pickle":
        return pickle.loads(payload["meta"],
                            buffers=payload.get("buffers") or ()), None
    from multiprocessing import shared_memory
    shm = shared_memory.SharedMemory(name=payload["shm"])
    lease = ShmLease(shm)
    base = memoryview(shm.buf)
    items = pickle.loads(payload["meta"],
                         buffers=[base[o:o + l] for o, l in payload["offsets"]])
    if not copy:
        return items, lease
    # comprehension scope: no loop variable may outlive the release below,
    # or the segment unmaps with exported views (BufferError at GC)
    out = [_materialize_item(it) for it in items]
    del items, base
    lease.release()
    return out, None


def _materialize_item(item: "IngestItem") -> "IngestItem":
    """Deep-copy any array payload out of a shared-memory view."""
    d = item.data
    if isinstance(d, np.ndarray):
        d = d.copy()
    elif isinstance(d, dict):
        d = {k: (v.copy() if isinstance(v, np.ndarray) else v)
             for k, v in d.items()}
    else:
        return item
    return replace(item, data=d)


def matches(item: IngestItem, predicates: Dict[str, Any]) -> bool:
    """Label-predicate match used by the dataflow stages (paper Sec. IV-B).

    ``predicates`` maps operator name -> required label value; a predicate
    value may also be a callable for inequality predicates such as the
    paper's ``l_parser > now-1``.
    """
    for op, want in predicates.items():
        have = item.label_value(op)
        if callable(want):
            if not want(have):
                return False
        elif have != want:
            return False
    return True
