"""Ingest data items — the unit of data flowing through an ingestion plan.

The paper (Sec. III) defines *ingest data items* as raw files that may be broken
into smaller items (chunks, records, blocks) for fine-grained ingestion logic,
each carrying a list of *labels* denoting its lineage.

TPU-era adaptation (DESIGN.md §2): an item's payload is columnar — a dict of
equal-length numpy arrays — so operators are vectorized over whole chunks while
the item remains the paper's unit of control flow.  A RECORD-granularity item is
simply a chunk of length 1; a BLOCK is a device-ready, fixed-size packed array.
"""
from __future__ import annotations

import enum
import hashlib
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


class Granularity(enum.IntEnum):
    """Granularity ladder of ingest data items (paper Sec. III)."""

    FILE = 0      # raw input file (bytes, unparsed)
    CHUNK = 1     # parsed slice of a file: columnar record batch
    RECORD = 2    # single record (chunk of length 1)
    BLOCK = 3     # packed, serialized block — the storage/consumption unit


# Columnar payload: field name -> equal-length np.ndarray.
Columns = Dict[str, np.ndarray]


def num_rows(columns: Columns) -> int:
    if not columns:
        return 0
    return len(next(iter(columns.values())))


def concat_columns(parts: List[Columns]) -> Columns:
    parts = [p for p in parts if p and num_rows(p) > 0]
    if not parts:
        return {}
    keys = list(parts[0].keys())
    return {k: np.concatenate([p[k] for p in parts]) for k in keys}


def take_rows(columns: Columns, idx: np.ndarray) -> Columns:
    return {k: v[idx] for k, v in columns.items()}


@dataclass(frozen=True)
class Label:
    """One lineage entry: the operator that touched the item and the value it assigned."""

    op: str
    value: Any

    def __str__(self) -> str:  # used in lineage-encoded filenames
        return f"{self.op}-{self.value}"


@dataclass
class IngestItem:
    """A labelled ingest data item.

    ``data`` is payload whose type depends on granularity:
      FILE   -> bytes or str (path-like raw content)
      CHUNK  -> Columns (dict of equal-length numpy arrays)
      RECORD -> Columns with a single row
      BLOCK  -> SerializedBlock (see layouts/) or raw ndarray/bytes
    ``labels`` is the ordered lineage (paper Sec. VII: filename-encoded).
    """

    data: Any
    granularity: Granularity = Granularity.FILE
    labels: Tuple[Label, ...] = ()
    meta: Dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------------ labels
    def with_label(self, op: str, value: Any) -> "IngestItem":
        return replace(self, labels=self.labels + (Label(op, value),))

    def label_value(self, op: str, default: Any = None) -> Any:
        """Latest label value assigned by operator ``op`` (None if never touched)."""
        for lab in reversed(self.labels):
            if lab.op == op:
                return lab.value
        return default

    def label_values(self, op: str) -> List[Any]:
        return [l.value for l in self.labels if l.op == op]

    def lineage_name(self) -> str:
        """The paper's label-encoded physical file name: label1_label2_..._labeln."""
        return "_".join(str(l) for l in self.labels) or "raw"

    # ------------------------------------------------------------------- sizes
    def nbytes(self) -> int:
        d = self.data
        if isinstance(d, (bytes, bytearray, str)):
            return len(d)
        if isinstance(d, np.ndarray):
            return int(d.nbytes)
        if isinstance(d, dict):
            return int(sum(v.nbytes for v in d.values() if isinstance(v, np.ndarray)))
        if hasattr(d, "nbytes"):
            return int(d.nbytes)
        return 0

    def nrows(self) -> int:
        if isinstance(self.data, dict):
            return num_rows(self.data)
        if isinstance(self.data, np.ndarray):
            return len(self.data)
        return 1

    def checksum(self) -> str:
        h = hashlib.sha256()
        d = self.data
        if isinstance(d, (bytes, bytearray)):
            h.update(d)
        elif isinstance(d, str):
            h.update(d.encode())
        elif isinstance(d, np.ndarray):
            h.update(np.ascontiguousarray(d).tobytes())
        elif isinstance(d, dict):
            for k in sorted(d):
                h.update(k.encode())
                h.update(np.ascontiguousarray(d[k]).tobytes())
        elif hasattr(d, "tobytes"):
            h.update(d.tobytes())
        return h.hexdigest()[:16]


def matches(item: IngestItem, predicates: Dict[str, Any]) -> bool:
    """Label-predicate match used by the dataflow stages (paper Sec. IV-B).

    ``predicates`` maps operator name -> required label value; a predicate
    value may also be a callable for inequality predicates such as the
    paper's ``l_parser > now-1``.
    """
    for op, want in predicates.items():
        have = item.label_value(op)
        if callable(want):
            if not want(have):
                return False
        elif have != want:
            return False
    return True
