"""Socket fabric: framed, versioned message transport for the process
backend (ISSUE 9).

The process backend's control and store channels used to be
``multiprocessing.Pipe`` objects — single-host by construction.  This
module supplies the multi-host substitute behind the same duck-typed
surface (``send(obj)`` / ``recv()`` / ``close()``), so ``procexec``'s
worker loop, receiver threads, and store-RPC client run unchanged on
either medium (``transport="pipe"|"socket"``):

* :class:`FramedConnection` — length-prefixed frames over TCP.  Every
  frame carries a magic, a protocol version, the payload length, a CRC
  of the payload, and a CRC of the header itself.  A clean peer close
  surfaces as ``EOFError`` (exactly what a pipe does), while a torn,
  truncated, or garbled frame raises :class:`FrameError` — an
  ``OSError`` subclass, so every existing ``except (EOFError, OSError)``
  death path catches it instead of a bare ``struct.error`` escaping or,
  worse, ``recv`` blocking forever on a half-frame.  Sends run under a
  bounded ``send_timeout_s`` (a partitioned peer with full TCP buffers
  fails the sender instead of wedging the coordinator) and a partial
  frame that stops making progress for ``idle_timeout_s`` is declared
  torn.
* :func:`connect_framed` / :class:`FrameListener` — connect and accept
  wrapped in ``liveness.retry_call`` bounded backoff, with a hello
  handshake (role + node + shared token) so one listener serves both the
  control and the store channel of a worker.
* :class:`ChaosProxy` — a byte-level TCP shim the chaos harness renders
  network events onto: ``partition()`` stops pumping both directions
  (silence -> the liveness monitor declares the host dead as a unit),
  ``drop_bytes()`` discards bytes mid-stream (the receiver sees a
  garbled frame -> CRC failure -> death path), ``delay()`` stalls
  forwarding once.  Deterministic by construction: events fire from the
  seeded chaos schedule, not from timers.
* :class:`PartitionStreamServer` — the degraded-mode exchange endpoint
  (DESIGN.md §7): every socket-transport worker serves its own spill
  files to peers over the same framed protocol, consume-on-read, so two
  workers that do not share ``/dev/shm`` (different hosts) still exchange
  partitions worker-to-worker.
"""
from __future__ import annotations

import os
import pickle
import socket
import struct
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

from .liveness import retry_call

#: frame magic — never a prefix of a pickle stream, so a raw-pickle peer
#: (or trash bytes after a dropped range) fails the magic check instantly
FRAME_MAGIC = b"IGB\xa9"
#: protocol version: bump on any wire-incompatible frame change; a peer
#: speaking another version is garbled by definition (FrameError, death)
FRAME_VERSION = 1

#: magic(4s) version(B) flags(B) reserved(H) payload_len(I) payload_crc(I)
_HDR = struct.Struct("!4sBBHII")
#: crc32 of the preceding header bytes
_HDR_CRC = struct.Struct("!I")
HEADER_SIZE = _HDR.size + _HDR_CRC.size

#: ceiling on a single frame — control traffic is metadata (manifests,
#: refs, store records) and degraded-mode partition payloads; anything
#: past this is a corrupt length field, not a real message
MAX_FRAME_BYTES = 1 << 30

#: stream-fetch chunk size (ISSUE 10): a partition whose byte image exceeds
#: this crosses as a bounded sequence of ``chunk`` frames instead of one
#: giant frame — an oversized partition must stream, never trip the
#: MAX_FRAME_BYTES sanity ceiling as a spurious FrameError.  Module-level
#: and read at call time so tests can shrink it.
STREAM_CHUNK_BYTES = 64 << 20

#: socket-level tick: blocked recv/send wake this often to re-check the
#: closed flag and their deadlines (close() from another thread must
#: unblock a receiver whose peer is partitioned, not crashed)
_TICK_S = 0.2


class FrameError(OSError):
    """A torn or garbled frame: bad magic/version, a CRC mismatch, an
    insane length, or EOF mid-frame.  Subclasses ``OSError`` so the
    process backend's existing ``except (EOFError, OSError)`` death
    paths convert it to WorkerDeath instead of hanging or crashing on an
    unhandled ``struct.error``."""


class SendTimeout(FrameError):
    """A send made no progress for ``send_timeout_s`` — the peer is
    partitioned or wedged with full buffers.  The connection is poisoned
    (frame boundaries are lost mid-``sendall``), so it also maps to the
    death path."""


def pack_frame(payload: bytes) -> bytes:
    """One wire frame for ``payload``: header + header CRC + payload."""
    hdr = _HDR.pack(FRAME_MAGIC, FRAME_VERSION, 0, 0, len(payload),
                    zlib.crc32(payload))
    return hdr + _HDR_CRC.pack(zlib.crc32(hdr)) + payload


def unpack_header(raw: bytes) -> Tuple[int, int]:
    """Validate a ``HEADER_SIZE`` block; returns (payload_len, payload_crc).

    Raises :class:`FrameError` on any mismatch — never ``struct.error``
    (the block length is fixed by the caller)."""
    if len(raw) != HEADER_SIZE:
        raise FrameError(f"torn frame header: {len(raw)}/{HEADER_SIZE} bytes")
    magic, version, _flags, _rsv, length, payload_crc = _HDR.unpack(
        raw[:_HDR.size])
    (hdr_crc,) = _HDR_CRC.unpack(raw[_HDR.size:])
    if zlib.crc32(raw[:_HDR.size]) != hdr_crc:
        raise FrameError("garbled frame header (CRC mismatch)")
    if magic != FRAME_MAGIC:
        raise FrameError(f"bad frame magic {magic!r}")
    if version != FRAME_VERSION:
        raise FrameError(f"frame version {version} != {FRAME_VERSION}")
    if length > MAX_FRAME_BYTES:
        raise FrameError(f"insane frame length {length}")
    return length, payload_crc


class FramedConnection:
    """A ``multiprocessing.Connection``-shaped wrapper over one TCP socket.

    ``send(obj)`` pickles and writes one frame; ``recv()`` reads one frame
    and unpickles.  Failure mapping (the whole point — see module doc):
    clean close -> ``EOFError``; torn/garbled frame, send timeout, reset
    -> ``FrameError``/``OSError``.  ``close()`` from any thread unblocks
    a pending ``recv()`` within one tick even when the peer never sends
    EOF (a partitioned, not crashed, peer)."""

    def __init__(self, sock: socket.socket, *,
                 send_timeout_s: float = 10.0,
                 idle_timeout_s: float = 30.0) -> None:
        self._sock = sock
        self.send_timeout_s = send_timeout_s
        self.idle_timeout_s = idle_timeout_s
        self._closed = False
        self._send_lock = threading.Lock()
        self._recv_lock = threading.Lock()
        sock.settimeout(_TICK_S)
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass

    # ------------------------------------------------------------------ send
    def send(self, obj: Any) -> None:
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        frame = pack_frame(payload)
        with self._send_lock:
            if self._closed:
                raise OSError("connection closed")
            view = memoryview(frame)
            deadline = time.monotonic() + self.send_timeout_s
            while view:
                try:
                    n = self._sock.send(view)
                except socket.timeout:
                    if self._closed:
                        raise OSError("connection closed") from None
                    if time.monotonic() > deadline:
                        self.close()
                        raise SendTimeout(
                            f"send stalled > {self.send_timeout_s}s "
                            f"(partitioned peer?)") from None
                    continue
                except InterruptedError:
                    continue
                view = view[n:]

    # ------------------------------------------------------------------ recv
    def _read_exact(self, n: int, *, mid_frame: bool) -> bytes:
        """Exactly ``n`` bytes.  At a frame boundary (``mid_frame=False``)
        silence is legal for as long as the peer lives — heartbeat gaps are
        the liveness monitor's business, not ours.  Once the first byte of
        a frame has arrived, the rest must follow within ``idle_timeout_s``
        or the frame is torn."""
        buf = bytearray()
        deadline: Optional[float] = (
            time.monotonic() + self.idle_timeout_s if mid_frame else None)
        while len(buf) < n:
            try:
                chunk = self._sock.recv(min(1 << 16, n - len(buf)))
            except socket.timeout:
                if self._closed:
                    raise EOFError("connection closed")
                if deadline is not None and time.monotonic() > deadline:
                    raise FrameError(
                        f"torn frame: {len(buf)}/{n} bytes then "
                        f"{self.idle_timeout_s}s of silence")
                continue
            except InterruptedError:
                continue
            if not chunk:
                if buf or mid_frame:
                    raise FrameError(
                        f"torn frame: EOF after {len(buf)}/{n} bytes")
                raise EOFError("peer closed")
            buf += chunk
            if deadline is None:
                # first byte of the frame: the rest is now on the clock
                deadline = time.monotonic() + self.idle_timeout_s
        return bytes(buf)

    def recv(self) -> Any:
        with self._recv_lock:
            if self._closed:
                raise EOFError("connection closed")
            hdr = self._read_exact(HEADER_SIZE, mid_frame=False)
            length, payload_crc = unpack_header(hdr)
            payload = self._read_exact(length, mid_frame=True)
        if zlib.crc32(payload) != payload_crc:
            raise FrameError("garbled frame payload (CRC mismatch)")
        try:
            return pickle.loads(payload)
        except Exception as e:
            # a CRC collision over corrupt bytes still must not escape as
            # an unpickling crash — garbled is garbled
            raise FrameError(f"garbled frame payload: {e}") from e

    # ----------------------------------------------------------------- admin
    def close(self) -> None:
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass

    def fileno(self) -> int:
        return self._sock.fileno()


# ---------------------------------------------------------------------------
# Listener / connect with bounded retry + hello handshake
# ---------------------------------------------------------------------------
def _hello(role: str, node: str, token: str,
           info: Optional[Dict[str, Any]]) -> Tuple[str, str, str, dict]:
    return ("hello", role, node, token, dict(info or {}))  # type: ignore


class FrameListener:
    """Accept side of the fabric: one loopback listener per executor,
    serving the worker's control and store connections (distinguished by
    the hello's role) and authenticated by a per-executor token."""

    def __init__(self, host: str = "127.0.0.1") -> None:
        self._sock = socket.create_server((host, 0))
        self._sock.settimeout(_TICK_S)
        self._closed = False

    @property
    def address(self) -> Tuple[str, int]:
        return self._sock.getsockname()[:2]

    def accept_framed(self, token: str, *, timeout_s: float = 30.0,
                      send_timeout_s: float = 10.0,
                      idle_timeout_s: float = 30.0
                      ) -> Tuple[FramedConnection, str, str, Dict[str, Any]]:
        """One authenticated connection: ``(conn, role, node, info)``.

        A connection with a bad token or a garbled hello is dropped and
        the accept keeps waiting (within ``timeout_s``) — a stray dialer
        must not poison the worker's slot."""
        deadline = time.monotonic() + timeout_s
        while True:
            if self._closed:
                raise OSError("listener closed")
            if time.monotonic() > deadline:
                raise TimeoutError(f"no authenticated peer in {timeout_s}s")
            try:
                sock, _addr = self._sock.accept()
            except socket.timeout:
                continue
            conn = FramedConnection(sock, send_timeout_s=send_timeout_s,
                                    idle_timeout_s=idle_timeout_s)
            try:
                msg = conn.recv()
                if (isinstance(msg, tuple) and len(msg) == 5
                        and msg[0] == "hello" and msg[3] == token):
                    return conn, msg[1], msg[2], msg[4]
            except (EOFError, OSError):
                pass
            conn.close()

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass


def connect_framed(address: Tuple[str, int], *,
                   role: str = "", node: str = "", token: str = "",
                   info: Optional[Dict[str, Any]] = None,
                   attempts: int = 5, base_delay_s: float = 0.05,
                   connect_timeout_s: float = 5.0,
                   send_timeout_s: float = 10.0,
                   idle_timeout_s: float = 30.0) -> FramedConnection:
    """Dial ``address`` with bounded backoff (``retry_call``) and present
    the hello handshake.  A flaky accept or a listener that is still a few
    milliseconds from binding retries instead of failing the spawn."""

    def dial() -> FramedConnection:
        sock = socket.create_connection(tuple(address),
                                        timeout=connect_timeout_s)
        conn = FramedConnection(sock, send_timeout_s=send_timeout_s,
                                idle_timeout_s=idle_timeout_s)
        if token:
            try:
                conn.send(_hello(role, node, token, info))
            except OSError:
                conn.close()
                raise
        return conn

    conn, _used = retry_call(dial, attempts=attempts,
                             base_delay_s=base_delay_s,
                             retry_on=(OSError,))
    return conn


# ---------------------------------------------------------------------------
# Chaos proxy: deterministic network faults on a socket pair
# ---------------------------------------------------------------------------
class ChaosProxy:
    """Byte-level TCP shim between a worker and its executor's listener.

    The worker dials the proxy; each inbound connection gets its own
    outbound dial to ``target`` and two pump threads.  Faults apply to
    every pumped pair:

    * ``partition()`` — stop *reading* both directions: the link goes
      silent (heartbeats die -> per-host quorum declares) and a sender
      eventually fills its buffers (``SendTimeout``).  ``heal()`` undoes.
    * ``drop_bytes(n)`` — discard the next ``n`` bytes worker->coordinator:
      frame boundaries are lost, the coordinator's next recv fails CRC or
      magic (FrameError -> death path).
    * ``delay(seconds)`` — one-shot stall before the next forward in
      either direction (a slow link, simulated deterministically).
    """

    def __init__(self, target: Tuple[str, int],
                 host: str = "127.0.0.1") -> None:
        self.target = tuple(target)
        self._listener = socket.create_server((host, 0))
        self._listener.settimeout(_TICK_S)
        self._partitioned = threading.Event()
        self._closed = threading.Event()
        self._lock = threading.Lock()
        self._drop_pending = 0          # bytes to discard, inbound->target
        self._delay_pending = 0.0       # one-shot stall, either direction
        self._threads: List[threading.Thread] = []
        self._socks: List[socket.socket] = []
        t = threading.Thread(target=self._accept_loop, daemon=True,
                             name="chaos-proxy-accept")
        t.start()
        self._threads.append(t)

    @property
    def address(self) -> Tuple[str, int]:
        return self._listener.getsockname()[:2]

    # ----------------------------------------------------------------- faults
    def partition(self) -> None:
        self._partitioned.set()

    def heal(self) -> None:
        self._partitioned.clear()

    def drop_bytes(self, n: int = 64) -> None:
        with self._lock:
            self._drop_pending += int(n)

    def delay(self, seconds: float) -> None:
        with self._lock:
            self._delay_pending = max(self._delay_pending, float(seconds))

    # ------------------------------------------------------------------ pumps
    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                inbound, _addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            try:
                outbound = socket.create_connection(self.target, timeout=5.0)
            except OSError:
                inbound.close()
                continue
            for s in (inbound, outbound):
                s.settimeout(_TICK_S)
            self._socks += [inbound, outbound]
            for src, dst, lossy in ((inbound, outbound, True),
                                    (outbound, inbound, False)):
                t = threading.Thread(target=self._pump, daemon=True,
                                     args=(src, dst, lossy),
                                     name="chaos-proxy-pump")
                t.start()
                self._threads.append(t)

    def _pump(self, src: socket.socket, dst: socket.socket,
              lossy: bool) -> None:
        """Forward src->dst; ``lossy`` marks the worker->coordinator
        direction where ``drop_bytes`` applies."""
        while not self._closed.is_set():
            if self._partitioned.is_set():
                # a partition drops packets on the floor: stop reading, so
                # the receiver sees silence and the sender backs up
                time.sleep(_TICK_S)
                continue
            try:
                data = src.recv(1 << 16)
            except socket.timeout:
                continue
            except OSError:
                break
            if not data:
                try:
                    dst.shutdown(socket.SHUT_WR)
                except OSError:
                    pass
                break
            with self._lock:
                delay, self._delay_pending = self._delay_pending, 0.0
                if lossy and self._drop_pending > 0:
                    dropped = min(len(data), self._drop_pending)
                    self._drop_pending -= dropped
                    data = data[dropped:]
            if delay:
                time.sleep(delay)
            if not data:
                continue
            try:
                dst.sendall(data)
            except OSError:
                break

    def close(self) -> None:
        self._closed.set()
        try:
            self._listener.close()
        except OSError:
            pass
        for s in self._socks:
            try:
                s.close()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# Degraded-mode exchange: worker-to-worker partition streaming
# ---------------------------------------------------------------------------
class PartitionStreamServer:
    """Per-worker endpoint serving the worker's own spill files to peers.

    When producer and consumer are not shm-reachable (different hosts),
    the producer writes the partition as an ordinary exchange spill file
    — same naming, same ``gc_orphans`` coverage — and advertises this
    endpoint in the ref (``kind="stream"``).  The consumer fetches the
    raw file bytes over one framed request/response; a successful send
    deletes the file (consume-on-read, exactly like the direct-read
    path).  Requests outside ``root`` are refused."""

    def __init__(self, root: str, host: str = "127.0.0.1") -> None:
        self.root = os.path.realpath(root)
        self._listener = socket.create_server((host, 0))
        self._listener.settimeout(_TICK_S)
        self._closed = threading.Event()
        self.served = 0          # partitions streamed (observability)
        self.served_bytes = 0
        self._thread = threading.Thread(target=self._serve_loop, daemon=True,
                                        name="partition-stream-server")
        self._thread.start()

    @property
    def endpoint(self) -> Tuple[str, int]:
        return self._listener.getsockname()[:2]

    def _serve_loop(self) -> None:
        while not self._closed.is_set():
            try:
                sock, _addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            threading.Thread(target=self._handle, args=(sock,), daemon=True,
                             name="partition-stream-req").start()

    def _handle(self, sock: socket.socket) -> None:
        conn = FramedConnection(sock, idle_timeout_s=10.0)
        try:
            msg = conn.recv()
            if (not isinstance(msg, tuple) or len(msg) != 2
                    or msg[0] != "fetch"):
                conn.send(("err", "bad request"))
                return
            path = os.path.realpath(str(msg[1]))
            if not path.startswith(self.root + os.sep):
                conn.send(("err", f"path outside exchange root: {path}"))
                return
            try:
                with open(path, "rb") as f:
                    data = f.read()
            except FileNotFoundError:
                # already consumed (direct read, a replayed round's cleanup)
                conn.send(("gone", None))
                return
            if len(data) > STREAM_CHUNK_BYTES:
                # oversized partition (ISSUE 10): announce the chunked
                # reply, then stream bounded frames — each stays far under
                # MAX_FRAME_BYTES, so the frame-sanity check never fires
                # on legitimate data
                n = -(-len(data) // STREAM_CHUNK_BYTES)
                conn.send(("chunks", [len(data), n]))
                for i in range(n):
                    conn.send(("chunk",
                               data[i * STREAM_CHUNK_BYTES:
                                    (i + 1) * STREAM_CHUNK_BYTES]))
            else:
                conn.send(("ok", data))
            # consume-on-read: the bytes are on the wire; the consumer's
            # death mid-read aborts its epoch, which re-deals everything
            try:
                os.remove(path)
            except OSError:
                pass
            self.served += 1
            self.served_bytes += len(data)
        except (EOFError, OSError):
            pass
        finally:
            conn.close()

    def close(self) -> None:
        self._closed.set()
        try:
            self._listener.close()
        except OSError:
            pass


def fetch_stream_bytes(endpoint: Tuple[str, int], path: str, *,
                       attempts: int = 2,
                       timeout_s: float = 10.0) -> Optional[bytes]:
    """Client half of the degraded exchange: fetch a spill file's bytes
    from a peer's :class:`PartitionStreamServer`.  Returns ``None`` when
    the peer is unreachable or the file is gone — callers fall back to
    the shared-dir direct read, which stays correct on a single host."""
    try:
        conn = connect_framed(tuple(endpoint), attempts=attempts,
                              connect_timeout_s=timeout_s,
                              send_timeout_s=timeout_s,
                              idle_timeout_s=timeout_s)
    except OSError:
        return None
    try:
        conn.send(("fetch", path))
        status, data = conn.recv()
        if status == "chunks":
            # oversized partition (ISSUE 10): reassemble the bounded
            # chunk frames; any torn/garbled chunk surfaces as FrameError
            # (caught below) and the caller falls back to the direct read
            total, n = int(data[0]), int(data[1])
            parts = []
            for _ in range(n):
                tag, chunk = conn.recv()
                if tag != "chunk":
                    return None
                parts.append(chunk)
            blob = b"".join(parts)
            return blob if len(blob) == total else None
    except (EOFError, OSError, ValueError, TypeError):
        return None
    finally:
        conn.close()
    return data if status == "ok" else None
