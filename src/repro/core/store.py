"""DataStore — the storage substrate under ingestion plans (the HDFS analogue).

Physical blocks live under ``<root>/nodes/<node>/`` with their lineage-encoded
names (paper Sec. VII: the filename *is* the metadata).  A JSON manifest adds
what HDFS's namenode would know: node placement, checksums, replica groups and
erasure stripes — enough for the post-ingestion fault-tolerance daemon to
detect and recover failures (paper Sec. VI-C2).

A shared ``<root>/dfs/`` directory mediates shuffles (paper Sec. VI-B: local
groups are copied to the distributed file system, then read back per group).

Streaming epochs: the micro-batch runtime stages each epoch's blocks under an
epoch id and publishes them atomically via ``commit_epoch`` — the manifest only
ever records blocks of *committed* epochs.  The exactly-once commit point is
one appended line in the epoch journal (``manifest.epochs.jsonl``): a whole
line is a committed epoch, a torn line is not; ``flush_manifest`` (temp-write
+ rename) periodically compacts the journal into the base snapshot.  Blocks
with ``epoch=-1`` are batch-ingested and always visible.

Pipelined epochs (DESIGN.md §3): several epochs may stage *concurrently* —
each writer thread binds its epoch with ``epoch_context`` so ``put_block``
attributes blocks unambiguously — and the **commit sequencer** publishes
commits strictly in epoch-id order: ``commit_epoch(e)`` blocks while any
epoch < e is still staging, so ``since_epoch`` readers only ever observe a
gap-free, in-order prefix of the epoch sequence.
"""
from __future__ import annotations

import contextlib
import json
import os
import shutil
import threading
import time
import zlib
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..layouts import SerializedBlock
from .items import Granularity, IngestItem, Label


@dataclass
class BlockEntry:
    """Manifest entry for one stored physical block."""

    block_id: str              # unique id: lineage name + disambiguator
    node: str                  # placement node
    path: str                  # path relative to store root
    checksum: str
    nbytes: int
    labels: List[List[Any]]    # [[op, value], ...] lineage
    layout: str = "raw"
    logical_id: str = ""       # identifies the logical content (replicas share it)
    replica_index: int = 0     # which replica of logical_id this is
    stripe_id: str = ""        # erasure stripe membership ("" = not striped)
    stripe_pos: int = -1       # position within the stripe (data: 0..k-1, parity: k..k+m-1)
    is_parity: bool = False
    epoch: int = -1            # streaming epoch that wrote this block (-1 = batch)
    compressed: bool = False   # payload is zlib-compressed at rest
    raw_nbytes: int = -1       # logical (uncompressed) size; -1 = same as nbytes
    meta: Dict[str, Any] = field(default_factory=dict)

    def logical_nbytes(self) -> int:
        return self.raw_nbytes if self.raw_nbytes >= 0 else self.nbytes

    def to_manifest(self) -> Dict[str, Any]:
        """The exact dict ``dataclasses.asdict`` would build, minus its
        recursive deep-copy walk — every field here is already a plain
        value, and the manifest writer only serializes the result.  At
        ~30µs per ``asdict`` call a few hundred entries turn every
        manifest flush into a two-digit-millisecond stall (ISSUE 10)."""
        return {"block_id": self.block_id, "node": self.node,
                "path": self.path, "checksum": self.checksum,
                "nbytes": self.nbytes, "labels": self.labels,
                "layout": self.layout, "logical_id": self.logical_id,
                "replica_index": self.replica_index,
                "stripe_id": self.stripe_id, "stripe_pos": self.stripe_pos,
                "is_parity": self.is_parity, "epoch": self.epoch,
                "compressed": self.compressed,
                "raw_nbytes": self.raw_nbytes, "meta": self.meta}


@dataclass
class EpochEntry:
    """Manifest entry for one committed streaming epoch."""

    epoch: int
    n_blocks: int = 0
    n_items: int = 0           # source items the epoch consumed
    committed_at: float = 0.0  # wall-clock commit timestamp


def prepare_block_payload(data: Any, compress: bool,
                          compress_level: int) -> Tuple[bytes, str, int]:
    """Item payload -> (stored bytes, layout id, logical size).  Shared by
    ``DataStore.put_block`` and the process backend's worker-side store
    client, so both backends accept exactly the same payload types and
    apply at-rest compression identically."""
    if isinstance(data, SerializedBlock):
        payload, layout = data.tobytes(), data.layout
    elif isinstance(data, np.ndarray):
        payload, layout = data.tobytes(), "raw"
    elif isinstance(data, (bytes, bytearray)):
        payload, layout = bytes(data), "raw"
    else:
        raise TypeError(f"cannot store payload of type {type(data)}")
    raw_nbytes = len(payload)
    if compress:   # at-rest compression: transparent to readers
        payload = zlib.compress(payload, compress_level)
    return payload, layout, raw_nbytes


class DataStore:
    #: how long a commit waits on out-of-order predecessors before giving up
    COMMIT_SEQUENCE_TIMEOUT_S = 60.0

    def __init__(self, root: str, nodes: Sequence[str] = ("node0",),
                 durable: bool = False, compress: bool = False,
                 compress_level: int = 3, journal_commits: bool = True,
                 journal_compact_lines: int = 512) -> None:
        """``durable=True`` fsyncs staged block files and the epoch-commit
        journal line — a committed epoch survives power loss, not just
        process death.  ``compress=True`` zlib-compresses block payloads at
        rest (transparent: ``read_payload`` decompresses; checksums stay
        logical).  ``journal_commits=False`` commits by rewriting the full
        manifest snapshot instead of appending a journal line — a single
        manifest file, at O(store) cost per commit (the pre-ISSUE-2
        behavior, kept for ops that want one file and as the pipelining
        benchmark's baseline).  ``journal_compact_lines`` bounds the epoch
        journal: once it exceeds that many commit lines, the next commit
        auto-folds it into the base snapshot (``flush_manifest``), so a
        long-running stream never replays an unbounded journal on open
        (0/None disables auto-compaction)."""
        self.root = root
        self.nodes = list(nodes)
        self.durable = durable
        self.compress = compress
        self.compress_level = compress_level
        self.journal_commits = journal_commits
        self.journal_compact_lines = journal_compact_lines
        self._journal_lines = 0      # commit lines currently in the journal
        self._lock = threading.Lock()
        self._commit_cv = threading.Condition(self._lock)
        self.entries: Dict[str, BlockEntry] = {}
        self.epochs: Dict[int, EpochEntry] = {}   # committed epochs only
        self._staging: Set[int] = set()           # epochs currently staging
        # staging epoch -> its block ids, so commit/abort are O(epoch
        # blocks), not an O(store) scan
        self._epoch_blocks: Dict[int, List[str]] = {}
        self._epoch_ctx = threading.local()       # per-thread staging binding
        self._dead_nodes: Set[str] = set()        # in-flight node deaths
        # shuffle-exchange spill paths of *live* rounds (leased by the
        # ShuffleCoordinator): gc_orphans keeps these and reclaims the rest
        # — after a crash a fresh store holds no leases, so a dead epoch's
        # partition files become reclaimable garbage
        self._exchange_leases: Set[str] = set()
        os.makedirs(self.dfs_dir, exist_ok=True)
        for n in self.nodes:
            os.makedirs(self.node_dir(n), exist_ok=True)
        self._load_manifest()

    # ----------------------------------------------------------------- layout
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.root, "manifest.json")

    @property
    def epoch_journal_path(self) -> str:
        return os.path.join(self.root, "manifest.epochs.jsonl")

    @property
    def dfs_dir(self) -> str:
        return os.path.join(self.root, "dfs")

    def node_dir(self, node: str) -> str:
        return os.path.join(self.root, "nodes", node)

    # --------------------------------------------------------------- manifest
    def _load_manifest(self) -> None:
        if os.path.exists(self.manifest_path):
            with open(self.manifest_path) as f:
                raw = json.load(f)
            if "blocks" in raw:        # epoch-aware format
                self.entries = {k: BlockEntry(**v) for k, v in raw["blocks"].items()}
                self.epochs = {int(k): EpochEntry(**v)
                               for k, v in raw.get("epochs", {}).items()}
            else:                      # legacy flat block map
                self.entries = {k: BlockEntry(**v) for k, v in raw.items()}
        self._replay_epoch_journal()

    def _replay_epoch_journal(self) -> None:
        """Apply epoch-commit journal lines on top of the base snapshot.

        A torn trailing line (crash mid-append) is simply an epoch that never
        committed — its blocks stay unreferenced and ``gc_orphans`` reclaims
        them; lines for epochs already in the snapshot are skipped (crash
        between snapshot rename and journal truncation)."""
        if not os.path.exists(self.epoch_journal_path):
            return
        with open(self.epoch_journal_path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    break   # torn tail: everything after it never committed
                self._journal_lines += 1
                entry = EpochEntry(**rec["epoch"])
                if entry.epoch in self.epochs:
                    continue
                self.epochs[entry.epoch] = entry
                for k, v in rec["blocks"].items():
                    self.entries[k] = BlockEntry(**v)

    def flush_manifest(self) -> None:
        """Atomically publish the full manifest snapshot (write-temp + rename)
        and compact the epoch-commit journal into it.

        Blocks of a still-staging epoch are withheld: a crash before
        ``commit_epoch`` leaves at most orphaned ``.blk`` files that no
        manifest references — the epoch never half-commits.
        """
        with self._lock:
            blocks = {k: v.to_manifest() for k, v in self.entries.items()
                      if v.epoch < 0 or v.epoch in self.epochs}
            payload = {"blocks": blocks,
                       "epochs": {str(k): asdict(v) for k, v in self.epochs.items()}}
            tmp = self.manifest_path + ".tmp"
            with open(tmp, "w") as f:
                # one buffered write of a compact dump: indent (even 0)
                # forces json's pure-Python encoder — on a manifest with
                # hundreds of blocks that is a ~100ms stall per flush,
                # ~10x the C encoder this way (ISSUE 10)
                f.write(json.dumps(payload, separators=(",", ":")))
                if self.durable:
                    f.flush()
                    os.fsync(f.fileno())
            os.replace(tmp, self.manifest_path)
            # journal lines are now folded into the snapshot; a crash right
            # here only leaves duplicate records, which replay skips
            if os.path.exists(self.epoch_journal_path):
                os.remove(self.epoch_journal_path)
            self._journal_lines = 0

    # ------------------------------------------------------------------ epochs
    def begin_epoch(self, epoch: int) -> None:
        """Start staging blocks under ``epoch``.  Re-ingesting a committed
        epoch is refused — the exactly-once guard for replays.

        Several epochs may stage concurrently (pipelined streaming overlaps
        epoch N's store/commit with epoch N+1's ingest).  A writer thread that
        stages blocks while more than one epoch is open must bind its epoch
        with ``epoch_context`` so ``put_block`` attributes them unambiguously.
        Re-beginning a still-staging epoch is a no-op (epoch replay)."""
        with self._lock:
            if epoch in self.epochs:
                raise ValueError(f"epoch {epoch} already committed")
            self._staging.add(epoch)

    @contextlib.contextmanager
    def epoch_context(self, epoch: Optional[int]) -> Iterator[None]:
        """Bind ``put_block`` calls on this thread to a staging epoch (None =
        no binding: batch writes, or single-staging-epoch legacy mode)."""
        prev = getattr(self._epoch_ctx, "epoch", None)
        self._epoch_ctx.epoch = epoch
        try:
            yield
        finally:
            self._epoch_ctx.epoch = prev

    def _current_epoch(self) -> int:
        """Epoch to attribute a put_block to: thread binding first, else the
        single staging epoch, else batch (-1).  Ambiguity is an error — a
        block silently attached to the wrong epoch would break atomicity."""
        bound = getattr(self._epoch_ctx, "epoch", None)
        if bound is not None:
            return bound
        if not self._staging:
            return -1
        if len(self._staging) == 1:
            return next(iter(self._staging))
        raise RuntimeError(
            f"epochs {sorted(self._staging)} are staging concurrently; "
            f"writers must bind one with DataStore.epoch_context")

    def commit_epoch(self, epoch: int, n_items: int = 0) -> EpochEntry:
        """Atomically publish every block staged under ``epoch``.

        The commit sequencer: if any *smaller* epoch id is still staging, this
        call blocks until that epoch commits or aborts, so commits land in
        strict epoch order and readers never observe a gap in the committed
        sequence (DESIGN.md §3).

        The durable commit point is one appended journal line (O(epoch
        blocks), not an O(store) manifest rewrite): a fully-written line is a
        committed epoch, a torn line is not — ``flush_manifest`` periodically
        folds the journal into the snapshot."""
        deadline = time.monotonic() + self.COMMIT_SEQUENCE_TIMEOUT_S
        with self._commit_cv:
            if epoch in self.epochs:
                raise ValueError(f"epoch {epoch} already committed")
            while any(s < epoch for s in self._staging):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise RuntimeError(
                        f"commit of epoch {epoch} timed out waiting for "
                        f"staged predecessors {sorted(s for s in self._staging if s < epoch)}")
                self._commit_cv.wait(timeout=remaining)
            if epoch in self.epochs:      # re-check after waiting
                raise ValueError(f"epoch {epoch} already committed")
            blocks = {k: self.entries[k].to_manifest()
                      for k in self._epoch_blocks.pop(epoch, [])
                      if k in self.entries}
            entry = EpochEntry(epoch=epoch, n_blocks=len(blocks),
                               n_items=n_items, committed_at=time.time())
            if self.journal_commits:
                # the commit point: one whole journal line lands (or doesn't)
                with open(self.epoch_journal_path, "a") as f:
                    f.write(json.dumps({"epoch": asdict(entry), "blocks": blocks}))
                    f.write("\n")
                    f.flush()
                    if self.durable:
                        os.fsync(f.fileno())
                self._journal_lines += 1
            self.epochs[epoch] = entry
            self._staging.discard(epoch)
            self._commit_cv.notify_all()
        if not self.journal_commits:
            self.flush_manifest()   # snapshot commit: temp-write + rename
        elif (self.journal_compact_lines
              and self._journal_lines > self.journal_compact_lines):
            # auto-compaction: fold the oversized journal into the snapshot
            # so a long-running stream never replays an unbounded journal
            self.flush_manifest()
        return entry

    def abort_epoch(self, epoch: int) -> int:
        """Roll back a failed epoch attempt: drop its staged entries and
        delete their physical files.  Committed epochs cannot be aborted."""
        with self._commit_cv:
            if epoch in self.epochs:
                raise ValueError(f"epoch {epoch} already committed")
            victims = [k for k in self._epoch_blocks.pop(epoch, [])
                       if k in self.entries]
            for k in victims:
                full = os.path.join(self.root, self.entries[k].path)
                if os.path.exists(full):
                    os.remove(full)
                del self.entries[k]
            self._staging.discard(epoch)
            self._commit_cv.notify_all()
        return len(victims)

    def epoch_committed(self, epoch: int) -> bool:
        return epoch in self.epochs

    def committed_epoch_ids(self) -> List[int]:
        with self._lock:   # consistent snapshot while the committer publishes
            return sorted(self.epochs)

    def staging_epoch_ids(self) -> List[int]:
        with self._lock:
            return sorted(self._staging)

    def next_epoch_id(self) -> int:
        with self._lock:
            return max(max(self.epochs, default=-1),
                       max(self._staging, default=-1)) + 1

    # ----------------------------------------------------- exchange leases
    def lease_exchange_path(self, path: str) -> None:
        """Pin a shuffle-exchange spill path (file or legacy spill dir) as
        belonging to a live round — ``gc_orphans`` will not reclaim it."""
        with self._lock:
            self._exchange_leases.add(os.path.abspath(path))

    def release_exchange_path(self, path: str) -> None:
        with self._lock:
            self._exchange_leases.discard(os.path.abspath(path))

    # ---------------------------------------------------------- node liveness
    def mark_node_dead(self, node: str) -> None:
        """In-flight node failure (runtime): stop placing new blocks there —
        its location IDs flow to the surviving nodes (paper Sec. VI-C1)."""
        self._dead_nodes.add(node)

    def mark_node_live(self, node: str) -> None:
        self._dead_nodes.discard(node)

    def live_nodes(self) -> List[str]:
        return [n for n in self.nodes if n not in self._dead_nodes]

    # ------------------------------------------------------------------- write
    def put_block(self, item: IngestItem, node: str, *, logical_id: str = "",
                  replica_index: int = 0, stripe_id: str = "", stripe_pos: int = -1,
                  is_parity: bool = False) -> BlockEntry:
        payload, layout, raw_nbytes = prepare_block_payload(
            item.data, self.compress, self.compress_level)
        base = item.lineage_name()
        with self._lock:
            block_id = base
            k = 0
            while block_id in self.entries:
                k += 1
                block_id = f"{base}_{k}"
            rel = os.path.join("nodes", node, block_id + ".blk")
            entry = BlockEntry(
                block_id=block_id, node=node, path=rel,
                checksum=item.checksum(), nbytes=len(payload),
                labels=[[l.op, l.value] for l in item.labels],
                layout=layout, logical_id=logical_id or self._logical_id(item),
                replica_index=replica_index, stripe_id=stripe_id,
                stripe_pos=stripe_pos, is_parity=is_parity,
                epoch=self._current_epoch(),
                compressed=self.compress, raw_nbytes=raw_nbytes,
                meta=dict(item.meta),
            )
            self.entries[block_id] = entry
            if entry.epoch >= 0:   # index for O(epoch) commit/abort
                self._epoch_blocks.setdefault(entry.epoch, []).append(block_id)
        full = os.path.join(self.root, rel)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        with open(full, "wb") as f:
            f.write(payload)
            if self.durable:   # staged data must survive a crash-then-commit
                f.flush()
                os.fsync(f.fileno())
        return entry

    #: columnar data plane (ISSUE 10): direct-call stores take the bulk
    #: registration path unconditionally — without an RPC boundary it is
    #: byte-for-byte the per-block loop, so thread-backend runs are
    #: identical columnar on or off
    bulk_registration = True

    def put_block_batch(self, reqs: Sequence[Dict[str, Any]]
                        ) -> List["BlockEntry"]:
        """Register a whole block batch, order preserved (ISSUE 10).  Each
        request is a ``put_block`` call as a dict (``item``, ``node``, plus
        the keyword metadata); on a direct-call store this IS the per-block
        loop — the worker-side twin (``_WorkerStoreClient``) collapses it
        into one coordinator round trip."""
        return [self.put_block(r["item"], r["node"],
                               **{k: v for k, v in r.items()
                                  if k not in ("item", "node")})
                for r in reqs]

    def register_block_file(self, node: str, tmp_path: str, *, base: str,
                            checksum: str, nbytes: int, raw_nbytes: int,
                            compressed: bool, labels: List[List[Any]],
                            layout: str, logical_id: str, replica_index: int,
                            stripe_id: str, stripe_pos: int, is_parity: bool,
                            meta: Dict[str, Any], epoch: int) -> BlockEntry:
        """Adopt a block file a *worker process* already wrote (DESIGN.md §6).

        The process backend keeps the heavy work — serialization, compression,
        the disk write — in the worker, which writes to a ``.tmp`` name the
        orphan GC never scans; only this metadata registration is routed
        through the coordinator, which owns the manifest: it allocates the
        unique block id under the store lock, records the entry (attributed
        to the worker's staging ``epoch``), and renames the temp file into
        its final lineage-encoded path.  Entry-before-rename preserves the
        ``gc_orphans`` invariant: every visible ``.blk`` file has an entry.
        """
        with self._lock:
            if epoch >= 0 and epoch in self.epochs:
                raise ValueError(f"epoch {epoch} already committed")
            block_id = base
            k = 0
            while block_id in self.entries:
                k += 1
                block_id = f"{base}_{k}"
            rel = os.path.join("nodes", node, block_id + ".blk")
            entry = BlockEntry(
                block_id=block_id, node=node, path=rel, checksum=checksum,
                nbytes=nbytes, labels=labels, layout=layout,
                logical_id=logical_id or base, replica_index=replica_index,
                stripe_id=stripe_id, stripe_pos=stripe_pos,
                is_parity=is_parity, epoch=epoch, compressed=compressed,
                raw_nbytes=raw_nbytes, meta=dict(meta))
            self.entries[block_id] = entry
            if epoch >= 0:
                self._epoch_blocks.setdefault(epoch, []).append(block_id)
        full = os.path.join(self.root, rel)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        os.replace(tmp_path, full)
        return entry

    def register_block_batch(self, records: Sequence[Dict[str, Any]]
                             ) -> List[BlockEntry]:
        """Bulk twin of :meth:`register_block_file` (ISSUE 10): adopt a whole
        worker-written batch under ONE lock acquisition, order preserved.
        Each record is exactly a ``register_block_file`` call as a dict
        (``node``, ``tmp_path``, plus the keyword metadata).  Every epoch is
        validated and every entry recorded before any temp file renames, so
        entry-before-rename holds batch-wide; the renames share one
        made-directory memo instead of 512 ``makedirs`` round trips."""
        entries: List[BlockEntry] = []
        renames: List[Tuple[str, str]] = []
        with self._lock:
            for rec in records:
                epoch = rec["epoch"]
                if epoch >= 0 and epoch in self.epochs:
                    raise ValueError(f"epoch {epoch} already committed")
            for rec in records:
                base = rec["base"]
                block_id = base
                k = 0
                while block_id in self.entries:
                    k += 1
                    block_id = f"{base}_{k}"
                rel = os.path.join("nodes", rec["node"], block_id + ".blk")
                entry = BlockEntry(
                    block_id=block_id, node=rec["node"], path=rel,
                    checksum=rec["checksum"], nbytes=rec["nbytes"],
                    labels=rec["labels"], layout=rec["layout"],
                    logical_id=rec["logical_id"] or base,
                    replica_index=rec["replica_index"],
                    stripe_id=rec["stripe_id"], stripe_pos=rec["stripe_pos"],
                    is_parity=rec["is_parity"], epoch=rec["epoch"],
                    compressed=rec["compressed"],
                    raw_nbytes=rec["raw_nbytes"], meta=dict(rec["meta"]))
                self.entries[block_id] = entry
                if entry.epoch >= 0:
                    self._epoch_blocks.setdefault(entry.epoch,
                                                  []).append(block_id)
                entries.append(entry)
                renames.append((rec["tmp_path"],
                                os.path.join(self.root, rel)))
        made = set()
        for tmp, full in renames:
            d = os.path.dirname(full)
            if d not in made:
                os.makedirs(d, exist_ok=True)
                made.add(d)
            os.replace(tmp, full)
        return entries

    @staticmethod
    def _logical_id(item: IngestItem) -> str:
        """Replica-invariant identity: the lineage minus replicate/locate labels."""
        keep = [l for l in item.labels if not l.op.startswith(("replicate", "locate", "upload"))]
        return "_".join(str(l) for l in keep) or "raw"

    # -------------------------------------------------------------------- read
    def read_payload(self, block_id: str) -> bytes:
        """The block's *logical* payload (at-rest compression is peeled off)."""
        entry = self.entries[block_id]
        with open(os.path.join(self.root, entry.path), "rb") as f:
            raw = f.read()
        return zlib.decompress(raw) if entry.compressed else raw

    def read_block(self, block_id: str) -> SerializedBlock:
        entry = self.entries[block_id]
        raw = self.read_payload(block_id)
        if entry.layout == "raw":
            return SerializedBlock(layout="raw", payload=raw)
        return SerializedBlock.frombytes(raw)

    def read_item(self, block_id: str) -> IngestItem:
        entry = self.entries[block_id]
        labels = tuple(Label(op, v) for op, v in entry.labels)
        return IngestItem(self.read_block(block_id), Granularity.BLOCK, labels,
                          dict(entry.meta))

    # ------------------------------------------------------------------- query
    def blocks(self) -> List[BlockEntry]:
        with self._lock:   # consistent snapshot while a streaming epoch writes
            return list(self.entries.values())

    def blocks_with_label(self, op: str, value: Any = None) -> List[BlockEntry]:
        out = []
        for e in self.blocks():
            for lop, lval in e.labels:
                if lop == op and (value is None or lval == value):
                    out.append(e)
                    break
        return out

    def replicas_of(self, logical_id: str) -> List[BlockEntry]:
        return [e for e in self.blocks() if e.logical_id == logical_id]

    def stripe_members(self, stripe_id: str) -> List[BlockEntry]:
        out = [e for e in self.blocks() if e.stripe_id == stripe_id]
        return sorted(out, key=lambda e: e.stripe_pos)

    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.blocks())

    # --------------------------------------------------- failure detect/inject
    def verify_block(self, block_id: str) -> bool:
        """True if the physical file exists and matches its recorded size."""
        entry = self.entries.get(block_id)
        if entry is None:
            return False
        full = os.path.join(self.root, entry.path)
        if not os.path.exists(full):
            return False
        return os.path.getsize(full) == entry.nbytes

    def failed_blocks(self) -> List[str]:
        """The fault daemon's ``detect`` scan source (paper Fig. 3)."""
        return [e.block_id for e in self.blocks() if not self.verify_block(e.block_id)]

    def gc_orphans(self) -> List[str]:
        """Delete files no live reference covers and return their paths.

        Two kinds of crash garbage exist (the commit + exchange protocols
        guarantee there are no others):

        * ``.blk`` block files the manifest never references — an epoch
          aborted or crashed mid-stage.  Blocks of epochs still staging in
          *this* process are referenced by in-memory entries and are kept;
          after a crash, a fresh DataStore loads only the committed
          manifest, so the dead epoch's files become orphans here.
        * exchange spill files under ``dfs/`` — peer partition files
          (``exchange_*.part``), resident-bucket spills of narrow edges and
          pinned cross-segment rounds (``resident_*.part``, a crash
          mid-slice leaves them with no consumer), and legacy barrier group
          dirs (``shuffle_*``).  Live rounds lease their paths
          (``lease_exchange_path``); a crash drops the leases with the
          process, so a fresh store reclaims the files.

        The ``.blk`` scan holds the store lock and ``put_block`` registers
        the entry under it *before* writing the file, so a concurrently
        staged block can never be swept.  Exchange files are weaker: a
        worker writes the spill before its manifest reaches the coordinator
        (which leases the path on arrival), so running this scan
        *concurrently with an in-flight shuffle round* can race that window
        and sweep a not-yet-leased partition — the consumer then fails the
        stage and the epoch replays (an availability blip, never data
        loss).  Treat exchange-file reclamation as a crash-recovery /
        idle-time operation."""
        removed: List[str] = []
        with self._lock:
            referenced = {os.path.normpath(e.path) for e in self.entries.values()}
            for node in self.nodes:
                ndir = self.node_dir(node)
                if not os.path.isdir(ndir):
                    continue
                for fn in sorted(os.listdir(ndir)):
                    if not fn.endswith(".blk"):
                        continue
                    rel = os.path.normpath(os.path.join("nodes", node, fn))
                    if rel not in referenced:
                        os.remove(os.path.join(self.root, rel))
                        removed.append(rel)
            # ---- stale shuffle/exchange spills (ISSUE 4 satellite)
            from .exchange import is_exchange_file
            dfs = self.dfs_dir
            if os.path.isdir(dfs):
                for fn in sorted(os.listdir(dfs)):
                    full = os.path.abspath(os.path.join(dfs, fn))
                    if full in self._exchange_leases:
                        continue
                    rel = os.path.normpath(os.path.join("dfs", fn))
                    if os.path.isfile(full) and is_exchange_file(fn):
                        os.remove(full)
                        removed.append(rel)
                    elif os.path.isdir(full) and fn.startswith("shuffle_"):
                        shutil.rmtree(full, ignore_errors=True)
                        removed.append(rel)
        return removed

    def corrupt_block(self, block_id: str) -> None:
        entry = self.entries[block_id]
        full = os.path.join(self.root, entry.path)
        with open(full, "wb") as f:
            f.write(b"\x00corrupt")

    def kill_node(self, node: str) -> None:
        """Simulate a node failure: its local storage disappears."""
        shutil.rmtree(self.node_dir(node), ignore_errors=True)

    def restore_file(self, entry: BlockEntry, payload: bytes, node: Optional[str] = None) -> None:
        """Write a recovered *logical* payload back (optionally onto a
        different node), re-applying at-rest compression."""
        if node is not None and node != entry.node:
            entry.node = node
            entry.path = os.path.join("nodes", node, entry.block_id + ".blk")
        entry.raw_nbytes = len(payload)
        if self.compress:
            payload = zlib.compress(payload, self.compress_level)
            entry.compressed = True
        else:
            entry.compressed = False
        full = os.path.join(self.root, entry.path)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        with open(full, "wb") as f:
            f.write(payload)
        entry.nbytes = len(payload)
