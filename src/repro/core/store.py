"""DataStore — the storage substrate under ingestion plans (the HDFS analogue).

Physical blocks live under ``<root>/nodes/<node>/`` with their lineage-encoded
names (paper Sec. VII: the filename *is* the metadata).  A JSON manifest adds
what HDFS's namenode would know: node placement, checksums, replica groups and
erasure stripes — enough for the post-ingestion fault-tolerance daemon to
detect and recover failures (paper Sec. VI-C2).

A shared ``<root>/dfs/`` directory mediates shuffles (paper Sec. VI-B: local
groups are copied to the distributed file system, then read back per group).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..layouts import SerializedBlock
from .items import Granularity, IngestItem, Label


@dataclass
class BlockEntry:
    """Manifest entry for one stored physical block."""

    block_id: str              # unique id: lineage name + disambiguator
    node: str                  # placement node
    path: str                  # path relative to store root
    checksum: str
    nbytes: int
    labels: List[List[Any]]    # [[op, value], ...] lineage
    layout: str = "raw"
    logical_id: str = ""       # identifies the logical content (replicas share it)
    replica_index: int = 0     # which replica of logical_id this is
    stripe_id: str = ""        # erasure stripe membership ("" = not striped)
    stripe_pos: int = -1       # position within the stripe (data: 0..k-1, parity: k..k+m-1)
    is_parity: bool = False
    meta: Dict[str, Any] = field(default_factory=dict)


class DataStore:
    def __init__(self, root: str, nodes: Sequence[str] = ("node0",)) -> None:
        self.root = root
        self.nodes = list(nodes)
        self._lock = threading.Lock()
        self.entries: Dict[str, BlockEntry] = {}
        os.makedirs(self.dfs_dir, exist_ok=True)
        for n in self.nodes:
            os.makedirs(self.node_dir(n), exist_ok=True)
        self._load_manifest()

    # ----------------------------------------------------------------- layout
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.root, "manifest.json")

    @property
    def dfs_dir(self) -> str:
        return os.path.join(self.root, "dfs")

    def node_dir(self, node: str) -> str:
        return os.path.join(self.root, "nodes", node)

    # --------------------------------------------------------------- manifest
    def _load_manifest(self) -> None:
        if os.path.exists(self.manifest_path):
            with open(self.manifest_path) as f:
                raw = json.load(f)
            self.entries = {k: BlockEntry(**v) for k, v in raw.items()}

    def flush_manifest(self) -> None:
        with self._lock:
            tmp = self.manifest_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({k: asdict(v) for k, v in self.entries.items()}, f, indent=0)
            os.replace(tmp, self.manifest_path)

    # ------------------------------------------------------------------- write
    def put_block(self, item: IngestItem, node: str, *, logical_id: str = "",
                  replica_index: int = 0, stripe_id: str = "", stripe_pos: int = -1,
                  is_parity: bool = False) -> BlockEntry:
        data = item.data
        if isinstance(data, SerializedBlock):
            payload, layout = data.tobytes(), data.layout
        elif isinstance(data, np.ndarray):
            payload, layout = data.tobytes(), "raw"
        elif isinstance(data, (bytes, bytearray)):
            payload, layout = bytes(data), "raw"
        else:
            raise TypeError(f"cannot store payload of type {type(data)}")

        base = item.lineage_name()
        with self._lock:
            block_id = base
            k = 0
            while block_id in self.entries:
                k += 1
                block_id = f"{base}_{k}"
            rel = os.path.join("nodes", node, block_id + ".blk")
            entry = BlockEntry(
                block_id=block_id, node=node, path=rel,
                checksum=item.checksum(), nbytes=len(payload),
                labels=[[l.op, l.value] for l in item.labels],
                layout=layout, logical_id=logical_id or self._logical_id(item),
                replica_index=replica_index, stripe_id=stripe_id,
                stripe_pos=stripe_pos, is_parity=is_parity,
                meta=dict(item.meta),
            )
            self.entries[block_id] = entry
        full = os.path.join(self.root, rel)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        with open(full, "wb") as f:
            f.write(payload)
        return entry

    @staticmethod
    def _logical_id(item: IngestItem) -> str:
        """Replica-invariant identity: the lineage minus replicate/locate labels."""
        keep = [l for l in item.labels if not l.op.startswith(("replicate", "locate", "upload"))]
        return "_".join(str(l) for l in keep) or "raw"

    # -------------------------------------------------------------------- read
    def read_payload(self, block_id: str) -> bytes:
        entry = self.entries[block_id]
        with open(os.path.join(self.root, entry.path), "rb") as f:
            return f.read()

    def read_block(self, block_id: str) -> SerializedBlock:
        entry = self.entries[block_id]
        raw = self.read_payload(block_id)
        if entry.layout == "raw":
            return SerializedBlock(layout="raw", payload=raw)
        return SerializedBlock.frombytes(raw)

    def read_item(self, block_id: str) -> IngestItem:
        entry = self.entries[block_id]
        labels = tuple(Label(op, v) for op, v in entry.labels)
        return IngestItem(self.read_block(block_id), Granularity.BLOCK, labels,
                          dict(entry.meta))

    # ------------------------------------------------------------------- query
    def blocks(self) -> List[BlockEntry]:
        return list(self.entries.values())

    def blocks_with_label(self, op: str, value: Any = None) -> List[BlockEntry]:
        out = []
        for e in self.entries.values():
            for lop, lval in e.labels:
                if lop == op and (value is None or lval == value):
                    out.append(e)
                    break
        return out

    def replicas_of(self, logical_id: str) -> List[BlockEntry]:
        return [e for e in self.entries.values() if e.logical_id == logical_id]

    def stripe_members(self, stripe_id: str) -> List[BlockEntry]:
        out = [e for e in self.entries.values() if e.stripe_id == stripe_id]
        return sorted(out, key=lambda e: e.stripe_pos)

    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.entries.values())

    # --------------------------------------------------- failure detect/inject
    def verify_block(self, block_id: str) -> bool:
        """True if the physical file exists and matches its recorded size."""
        entry = self.entries.get(block_id)
        if entry is None:
            return False
        full = os.path.join(self.root, entry.path)
        if not os.path.exists(full):
            return False
        return os.path.getsize(full) == entry.nbytes

    def failed_blocks(self) -> List[str]:
        """The fault daemon's ``detect`` scan source (paper Fig. 3)."""
        return [bid for bid in self.entries if not self.verify_block(bid)]

    def corrupt_block(self, block_id: str) -> None:
        entry = self.entries[block_id]
        full = os.path.join(self.root, entry.path)
        with open(full, "wb") as f:
            f.write(b"\x00corrupt")

    def kill_node(self, node: str) -> None:
        """Simulate a node failure: its local storage disappears."""
        shutil.rmtree(self.node_dir(node), ignore_errors=True)

    def restore_file(self, entry: BlockEntry, payload: bytes, node: Optional[str] = None) -> None:
        """Write a recovered payload back (optionally onto a different node)."""
        if node is not None and node != entry.node:
            entry.node = node
            entry.path = os.path.join("nodes", node, entry.block_id + ".blk")
        full = os.path.join(self.root, entry.path)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        with open(full, "wb") as f:
            f.write(payload)
        entry.nbytes = len(payload)
