"""DataStore — the storage substrate under ingestion plans (the HDFS analogue).

Physical blocks live under ``<root>/nodes/<node>/`` with their lineage-encoded
names (paper Sec. VII: the filename *is* the metadata).  A JSON manifest adds
what HDFS's namenode would know: node placement, checksums, replica groups and
erasure stripes — enough for the post-ingestion fault-tolerance daemon to
detect and recover failures (paper Sec. VI-C2).

A shared ``<root>/dfs/`` directory mediates shuffles (paper Sec. VI-B: local
groups are copied to the distributed file system, then read back per group).

Streaming epochs: the micro-batch runtime stages each epoch's blocks under an
epoch id and publishes them atomically via ``commit_epoch`` — the manifest only
ever records blocks of *committed* epochs, and the temp-write + rename in
``flush_manifest`` is the exactly-once commit point.  Blocks with ``epoch=-1``
are batch-ingested and always visible.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..layouts import SerializedBlock
from .items import Granularity, IngestItem, Label


@dataclass
class BlockEntry:
    """Manifest entry for one stored physical block."""

    block_id: str              # unique id: lineage name + disambiguator
    node: str                  # placement node
    path: str                  # path relative to store root
    checksum: str
    nbytes: int
    labels: List[List[Any]]    # [[op, value], ...] lineage
    layout: str = "raw"
    logical_id: str = ""       # identifies the logical content (replicas share it)
    replica_index: int = 0     # which replica of logical_id this is
    stripe_id: str = ""        # erasure stripe membership ("" = not striped)
    stripe_pos: int = -1       # position within the stripe (data: 0..k-1, parity: k..k+m-1)
    is_parity: bool = False
    epoch: int = -1            # streaming epoch that wrote this block (-1 = batch)
    meta: Dict[str, Any] = field(default_factory=dict)


@dataclass
class EpochEntry:
    """Manifest entry for one committed streaming epoch."""

    epoch: int
    n_blocks: int = 0
    n_items: int = 0           # source items the epoch consumed
    committed_at: float = 0.0  # wall-clock commit timestamp


class DataStore:
    def __init__(self, root: str, nodes: Sequence[str] = ("node0",)) -> None:
        self.root = root
        self.nodes = list(nodes)
        self._lock = threading.Lock()
        self.entries: Dict[str, BlockEntry] = {}
        self.epochs: Dict[int, EpochEntry] = {}   # committed epochs only
        self._staging_epoch: Optional[int] = None
        os.makedirs(self.dfs_dir, exist_ok=True)
        for n in self.nodes:
            os.makedirs(self.node_dir(n), exist_ok=True)
        self._load_manifest()

    # ----------------------------------------------------------------- layout
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.root, "manifest.json")

    @property
    def dfs_dir(self) -> str:
        return os.path.join(self.root, "dfs")

    def node_dir(self, node: str) -> str:
        return os.path.join(self.root, "nodes", node)

    # --------------------------------------------------------------- manifest
    def _load_manifest(self) -> None:
        if not os.path.exists(self.manifest_path):
            return
        with open(self.manifest_path) as f:
            raw = json.load(f)
        if "blocks" in raw:        # epoch-aware format
            self.entries = {k: BlockEntry(**v) for k, v in raw["blocks"].items()}
            self.epochs = {int(k): EpochEntry(**v)
                           for k, v in raw.get("epochs", {}).items()}
        else:                      # legacy flat block map
            self.entries = {k: BlockEntry(**v) for k, v in raw.items()}

    def flush_manifest(self) -> None:
        """Atomically publish the manifest (write-temp + rename).

        Blocks of a still-staging epoch are withheld: a crash before
        ``commit_epoch`` leaves at most orphaned ``.blk`` files that no
        manifest references — the epoch never half-commits.
        """
        with self._lock:
            blocks = {k: asdict(v) for k, v in self.entries.items()
                      if v.epoch < 0 or v.epoch in self.epochs}
            payload = {"blocks": blocks,
                       "epochs": {str(k): asdict(v) for k, v in self.epochs.items()}}
            tmp = self.manifest_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=0)
            os.replace(tmp, self.manifest_path)

    # ------------------------------------------------------------------ epochs
    def begin_epoch(self, epoch: int) -> None:
        """Start staging blocks under ``epoch``.  Re-ingesting a committed
        epoch is refused — the exactly-once guard for replays.

        The staging marker is store-global: while an epoch stages, this store
        has a single writer (the streaming engine).  Concurrent ingestion into
        the same store must target a different DataStore root — any put_block
        between begin and commit/abort is attributed to the staging epoch.
        Overlapping ``begin_epoch`` calls are refused for the same reason."""
        with self._lock:
            if epoch in self.epochs:
                raise ValueError(f"epoch {epoch} already committed")
            if self._staging_epoch is not None and self._staging_epoch != epoch:
                raise RuntimeError(
                    f"epoch {self._staging_epoch} is still staging; "
                    f"one writer per store during streaming ingestion")
            self._staging_epoch = epoch

    def commit_epoch(self, epoch: int, n_items: int = 0) -> EpochEntry:
        """Atomically publish every block staged under ``epoch``."""
        with self._lock:
            if epoch in self.epochs:
                raise ValueError(f"epoch {epoch} already committed")
            n_blocks = sum(1 for e in self.entries.values() if e.epoch == epoch)
            entry = EpochEntry(epoch=epoch, n_blocks=n_blocks, n_items=n_items,
                               committed_at=time.time())
            self.epochs[epoch] = entry
            self._staging_epoch = None
        self.flush_manifest()   # the commit point: temp-write + rename
        return entry

    def abort_epoch(self, epoch: int) -> int:
        """Roll back a failed epoch attempt: drop its staged entries and
        delete their physical files.  Committed epochs cannot be aborted."""
        with self._lock:
            if epoch in self.epochs:
                raise ValueError(f"epoch {epoch} already committed")
            victims = [k for k, e in self.entries.items() if e.epoch == epoch]
            for k in victims:
                full = os.path.join(self.root, self.entries[k].path)
                if os.path.exists(full):
                    os.remove(full)
                del self.entries[k]
            self._staging_epoch = None
        return len(victims)

    def epoch_committed(self, epoch: int) -> bool:
        return epoch in self.epochs

    def committed_epoch_ids(self) -> List[int]:
        return sorted(self.epochs)

    def next_epoch_id(self) -> int:
        return max(self.epochs, default=-1) + 1

    # ------------------------------------------------------------------- write
    def put_block(self, item: IngestItem, node: str, *, logical_id: str = "",
                  replica_index: int = 0, stripe_id: str = "", stripe_pos: int = -1,
                  is_parity: bool = False) -> BlockEntry:
        data = item.data
        if isinstance(data, SerializedBlock):
            payload, layout = data.tobytes(), data.layout
        elif isinstance(data, np.ndarray):
            payload, layout = data.tobytes(), "raw"
        elif isinstance(data, (bytes, bytearray)):
            payload, layout = bytes(data), "raw"
        else:
            raise TypeError(f"cannot store payload of type {type(data)}")

        base = item.lineage_name()
        with self._lock:
            block_id = base
            k = 0
            while block_id in self.entries:
                k += 1
                block_id = f"{base}_{k}"
            rel = os.path.join("nodes", node, block_id + ".blk")
            entry = BlockEntry(
                block_id=block_id, node=node, path=rel,
                checksum=item.checksum(), nbytes=len(payload),
                labels=[[l.op, l.value] for l in item.labels],
                layout=layout, logical_id=logical_id or self._logical_id(item),
                replica_index=replica_index, stripe_id=stripe_id,
                stripe_pos=stripe_pos, is_parity=is_parity,
                epoch=self._staging_epoch if self._staging_epoch is not None else -1,
                meta=dict(item.meta),
            )
            self.entries[block_id] = entry
        full = os.path.join(self.root, rel)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        with open(full, "wb") as f:
            f.write(payload)
        return entry

    @staticmethod
    def _logical_id(item: IngestItem) -> str:
        """Replica-invariant identity: the lineage minus replicate/locate labels."""
        keep = [l for l in item.labels if not l.op.startswith(("replicate", "locate", "upload"))]
        return "_".join(str(l) for l in keep) or "raw"

    # -------------------------------------------------------------------- read
    def read_payload(self, block_id: str) -> bytes:
        entry = self.entries[block_id]
        with open(os.path.join(self.root, entry.path), "rb") as f:
            return f.read()

    def read_block(self, block_id: str) -> SerializedBlock:
        entry = self.entries[block_id]
        raw = self.read_payload(block_id)
        if entry.layout == "raw":
            return SerializedBlock(layout="raw", payload=raw)
        return SerializedBlock.frombytes(raw)

    def read_item(self, block_id: str) -> IngestItem:
        entry = self.entries[block_id]
        labels = tuple(Label(op, v) for op, v in entry.labels)
        return IngestItem(self.read_block(block_id), Granularity.BLOCK, labels,
                          dict(entry.meta))

    # ------------------------------------------------------------------- query
    def blocks(self) -> List[BlockEntry]:
        with self._lock:   # consistent snapshot while a streaming epoch writes
            return list(self.entries.values())

    def blocks_with_label(self, op: str, value: Any = None) -> List[BlockEntry]:
        out = []
        for e in self.blocks():
            for lop, lval in e.labels:
                if lop == op and (value is None or lval == value):
                    out.append(e)
                    break
        return out

    def replicas_of(self, logical_id: str) -> List[BlockEntry]:
        return [e for e in self.blocks() if e.logical_id == logical_id]

    def stripe_members(self, stripe_id: str) -> List[BlockEntry]:
        out = [e for e in self.blocks() if e.stripe_id == stripe_id]
        return sorted(out, key=lambda e: e.stripe_pos)

    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.blocks())

    # --------------------------------------------------- failure detect/inject
    def verify_block(self, block_id: str) -> bool:
        """True if the physical file exists and matches its recorded size."""
        entry = self.entries.get(block_id)
        if entry is None:
            return False
        full = os.path.join(self.root, entry.path)
        if not os.path.exists(full):
            return False
        return os.path.getsize(full) == entry.nbytes

    def failed_blocks(self) -> List[str]:
        """The fault daemon's ``detect`` scan source (paper Fig. 3)."""
        return [e.block_id for e in self.blocks() if not self.verify_block(e.block_id)]

    def corrupt_block(self, block_id: str) -> None:
        entry = self.entries[block_id]
        full = os.path.join(self.root, entry.path)
        with open(full, "wb") as f:
            f.write(b"\x00corrupt")

    def kill_node(self, node: str) -> None:
        """Simulate a node failure: its local storage disappears."""
        shutil.rmtree(self.node_dir(node), ignore_errors=True)

    def restore_file(self, entry: BlockEntry, payload: bytes, node: Optional[str] = None) -> None:
        """Write a recovered payload back (optionally onto a different node)."""
        if node is not None and node != entry.node:
            entry.node = node
            entry.path = os.path.join("nodes", node, entry.block_id + ".blk")
        full = os.path.join(self.root, entry.path)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        with open(full, "wb") as f:
            f.write(payload)
        entry.nbytes = len(payload)
