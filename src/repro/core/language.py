"""The declarative ingestion language (paper Sec. IV).

Two front-ends over the same plan builder:

1. A Python-embedded DSL mirroring the paper's statements::

       p = IngestPlan("logs")
       s1 = select(p, parser="parser", parser_args={...}, replicate=2)
       s2 = format_(p, s1, chunk={"target_bytes": 100<<20}, serialize="sorted")
       s4 = store(p, s2, locate="disjoint", upload=store_target)
       create_stage(p, using=[s1]); chain_stage(p, to=["a"], using=[s2], where={"replicate": 1})

2. A SQL-ish text front-end parsing the paper's surface syntax::

       s1 = SELECT * FROM input USING parser REPLICATE BY 2;
       s3 = FORMAT s1 CHUNK BY 100mb;
       s9 = STORE s3 LOCATE USING roundrobin UPLOAD TO target;
       CREATE STAGE a USING s1;
       CHAIN STAGE b TO a USING s3 WHERE l_replicate=1;

   Operator names resolve through the operator registry, so custom operators
   participate in the textual language too.

Feed fan-out (ISSUE 2 / DESIGN.md §5): ``FEED <source> INTO plan1, plan2``
declares an AsterixDB-style feed joint — one ingest fanned into several
plans.  Plan names resolve to IngestPlan objects in ``env``; the resulting
``FeedSpec`` plugs straight into ``stream_ingest_multi``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .operators import IngestOp, resolve_op
from .plan import IngestPlan, coerce_bool
from .sources import SOURCE_KINDS, build_source
from .store import DataStore


@dataclass
class FeedSpec:
    """A parsed ``FEED <source> INTO p1, p2, ...`` statement.

    ``plans`` is what ``stream_ingest_multi`` consumes (it duck-types on the
    attribute, keeping the language layer import-free from the runtime)."""

    source: str
    plan_names: List[str] = field(default_factory=list)
    plans: List[IngestPlan] = field(default_factory=list)


# --------------------------------------------------------------------- helpers
def _as_op(spec: Union[str, IngestOp, None], default_key: str,
           args: Optional[Dict[str, Any]] = None) -> Optional[IngestOp]:
    if spec is None:
        return None
    if isinstance(spec, IngestOp):
        return spec
    return resolve_op(spec if spec != "default" else default_key, **(args or {}))


# ------------------------------------------------------------------ Python DSL
def select(plan: IngestPlan, source: Optional[str] = None, *,
           parser: Union[str, IngestOp, None] = "identity_parser",
           parser_args: Optional[Dict[str, Any]] = None,
           where: Union[IngestOp, Callable, None] = None,
           where_fields: Sequence[str] = (),
           projection: Union[Sequence[str], IngestOp, None] = None,
           replicate: Union[int, IngestOp, None] = None,
           replicate_tag: Optional[str] = None,
           sid: Optional[str] = None) -> str:
    """SELECT projection FROM source USING parser WHERE filter REPLICATE BY r.

    Compiles to the chain parser -> filter -> projection -> replicator
    (paper Sec. IV-A).
    """
    ops: List[IngestOp] = []
    p = _as_op(parser, "parser", parser_args)
    if p is not None:
        ops.append(p)
    if where is not None:
        if isinstance(where, IngestOp):
            ops.append(where)
        else:
            ops.append(resolve_op("filter", predicate=where, fields=tuple(where_fields)))
    if projection is not None:
        if isinstance(projection, IngestOp):
            ops.append(projection)
        else:
            ops.append(resolve_op("project", fields=tuple(projection)))
    if replicate is not None:
        if isinstance(replicate, IngestOp):
            ops.append(replicate)
        else:
            ops.append(resolve_op("replicate", copies=int(replicate),
                                  tag=replicate_tag))
    inputs = [source] if source else []
    return plan.add_statement(ops, kind="select", sid=sid, inputs=inputs)


def format_(plan: IngestPlan, source: str, *,
            steps: Optional[Sequence[Tuple[str, Dict[str, Any]]]] = None,
            partition: Optional[Dict[str, Any]] = None,
            chunk: Optional[Dict[str, Any]] = None,
            order: Optional[Dict[str, Any]] = None,
            pack: Optional[Dict[str, Any]] = None,
            erasure: Optional[Dict[str, Any]] = None,
            serialize: Union[str, IngestOp, None] = None,
            serialize_args: Optional[Dict[str, Any]] = None,
            sid: Optional[str] = None) -> str:
    """FORMAT source PARTITION BY .. CHUNK BY .. ORDER BY .. SERIALIZE AS ..

    Operators chain in keyword order partition->chunk->order->(pack)->serialize
    unless ``steps`` gives an explicit (possibly repeating) sequence — the
    paper's multi-level partitioning / global-sort variants (s2 vs s3).
    """
    ops: List[IngestOp] = []
    if steps is not None:
        for key, kw in steps:
            ops.append(resolve_op(key, **kw))
    else:
        if partition is not None:
            ops.append(resolve_op("partition", **partition))
        if chunk is not None:
            ops.append(resolve_op("chunk", **chunk))
        if order is not None:
            ops.append(resolve_op("order", **order))
        if pack is not None:
            ops.append(resolve_op("pack", **pack))
        if serialize is not None:
            if isinstance(serialize, IngestOp):
                ops.append(serialize)
            else:
                ops.append(resolve_op("serialize", layout=serialize,
                                      **(serialize_args or {})))
        if erasure is not None:
            ops.append(resolve_op("erasure", **erasure))
    return plan.add_statement(ops, kind="format", sid=sid, inputs=[source])


def store(plan: IngestPlan, *sources: str,
          locate: Union[str, IngestOp, None] = None,
          locate_args: Optional[Dict[str, Any]] = None,
          upload: Optional[DataStore] = None,
          upload_args: Optional[Dict[str, Any]] = None,
          sid: Optional[str] = None) -> str:
    """STORE sources LOCATE USING locator UPLOAD TO target."""
    ops: List[IngestOp] = []
    if locate is not None:
        if isinstance(locate, IngestOp):
            ops.append(locate)
        else:
            ops.append(resolve_op("locate", scheme=locate, **(locate_args or {})))
    if upload is not None:
        ops.append(resolve_op("upload", store=upload, **(upload_args or {})))
    return plan.add_statement(ops, kind="store", sid=sid, inputs=list(sources))


def create_stage(plan: IngestPlan, using: Sequence[str],
                 where: Optional[Dict[str, Any]] = None,
                 name: Optional[str] = None) -> str:
    return plan.create_stage(using, where, name)


def chain_stage(plan: IngestPlan, to: Sequence[str], using: Sequence[str],
                where: Optional[Dict[str, Any]] = None,
                name: Optional[str] = None) -> str:
    return plan.chain_stage(to, using, where, name)


def with_epochs(plan: IngestPlan, *, items: Optional[int] = None,
                seconds: Optional[float] = None,
                bytes: Optional[int] = None,
                capacity: Optional[int] = None,
                adaptive: Optional[bool] = None) -> IngestPlan:
    """Declare the plan streamable: epochs cut every ``items`` items,
    ``bytes`` of queued payload, and/or ``seconds`` of wall clock — first
    threshold wins — behind per-node ingest queues bounded at ``capacity``
    (STREAM WITH EPOCHS(...) in the textual language).  ``adaptive=True``
    turns on the commit-latency EWMA controller that rescales the
    items/bytes cut at runtime (``EpochPolicy.observe_commit``)."""
    cfg = {k: v for k, v in
           (("items", items), ("seconds", seconds), ("bytes", bytes),
            ("capacity", capacity),
            ("adaptive", None if adaptive is None else coerce_bool(adaptive)))
           if v is not None}
    if not cfg:
        raise LanguageError("with_epochs: give at least one of "
                            "items/seconds/bytes/capacity/adaptive")
    plan.stream_config = cfg
    return plan


def with_source(plan: IngestPlan, kind: str, **spec: Any) -> IngestPlan:
    """Declare a worker-pull source for the plan (``SOURCE kind(...)`` in the
    textual language, ISSUE 6): the spec compiles to a
    :class:`~repro.core.sources.SourceAdapter` at run time, so the coordinator
    distributes shard descriptors and the workers read the bytes themselves.

    The spec is validated eagerly by building a throwaway adapter — a typo'd
    kind or kwarg fails at declaration time, not mid-stream."""
    cfg: Dict[str, Any] = {"kind": kind.lower()}
    cfg.update({k: v for k, v in spec.items() if v is not None})
    try:
        build_source(dict(cfg))
    except (KeyError, TypeError, ValueError) as e:
        raise LanguageError(f"SOURCE {kind}: {e}") from e
    plan.source_spec = cfg
    return plan


def unparse_source(plan: IngestPlan) -> str:
    """The textual ``SOURCE kind(...)`` statement equivalent to the plan's
    source spec (parse -> unparse -> parse is stable)."""
    cfg = getattr(plan, "source_spec", None)
    if not cfg:
        raise LanguageError("plan has no source spec to unparse")
    kind = cfg["kind"]

    def fmt(v: Any) -> str:
        # field tuples unparse back to the a|b form the parser reads
        if isinstance(v, (tuple, list)):
            return "|".join(str(x) for x in v)
        return str(v)

    args = ", ".join(f"{k}={fmt(v)}" for k, v in cfg.items() if k != "kind")
    return f"SOURCE {kind}({args});"


def unparse_stream(plan: IngestPlan) -> str:
    """The textual ``STREAM WITH EPOCHS(...)`` statement equivalent to the
    plan's stream config (parse -> unparse -> parse is stable: the language
    round-trip test rides this)."""
    cfg = getattr(plan, "stream_config", None)
    if not cfg:
        raise LanguageError("plan has no stream config to unparse")
    order = ("items", "seconds", "bytes", "capacity", "adaptive")
    args = ", ".join(f"{k}={int(coerce_bool(cfg[k])) if k == 'adaptive' else cfg[k]}"
                     for k in order if k in cfg)
    return f"STREAM WITH EPOCHS({args});"


# ---------------------------------------------------------------- text parser
_STMT_RE = re.compile(r"^\s*(?:(\w+)\s*=\s*)?(SELECT|FORMAT|STORE|CREATE\s+STAGE|"
                      r"CHAIN\s+STAGE|STREAM|FEED|SOURCE)\b(.*)$",
                      re.IGNORECASE | re.DOTALL)


class LanguageError(ValueError):
    pass


def _parse_size(tok: str) -> int:
    m = re.fullmatch(r"(\d+)(kb|mb|gb)?", tok.lower())
    if not m:
        raise LanguageError(f"bad size literal {tok!r}")
    mult = {"kb": 1 << 10, "mb": 1 << 20, "gb": 1 << 30, None: 1}[m.group(2)]
    return int(m.group(1)) * mult


def _parse_value(tok: str) -> Any:
    tok = tok.strip()
    for cast in (int, float):
        try:
            return cast(tok)
        except ValueError:
            pass
    return tok.strip("'\"")


def _parse_where(clause: str) -> Dict[str, Any]:
    """WHERE l_op=v, l_op2=v2 (label predicates; l_ prefix optional)."""
    preds: Dict[str, Any] = {}
    for part in clause.split(","):
        part = part.strip()
        if not part:
            continue
        m = re.fullmatch(r"(?:l_)?(\w+)\s*(=|==|>|<|>=|<=)\s*(.+)", part)
        if not m:
            raise LanguageError(f"bad predicate {part!r}")
        key, op, val = m.group(1), m.group(2), _parse_value(m.group(3))
        if op in ("=", "=="):
            preds[key] = val
        else:
            import operator as _o
            fn = {">": _o.gt, "<": _o.lt, ">=": _o.ge, "<=": _o.le}[op]
            preds[key] = (lambda have, _fn=fn, _v=val:
                          have is not None and _fn(have, _v))
    return preds


class LanguageSession:
    """Parses ingestion-language text into an IngestPlan.

    ``env`` provides named runtime objects referenced from the text:
    predicates/custom operators (by name), and DataStore targets for
    ``UPLOAD TO <name>``.
    """

    def __init__(self, plan: Optional[IngestPlan] = None,
                 env: Optional[Dict[str, Any]] = None) -> None:
        self.plan = plan or IngestPlan("scripted")
        self.env = env or {}
        self.feeds: List[FeedSpec] = []   # FEED ... INTO declarations

    # ---- operator spec resolution: registry key, env object, or inline args
    def _resolve(self, key: str, **kw: Any) -> IngestOp:
        if key in self.env:
            obj = self.env[key]
            if isinstance(obj, IngestOp):
                return obj.clone()
            return resolve_op("map", fn=obj) if callable(obj) else resolve_op(key, **kw)
        return resolve_op(key, **kw)

    def execute(self, text: str) -> IngestPlan:
        for raw in [s for s in text.split(";") if s.strip()]:
            self._statement(raw.strip())
        return self.plan

    # ------------------------------------------------------------- statements
    def _statement(self, text: str) -> None:
        m = _STMT_RE.match(text)
        if not m:
            raise LanguageError(f"cannot parse statement: {text!r}")
        sid, verb, rest = m.group(1), re.sub(r"\s+", " ", m.group(2).upper()), m.group(3)
        rest = re.sub(r"\s+", " ", rest).strip()
        if verb == "SELECT":
            self._select(sid, rest)
        elif verb == "FORMAT":
            self._format(sid, rest)
        elif verb == "STORE":
            self._store(sid, rest)
        elif verb == "CREATE STAGE":
            self._create_stage(rest)
        elif verb == "CHAIN STAGE":
            self._chain_stage(rest)
        elif verb == "STREAM":
            self._stream(rest)
        elif verb == "SOURCE":
            self._source(rest)
        elif verb == "FEED":
            self._feed(rest)

    def _select(self, sid: Optional[str], rest: str) -> None:
        m = re.match(r"(?P<proj>.+?)\s+FROM\s+(?P<src>\w+)"
                     r"(?:\s+USING\s+(?P<parser>\w+))?"
                     r"(?:\s+WHERE\s+(?P<filter>\w+))?"
                     r"(?:\s+REPLICATE\s+BY\s+(?P<rep>\w+))?$", rest, re.IGNORECASE)
        if not m:
            raise LanguageError(f"bad SELECT: {rest!r}")
        ops: List[IngestOp] = []
        parser = m.group("parser")
        ops.append(self._resolve(parser) if parser else resolve_op("identity_parser"))
        if m.group("filter"):
            f = self.env.get(m.group("filter"))
            if f is None:
                raise LanguageError(f"unknown filter {m.group('filter')!r}")
            ops.append(f.clone() if isinstance(f, IngestOp)
                       else resolve_op("filter", predicate=f))
        proj = m.group("proj").strip()
        if proj != "*":
            fields = tuple(p.strip() for p in proj.split(","))
            ops.append(resolve_op("project", fields=fields))
        rep = m.group("rep")
        if rep:
            if rep.isdigit():
                ops.append(resolve_op("replicate", copies=int(rep),
                                      tag=f"replicate_{sid or 's'}"))
            else:
                ops.append(self._resolve(rep))
        src = m.group("src")
        inputs = [] if src.lower() == "input" else [src]
        self.plan.add_statement(ops, kind="select", sid=sid, inputs=inputs)

    _FORMAT_STEP = re.compile(
        r"(PARTITION\s+BY|CHUNK\s+BY|ORDER\s+BY|PACK\s+BY|SERIALIZE\s+AS|ERASURE\s+BY)\s+"
        r"(\w+)(?:\((?P<args>[^)]*)\))?", re.IGNORECASE)

    def _format(self, sid: Optional[str], rest: str) -> None:
        m = re.match(r"(\w+)\s*(.*)$", rest)
        if not m:
            raise LanguageError(f"bad FORMAT: {rest!r}")
        src, clauses = m.group(1), m.group(2)
        ops: List[IngestOp] = []
        for sm in self._FORMAT_STEP.finditer(clauses):
            kind = re.sub(r"\s+", " ", sm.group(1).upper())
            arg = sm.group(2)
            kwargs = self._parse_args(sm.group("args"))
            if kind == "PARTITION BY":
                if arg in self.env:
                    ops.append(self._resolve(arg))
                elif arg.lower() in ("hash", "range", "field", "length"):
                    ops.append(resolve_op("partition", scheme=arg.lower(), **kwargs))
                else:
                    ops.append(resolve_op("partition", key=arg, **kwargs))
            elif kind == "CHUNK BY":
                if re.fullmatch(r"\d+(kb|mb|gb)?", arg.lower()):
                    ops.append(resolve_op("chunk", target_bytes=_parse_size(arg), **kwargs))
                else:
                    ops.append(self._resolve(arg, **kwargs))
            elif kind == "ORDER BY":
                ops.append(resolve_op("order", key=arg, **kwargs))
            elif kind == "PACK BY":
                ops.append(resolve_op("pack", seq_len=int(arg), **kwargs))
            elif kind == "SERIALIZE AS":
                ops.append(self._resolve(arg) if arg in self.env
                           else resolve_op("serialize", layout=arg, **kwargs))
            elif kind == "ERASURE BY":
                k, mm = (int(x) for x in arg.split("x")) if "x" in arg else (int(arg), 2)
                ops.append(resolve_op("erasure", k=k, m=mm, **kwargs))
        self.plan.add_statement(ops, kind="format", sid=sid, inputs=[src])

    @staticmethod
    def _parse_args(argstr: Optional[str]) -> Dict[str, Any]:
        if not argstr:
            return {}
        out: Dict[str, Any] = {}
        for part in argstr.split(","):
            k, _, v = part.partition("=")
            out[k.strip()] = _parse_value(v)
        return out

    def _store(self, sid: Optional[str], rest: str) -> None:
        m = re.match(r"(?P<srcs>[\w\s,]+?)"
                     r"(?:\s+LOCATE\s+USING\s+(?P<loc>\w+)(?:\((?P<locargs>[^)]*)\))?)?"
                     r"(?:\s+UPLOAD\s+TO\s+(?P<target>\w+))?$", rest, re.IGNORECASE)
        if not m:
            raise LanguageError(f"bad STORE: {rest!r}")
        srcs = [s.strip() for s in m.group("srcs").split(",")]
        ops: List[IngestOp] = []
        if m.group("loc"):
            loc = m.group("loc")
            kwargs = self._parse_args(m.group("locargs"))
            if loc in self.env:
                ops.append(self._resolve(loc))
            else:
                scheme = {"disjointlocator": "disjoint", "randomlocator": "random"}.get(
                    loc.lower(), loc.lower())
                ops.append(resolve_op("locate", scheme=scheme, **kwargs))
        if m.group("target"):
            target = self.env.get(m.group("target"))
            if not isinstance(target, DataStore):
                raise LanguageError(f"UPLOAD TO {m.group('target')!r}: not a DataStore in env")
            ops.append(resolve_op("upload", store=target))
        self.plan.add_statement(ops, kind="store", sid=sid, inputs=srcs)

    def _stream(self, rest: str) -> None:
        """STREAM WITH EPOCHS(items=128, seconds=0.5, bytes=4mb, capacity=1024);"""
        m = re.match(r"WITH\s+EPOCHS\s*\((?P<args>[^)]*)\)$", rest, re.IGNORECASE)
        if not m:
            raise LanguageError(f"bad STREAM (want WITH EPOCHS(...)): {rest!r}")
        kwargs = self._parse_args(m.group("args"))
        allowed = {"items", "seconds", "bytes", "capacity", "adaptive"}
        bad = set(kwargs) - allowed
        if bad:
            raise LanguageError(f"STREAM WITH EPOCHS: unknown knobs {sorted(bad)} "
                                f"(allowed: {sorted(allowed)})")
        if not kwargs:
            raise LanguageError("STREAM WITH EPOCHS: give at least one of "
                                f"{sorted(allowed)}")
        if isinstance(kwargs.get("bytes"), str):
            kwargs["bytes"] = _parse_size(kwargs["bytes"])   # "4mb" literals
        with_epochs(self.plan, **kwargs)

    def _source(self, rest: str) -> None:
        """SOURCE files(paths='/data/*.csv', shard_bytes=4mb, fields=a|b);
        — declares a worker-pull source adapter for the plan (ISSUE 6).
        Kinds come from the source registry (files, tail, socket,
        generator, plus any ``register_source`` extras)."""
        m = re.match(r"(\w+)\s*\((?P<args>[^)]*)\)$", rest, re.IGNORECASE)
        if not m:
            raise LanguageError(
                f"bad SOURCE (want SOURCE kind(...), kinds: "
                f"{sorted(SOURCE_KINDS)}): {rest!r}")
        kwargs = self._parse_args(m.group("args"))
        if isinstance(kwargs.get("shard_bytes"), str):
            kwargs["shard_bytes"] = _parse_size(kwargs["shard_bytes"])
        if isinstance(kwargs.get("fields"), str):
            # a|b|c — commas are the argument separator in this surface
            kwargs["fields"] = tuple(
                f.strip() for f in kwargs["fields"].split("|") if f.strip())
        with_source(self.plan, m.group(1), **kwargs)

    def _feed(self, rest: str) -> None:
        """FEED <source> INTO plan1, plan2[, ...];  — plan names are IngestPlan
        objects in env (the feed joint: one ingest fanned into many plans)."""
        m = re.match(r"(\w+)\s+INTO\s+([\w\s,]+)$", rest, re.IGNORECASE)
        if not m:
            raise LanguageError(f"bad FEED (want FEED <source> INTO p1, p2): {rest!r}")
        names = [s.strip() for s in m.group(2).split(",") if s.strip()]
        if len(names) < 1:
            raise LanguageError("FEED ... INTO needs at least one plan")
        plans: List[IngestPlan] = []
        for name in names:
            target = self.env.get(name)
            if not isinstance(target, IngestPlan):
                raise LanguageError(
                    f"FEED INTO {name!r}: not an IngestPlan in env")
            plans.append(target)
        self.feeds.append(FeedSpec(source=m.group(1), plan_names=names,
                                   plans=plans))

    def _create_stage(self, rest: str) -> None:
        m = re.match(r"(\w+)\s+USING\s+([\w\s,]+?)(?:\s+WHERE\s+(.*))?$", rest, re.IGNORECASE)
        if not m:
            raise LanguageError(f"bad CREATE STAGE: {rest!r}")
        using = [s.strip() for s in m.group(2).split(",")]
        where = _parse_where(m.group(3)) if m.group(3) else {}
        self.plan.create_stage(using, where, name=m.group(1))

    def _chain_stage(self, rest: str) -> None:
        m = re.match(r"(\w+)\s+TO\s+([\w\s,]+?)\s+USING\s+([\w\s,]+?)"
                     r"(?:\s+WHERE\s+(.*))?$", rest, re.IGNORECASE)
        if not m:
            raise LanguageError(f"bad CHAIN STAGE: {rest!r}")
        to = [s.strip() for s in m.group(2).split(",")]
        using = [s.strip() for s in m.group(3).split(",")]
        where = _parse_where(m.group(4)) if m.group(4) else {}
        self.plan.chain_stage(to, using, where, name=m.group(1))


def parse_ingestion_script(text: str, env: Optional[Dict[str, Any]] = None) -> IngestPlan:
    return LanguageSession(env=env).execute(text)


def parse_feed_script(text: str, env: Optional[Dict[str, Any]] = None) -> List[FeedSpec]:
    """Parse a script of ``FEED ... INTO ...`` statements (plans in ``env``)
    and return the declared feed joints."""
    session = LanguageSession(env=env)
    session.execute(text)
    if not session.feeds:
        raise LanguageError("script declared no FEED ... INTO statements")
    return session.feeds
