"""Deterministic chaos harness (ISSUE 8): seeded fault plans + soak runs.

Fault machinery accreted in three disconnected dialects: the batch
engine's :class:`~repro.core.runtime.FaultInjection` (op failures, death
after a named stage), the streaming engine's
:class:`~repro.core.streaming.StreamFaultInjection` (op failures, death
keyed to an epoch index), and the raw per-operator ``_fail_next`` test
counter.  Each chaos test hand-rolled its own schedule, so no two
exercised the same interleavings and none composed kill + hang + garble
in one run.

This module puts one seeded DSL over all of them.  A :class:`ChaosPlan`
is a schedule of :class:`ChaosEvent`\\ s keyed to **epoch · stage ·
node** — generated deterministically from a seed, so a failing soak run
reproduces from its seed alone — and *renders* into whichever hook a
runtime consumes:

* ``stream_faults()`` -> ``StreamFaultInjection`` (kills become
  ``node_death_at`` placements; garbles become ``op_failures``);
* ``batch_faults()`` -> ``FaultInjection`` for the batch engine;
* ``arm_fail_next()`` drives the legacy per-operator counter;
* ``ChaosController`` fires the events that must be *real OS signals*
  (SIGSTOP hangs, coordinator-side delays) from the exchange manifest
  hook, at exactly the scheduled epoch·stage·node.

:func:`chaos_soak` is the regression entry point every later multi-host
PR runs against: N chaotic epochs on a backend, then the full
exactly-once audit — committed epoch ids gap-free, every input row read
back exactly once, ``gc_orphans()`` empty, no leaked shared-memory
segments or exchange spill files.  ``python -m repro.core.chaos`` runs it
from CI (see nightly.yml).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import random
import sys
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .items import IngestItem
from .operators import resolve_op
from .plan import IngestPlan, StagePlan
from .runtime import FaultInjection
from .streaming import StreamFaultInjection

KINDS = ("kill", "hang", "delay", "garble",
         "drop", "delay_conn", "partition")
#: the kinds rendered on the socket fabric's ChaosProxy shim (ISSUE 9) —
#: they need a real network pair to act on: process backend + socket
#: transport only
NET_KINDS = ("drop", "delay_conn", "partition")


@dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault, keyed to epoch index · stage name · node.

    ``kill``   — the node dies right after ``stage`` completes in epoch
                 ``epoch`` (rendered as an injected death for both
                 backends; deterministic by construction).
    ``hang``   — SIGSTOP the node's worker at the moment its ``stage``
                 manifest lands in ``epoch`` (process backend only; needs
                 the heartbeat monitor armed to be observed).
    ``delay``  — stall the coordinator's manifest handling for
                 ``seconds`` at the keyed point (a slow node, simulated).
    ``garble`` — operator ``op_index`` of ``stage`` raises
                 ``OperatorFailure`` ``count`` times (absorbed by
                 retry-from-checkpoint while ``count < max_retries``).

    Network events (ISSUE 9, socket transport only — rendered on the
    ChaosProxy shim in front of each worker's socket pair):

    ``drop``       — discard ``count`` * 64 bytes mid-stream on the node's
                     worker->coordinator direction: the next frame fails
                     CRC/magic (FrameError -> WorkerDeath), so this is
                     *lethal* and draws from the same victim budget as
                     kills.
    ``delay_conn`` — one-shot ``seconds`` forwarding stall on the node's
                     link (a slow network, simulated; non-lethal as long
                     as it stays under the liveness miss window).
    ``partition``  — the link to every worker of ``host`` goes silent in
                     both directions at the keyed epoch·stage: heartbeats
                     die together and the liveness monitor's per-host
                     quorum declares the host partitioned as a unit
                     (``node`` is unused — the host is the victim).
    """

    kind: str
    epoch: int
    stage: str
    node: str
    op_index: int = 0
    count: int = 1
    seconds: float = 0.0
    host: str = ""

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}")
        if self.kind == "partition" and not self.host:
            raise ValueError("partition events need a host")


@dataclass
class ChaosPlan:
    """A seeded schedule of chaos events plus its renderers."""

    events: List[ChaosEvent] = field(default_factory=list)
    seed: int = 0

    @classmethod
    def generate(cls, seed: int, *, epochs: int, nodes: Sequence[str],
                 stages: Sequence[str], kills: int = 1, hangs: int = 0,
                 delays: int = 2, garbles: int = 2,
                 delay_s: float = 0.05,
                 max_dead: Optional[int] = None,
                 partitions: int = 0, drops: int = 0,
                 conn_delays: int = 0,
                 hosts: Optional[Dict[str, str]] = None) -> "ChaosPlan":
        """Deterministically draw a schedule from ``seed``.

        Kills (and hangs — a hang becomes a death once liveness declares
        it) pick *distinct* victims, at most ``max_dead`` of them
        (default: all but two nodes stay alive, so the stream always has
        survivors to replay on).  Garbles keep per-(stage, op) counts
        below the runtime's default ``max_retries`` so they are absorbed
        by retry, never by dummy substitution — a substituted operator
        would silently drop rows and break the exactly-once audit the
        soak exists to run.

        Network events (ISSUE 9) need ``hosts`` (node -> host label) and a
        socket-transport run to render.  A ``partition`` kills a whole
        host, so it is budgeted first — every member counts against
        ``max_dead``, and a host whose loss would leave no survivors is
        skipped.  ``drops`` are lethal too (a garbled stream is a dead
        worker) and share the same distinct-victim pool as kills/hangs;
        ``conn_delays`` are benign slow-link stalls."""
        rng = random.Random(seed)
        nodes = list(nodes)
        stages = list(stages)
        if max_dead is None:
            max_dead = max(0, len(nodes) - 2)
        events: List[ChaosEvent] = []
        budget = max_dead
        parted_hosts: List[str] = []
        if partitions and hosts:
            by_host: Dict[str, List[str]] = {}
            for n in nodes:
                if hosts.get(n):
                    by_host.setdefault(hosts[n], []).append(n)
            cand = sorted(by_host)
            rng.shuffle(cand)
            for h in cand[:partitions]:
                members = by_host[h]
                if len(members) > budget or len(members) >= len(nodes):
                    continue   # would starve the survivors
                budget -= len(members)
                parted_hosts.append(h)
                events.append(ChaosEvent(
                    kind="partition", epoch=rng.randrange(epochs),
                    stage=rng.choice(stages), node="", host=h))
        # lethal point faults share one distinct-victim pool, drawn from
        # nodes OUTSIDE partitioned hosts (those die as a unit already)
        pool = [n for n in nodes
                if not hosts or hosts.get(n) not in parted_hosts]
        lethal = min(kills + hangs + drops, budget, len(pool))
        victims = rng.sample(pool, lethal) if lethal > 0 else []
        for i, victim in enumerate(victims):
            # hangs schedule first, then drops: when max_dead clips the
            # lethal budget the rarer events (SIGSTOP + liveness
            # declaration; garbled-frame death) must survive the clip
            if i < min(hangs, lethal):
                kind = "hang"
            elif i < min(hangs + drops, lethal):
                kind = "drop"
            else:
                kind = "kill"
            events.append(ChaosEvent(
                kind=kind, epoch=rng.randrange(epochs),
                stage=rng.choice(stages), node=victim))
        for _ in range(conn_delays):
            events.append(ChaosEvent(
                kind="delay_conn", epoch=rng.randrange(epochs),
                stage=rng.choice(stages), node=rng.choice(nodes),
                seconds=delay_s))
        for _ in range(delays):
            events.append(ChaosEvent(
                kind="delay", epoch=rng.randrange(epochs),
                stage=rng.choice(stages), node=rng.choice(nodes),
                seconds=delay_s))
        garble_budget: Dict[Tuple[str, int], int] = {}
        for _ in range(garbles):
            key = (rng.choice(stages), 0)
            if garble_budget.get(key, 0) >= 2:   # < max_retries default (3)
                continue
            garble_budget[key] = garble_budget.get(key, 0) + 1
            events.append(ChaosEvent(
                kind="garble", epoch=rng.randrange(epochs),
                stage=key[0], node=rng.choice(nodes), op_index=key[1]))
        events.sort(key=lambda e: (e.epoch, e.stage, e.kind, e.node))
        return cls(events=events, seed=seed)

    # -------------------------------------------------------------- renderers
    def stream_faults(self, backend: str = "thread") -> StreamFaultInjection:
        """Render for the streaming engine.  Kills become precise
        ``node_death_at`` placements; on the thread backend hangs render as
        kills too (a thread cannot be SIGSTOP'd independently — the
        injected death is the closest deterministic equivalent).  Garbles
        land in the shared ``op_failures`` map."""
        sf = StreamFaultInjection()
        for ev in self.events:
            if ev.kind == "kill" or (ev.kind == "hang"
                                     and backend != "process"):
                sf.node_death_at[(ev.node, ev.epoch)] = ev.stage
            elif ev.kind == "garble":
                key = (ev.stage, ev.op_index)
                sf.op_failures[key] = sf.op_failures.get(key, 0) + ev.count
        return sf

    def batch_faults(self) -> FaultInjection:
        """Render for the batch engine (no epochs: the first kill becomes a
        death after its stage, garbles map unchanged)."""
        bf = FaultInjection()
        for ev in self.events:
            if ev.kind in ("kill", "hang"):
                bf.node_death_after_stage.setdefault(ev.node, ev.stage)
            elif ev.kind == "garble":
                key = (ev.stage, ev.op_index)
                bf.op_failures[key] = bf.op_failures.get(key, 0) + ev.count
        return bf

    def arm_fail_next(self, stage_plans: Sequence[StagePlan]) -> int:
        """Drive the legacy per-operator ``_fail_next`` counters from the
        same schedule (for harnesses that bypass the engines' injection
        plumbing).  Returns how many operators were armed."""
        armed = 0
        by_stage = {sp.name: sp for sp in stage_plans}
        for ev in self.events:
            if ev.kind != "garble":
                continue
            sp = by_stage.get(ev.stage)
            if sp is not None and ev.op_index < len(sp.ops):
                sp.ops[ev.op_index]._fail_next += ev.count
                armed += 1
        return armed

    def signal_events(self, backend: str,
                      transport: str = "pipe") -> List[ChaosEvent]:
        """The events a :class:`ChaosController` must fire as real OS
        signals / coordinator stalls: delays always, hangs only where a
        worker process exists to stop, network events only where a
        ChaosProxy shim exists to render them (process + socket)."""
        out = [e for e in self.events if e.kind == "delay"]
        if backend == "process":
            out += [e for e in self.events if e.kind == "hang"]
            if transport == "socket":
                out += [e for e in self.events if e.kind in NET_KINDS]
        return out


class ChaosController:
    """Fires a plan's real-signal events from the exchange manifest hook.

    ``attach()`` wraps ``engine.shuffle.test_on_manifest``; every manifest
    arrival is matched against the plan's unfired signal events by
    (epoch index, producing stage, producer node) and fired at most once:
    ``hang`` SIGSTOPs that node's worker (the pipe stays open — only the
    heartbeat monitor can notice), ``delay`` sleeps the coordinator's
    manifest path.  Network events render on the executors' ChaosProxy
    shims: ``drop`` garbles a node's stream (lethal), ``delay_conn``
    stalls its link, ``partition`` silences every executor whose host
    matches (the partition matches on epoch·stage + *host*, not node —
    any member's manifest at the keyed point trips it).  ``detach()``
    restores the previous hook."""

    def __init__(self, plan: ChaosPlan, engine: Any, base_eid: int = 0,
                 backend: Optional[str] = None,
                 transport: Optional[str] = None) -> None:
        self.engine = engine
        self.base_eid = base_eid
        backend = backend or getattr(engine, "backend", "thread")
        transport = transport or getattr(engine, "transport", "pipe")
        self._pending = list(plan.signal_events(backend, transport))
        self.fired: List[ChaosEvent] = []
        self._prev_hook: Any = None
        self._attached = False

    def attach(self) -> "ChaosController":
        if not self._attached:
            self._prev_hook = self.engine.shuffle.test_on_manifest
            self.engine.shuffle.test_on_manifest = self._on_manifest
            self._attached = True
        return self

    def detach(self) -> None:
        if self._attached:
            self.engine.shuffle.test_on_manifest = self._prev_hook
            self._attached = False

    def _on_manifest(self, rnd: Any, node: str) -> None:
        idx = rnd.epoch - self.base_eid
        hosts = getattr(self.engine, "node_hosts", {}) or {}
        for ev in list(self._pending):
            if ev.kind == "partition":
                # host-keyed: any member of the host reaching the keyed
                # epoch·stage trips the whole-host silence
                if (ev.epoch, ev.stage) != (idx, rnd.stage) or \
                        hosts.get(node) != ev.host:
                    continue
            elif (ev.epoch, ev.stage, ev.node) != (idx, rnd.stage, node):
                continue
            self._pending.remove(ev)
            self.fired.append(ev)
            if ev.kind == "hang":
                ex = self.engine.executor(ev.node)
                hang = getattr(ex, "hang", None)
                if hang is not None:
                    hang()
            elif ev.kind == "delay":
                time.sleep(ev.seconds)
            elif ev.kind == "partition":
                for n, h in hosts.items():
                    if h != ev.host:
                        continue
                    part = getattr(self.engine.executor(n),
                                   "net_partition", None)
                    if part is not None:
                        part()
            elif ev.kind == "drop":
                drop = getattr(self.engine.executor(ev.node),
                               "net_drop", None)
                if drop is not None:
                    drop(64 * ev.count)
            elif ev.kind == "delay_conn":
                dly = getattr(self.engine.executor(ev.node),
                              "net_delay", None)
                if dly is not None:
                    dly(ev.seconds)
        if self._prev_hook is not None:
            self._prev_hook(rnd, node)


# ---------------------------------------------------------------------------
# Soak entry point
# ---------------------------------------------------------------------------
@dataclass
class SoakResult:
    """One chaos-soak run's audit: inputs vs. committed outputs + leaks."""

    backend: str
    seed: int
    epochs_committed: int
    rows_in: int
    rows_out: int
    node_failures: int
    cone_replays: int
    replayed_rows: int
    liveness_deaths: int
    orphans: List[str]
    shm_leaked: List[str]
    spill_leaked: List[str]
    errors: List[str]
    wall_s: float
    # socket fabric (ISSUE 9) — defaults keep older callers' positional
    # construction working
    transport: str = "pipe"
    host_partitions: int = 0
    degraded_rounds: int = 0
    partitions_fired: int = 0

    @property
    def ok(self) -> bool:
        return (not self.errors and not self.orphans and not self.shm_leaked
                and not self.spill_leaked and self.rows_in == self.rows_out)

    def to_json(self) -> Dict[str, Any]:
        d = dict(self.__dict__)
        d["ok"] = self.ok
        return d


def _soak_plan(store: Any) -> IngestPlan:
    """The soak's 3-stage narrow pipeline (parse -> chunk+serialize ->
    upload): cone-capable by construction, so kills exercise lineage-cone
    replay and everything else falls back to whole-epoch replay."""
    p = IngestPlan("chaos-soak")
    s1 = p.add_statement([resolve_op("identity_parser")], kind="select")
    s2 = p.add_statement([resolve_op("chunk", target_rows=256),
                          resolve_op("serialize", layout="columnar")],
                         kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=store)],
                         kind="store", inputs=[s2])
    p.create_stage(using=[s1], name="a")
    p.chain_stage(to=["a"], using=[s2], name="b")
    p.chain_stage(to=["b"], using=[s3], name="c")
    return p


def _shm_segments() -> set:
    return set(glob.glob("/dev/shm/psm_*"))


def chaos_soak(backend: str = "thread", seed: int = 9, epochs: int = 20,
               rows_per_shard: int = 40, epoch_items: int = 4,
               nodes: int = 4, kills: int = 2, hangs: Optional[int] = None,
               delays: int = 2, garbles: int = 2,
               heartbeat_interval_s: float = 0.05, heartbeat_miss: int = 3,
               root: Optional[str] = None, transport: str = "pipe",
               partitions: int = 0, drops: int = 0,
               conn_delays: int = 0) -> SoakResult:
    """Run ``epochs`` chaotic epochs on ``backend`` and audit the result.

    Deterministic given (seed, backend, scale): the chaos schedule, the
    input rows, and the epoch cuts all derive from the arguments.  Hangs
    default to 1 on the process backend (where SIGSTOP is real and the
    heartbeat monitor — armed here — must declare the death) and 0 on the
    thread backend (they render as kills anyway).

    ``transport="socket"`` (process backend only) runs the workers on the
    framed TCP fabric behind ChaosProxy shims, splits the nodes across
    two simulated hosts (so the shuffle crosses a "network" boundary and
    exercises the degraded streamed exchange), and enables the network
    event kinds: ``partitions`` whole-host silences, ``drops`` lethal
    stream garbles, ``conn_delays`` benign link stalls."""
    from .access import DataAccess
    from .store import DataStore
    from .streaming import StreamingRuntimeEngine
    from repro.data.generators import gen_lineitem

    if transport == "socket" and backend != "process":
        raise ValueError("socket transport needs the process backend "
                         f"(got backend={backend!r})")
    if hangs is None:
        hangs = 1 if backend == "process" else 0
    node_names = [f"n{i}" for i in range(nodes)]
    node_hosts: Dict[str, str] = {}
    if transport == "socket":
        # two simulated hosts: first half on hostA, rest on hostB — the
        # shuffle between them rides the degraded streamed exchange, and a
        # partition can take out either side while the other survives
        node_hosts = {n: ("hostA" if i < len(node_names) // 2 else "hostB")
                      for i, n in enumerate(node_names)}
    else:
        partitions = drops = conn_delays = 0
    n_shards = epochs * epoch_items
    shards = [IngestItem(gen_lineitem(rows_per_shard, seed=seed * 10007 + i))
              for i in range(n_shards)]
    rows_in = sum(it.nrows() for it in shards)

    tmp = None
    if root is None:
        tmp = tempfile.TemporaryDirectory(prefix="chaos-soak-")
        root = tmp.name
    t0 = time.time()
    errors: List[str] = []
    shm_before = _shm_segments()
    store = DataStore(os.path.join(root, f"store-{backend}-{seed}"),
                     nodes=node_names)
    plan = _soak_plan(store)
    stage_names = ["a", "b"]   # the terminal store stage produces no round
    cplan = ChaosPlan.generate(seed, epochs=epochs, nodes=node_names,
                               stages=stage_names, kills=kills, hangs=hangs,
                               delays=delays, garbles=garbles,
                               partitions=partitions, drops=drops,
                               conn_delays=conn_delays,
                               hosts=node_hosts or None)
    eng = StreamingRuntimeEngine(
        store, epoch_items=epoch_items, backend=backend,
        heartbeat_interval_s=(heartbeat_interval_s
                              if backend == "process" else None),
        heartbeat_miss=heartbeat_miss, transport=transport,
        node_hosts=node_hosts or None,
        network_chaos=(transport == "socket"))
    controller = ChaosController(cplan, eng, base_eid=store.next_epoch_id(),
                                 backend=backend, transport=transport).attach()
    rep = None
    try:
        rep = eng.run_stream(plan, iter(shards),
                             faults=cplan.stream_faults(backend))
    except BaseException as e:
        errors.append(f"{type(e).__name__}: {e}")
    finally:
        controller.detach()
        eng.close()

    rows_out = 0
    committed: List[int] = []
    n_failures = cone = replayed = live_deaths = 0
    host_parts = degraded = 0
    orphans: List[str] = []
    spill_leaked: List[str] = []
    parts_fired = sum(1 for e in controller.fired if e.kind == "partition")
    parts_planned = sum(1 for e in cplan.events if e.kind == "partition")
    if partitions and not parts_planned:
        errors.append("partition requested but none fit the victim budget")
    if parts_planned and not parts_fired:
        errors.append("planned partition never fired")
    if rep is not None:
        committed = rep.committed_epoch_ids()
        if committed and committed != list(range(committed[0],
                                                 committed[0] + len(committed))):
            errors.append(f"epoch ids not gap-free: {committed}")
        if len(committed) != epochs:
            errors.append(f"committed {len(committed)}/{epochs} epochs")
        n_failures = len(rep.node_failures)
        cone = rep.cone_replays()
        replayed = rep.replayed_rows()
        live_deaths = len(rep.liveness_deaths)
        host_parts = len(rep.host_partitions)
        degraded = rep.degraded_exchange_rounds()
        if parts_fired and not host_parts:
            errors.append("partition fired but liveness never declared "
                          "a host as a unit")
        try:
            rows_out = len(DataAccess(store).since_epoch(-1).read_all(
                projection=["quantity"])["quantity"])
        except BaseException as e:
            errors.append(f"read-back failed: {type(e).__name__}: {e}")
        orphans = store.gc_orphans()
        for dirpath, _dirs, files in os.walk(store.dfs_dir):
            spill_leaked.extend(os.path.join(dirpath, f) for f in files)
    shm_leaked = sorted(_shm_segments() - shm_before)

    result = SoakResult(
        backend=backend, seed=seed, epochs_committed=len(committed),
        rows_in=rows_in, rows_out=rows_out, node_failures=n_failures,
        cone_replays=cone, replayed_rows=replayed,
        liveness_deaths=live_deaths, orphans=orphans,
        shm_leaked=shm_leaked, spill_leaked=spill_leaked, errors=errors,
        wall_s=round(time.time() - t0, 3), transport=transport,
        host_partitions=host_parts, degraded_rounds=degraded,
        partitions_fired=parts_fired)
    if tmp is not None:
        tmp.cleanup()
    return result


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="seeded chaos soak: N chaotic epochs + exactly-once audit")
    ap.add_argument("--backend", default="both",
                    choices=["thread", "process", "both"])
    # default seed chosen so the schedule exercises BOTH recovery roads:
    # one kill after the segment's last ingest stage (lineage-cone replay)
    # and one mid-segment (whole-epoch fallback)
    ap.add_argument("--seed", type=int, default=9)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--rows", type=int, default=40,
                    help="rows per source shard")
    ap.add_argument("--kills", type=int, default=2)
    ap.add_argument("--delays", type=int, default=2)
    ap.add_argument("--garbles", type=int, default=2)
    ap.add_argument("--transport", default="pipe",
                    choices=["pipe", "socket"])
    ap.add_argument("--partitions", type=int, default=None,
                    help="whole-host partition events "
                         "(default: 1 on socket, 0 on pipe)")
    ap.add_argument("--drops", type=int, default=0,
                    help="lethal mid-stream byte drops (socket only)")
    ap.add_argument("--conn-delays", type=int, default=0,
                    help="benign link stalls (socket only)")
    args = ap.parse_args(argv)
    backends = (["thread", "process"] if args.backend == "both"
                else [args.backend])
    if args.transport == "socket":
        # the socket fabric only exists on the process backend
        backends = ["process"]
    partitions = args.partitions
    if partitions is None:
        partitions = 1 if args.transport == "socket" else 0
    results = [chaos_soak(backend=b, seed=args.seed, epochs=args.epochs,
                          rows_per_shard=args.rows, kills=args.kills,
                          delays=args.delays, garbles=args.garbles,
                          transport=args.transport, partitions=partitions,
                          drops=args.drops, conn_delays=args.conn_delays)
               for b in backends]
    print(json.dumps([r.to_json() for r in results], indent=2))
    return 0 if all(r.ok for r in results) else 1


if __name__ == "__main__":
    sys.exit(main())
