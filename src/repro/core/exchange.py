"""Worker-side shuffle data plane: peer-to-peer partition exchange.

The shuffle used to route every group through the coordinator: stage outputs
returned to it, were grouped there, and shipped back out to their target
nodes — so shuffle-heavy plans serialized on one pipe no matter how many
node workers existed.  This module is the *data plane* of the decentralized
shuffle (DESIGN.md §4): after a shuffle-boundary stage each node worker
partitions its own output by the routing key and hands partitions directly
to peer workers; the coordinator (``runtime.ShuffleCoordinator``) sees only
partition *manifests* — stage, epoch, counts, sizes, segment/file refs —
never item bytes.

Shared by both node backends:

* :func:`partition_items` — deterministic group->node assignment via a
  process-stable hash of the routing-key label, so every worker computes a
  group's target without global knowledge of the group set (Python's own
  ``hash`` is salted per process and would make peers disagree).
* :func:`encode_partition` / :func:`decode_partition` — the process
  backend's per-edge medium.  Same protocol-5 packing as
  ``items.encode_items``, but the pickle *meta stream rides inside the
  shared-memory segment* too: the manifest the coordinator relays carries
  only the segment name and an offset table, so zero item bytes cross the
  coordinator pipes.
* :func:`write_partition_file` / :func:`read_partition_file` — oversized
  partitions cross as peer-readable spill files under the store's DFS dir
  (consume-on-read).  The ``DataStore`` leases live rounds' files so
  ``gc_orphans`` can tell them from a crashed epoch's leftovers.
* :class:`PartitionExchange` — the node-side partition buffer.  The thread
  backend shares one instance across all node executors (deposits are the
  direct in-memory queue handoff); each process-backend worker hosts its
  own, holding the partitions addressed to itself and decoded
  multi-consumer batches.  Buckets carry refcounted ``ShmLease`` shares so
  the segment a resident partition aliases dies exactly when its last
  consumer finishes.
"""
from __future__ import annotations

import os
import pickle
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .items import (ColumnarBatch, IngestItem, ShmLease, _materialize_item,
                    create_segment)

#: manifest/file naming shared with DataStore.gc_orphans
EXCHANGE_PREFIX = "exchange_"
#: resident-bucket spills (narrow edges: a stage output pinned on its own
#: node that exceeded the per-edge memory share) — same GC family
RESIDENT_PREFIX = "resident_"
#: columnar partition spills (ISSUE 10): a ColumnarBatch written as
#: header + raw column buffer instead of a per-item pickle stream
COLUMNAR_PREFIX = "columnar_"
EXCHANGE_SUFFIX = ".part"

#: file magic of a columnar spill — ``read_partition_file`` sniffs it, so
#: every scalar call site decodes either format transparently
COLUMNAR_MAGIC = b"ICOLPART1\n"


def stable_group_hash(value: Any) -> int:
    """Process-stable hash of a routing-group value.

    Labels that compare equal must hash equal — the legacy barrier grouped
    by dict equality, so ``True``/``1``/``1.0``/``np.int64(1)`` are one
    group and must land on one node here too: any integral numeric maps
    through its integer value (which also spreads small partition counts
    evenly).  Strings/bytes hash their content; sets hash their *sorted*
    element reprs (a set's iteration order rides the per-process string
    hash salt).  Everything else falls back to crc32 of ``repr``, which
    requires the label type to have a process-stable repr — ints, strings,
    and tuples thereof, which is what partition/dedup operators emit; a
    default object repr (memory address) would make peers disagree, just
    as it would have broken the legacy barrier's ``sorted(key=str)``.
    Never use Python's ``hash`` — it is salted per process, and peer
    workers must agree on every group's target."""
    try:
        i = int(value)
        if i == value:
            return i & 0x7FFFFFFF
    except (TypeError, ValueError, OverflowError):
        pass
    if isinstance(value, str):
        return zlib.crc32(value.encode())
    if isinstance(value, (bytes, bytearray)):
        return zlib.crc32(bytes(value))
    if isinstance(value, (set, frozenset)):
        return zlib.crc32(repr(sorted(map(repr, value))).encode())
    try:
        return zlib.crc32(repr(value).encode())
    except Exception:
        return 0


def partition_items(items: Sequence[IngestItem], key: str,
                    targets: Sequence[str]) -> Dict[str, List[IngestItem]]:
    """Split a stage's output by the routing key's label value: every worker
    computes ``targets[stable_hash(group) % len(targets)]`` locally, so the
    same group lands on the same node no matter who produced it."""
    parts: Dict[str, List[IngestItem]] = {t: [] for t in targets}
    n = len(targets)
    for it in items:
        g = it.label_value(key, 0)
        parts[targets[stable_group_hash(g) % n]].append(it)
    return parts


def _hash_column(col: np.ndarray) -> np.ndarray:
    """Vectorized :func:`stable_group_hash` over a label value column —
    must agree with the scalar function bit-for-bit so columnar-on and
    columnar-off runs partition identically.

    Integer/bool dtypes take the int path in one vector op (two's-complement
    ``& 0x7FFFFFFF`` equals Python's ``int(v) & 0x7FFFFFFF``); unicode
    columns hash each *unique* string once; everything else (floats may be
    integral and object columns may hold anything) goes through the scalar
    function per value."""
    if col.dtype.kind in "bui":
        return col.astype(np.int64) & np.int64(0x7FFFFFFF)
    if col.dtype.kind == "U":
        uniq, inv = np.unique(col, return_inverse=True)
        hu = np.array([stable_group_hash(u.item()) for u in uniq],
                      dtype=np.int64)
        return hu[inv]
    return np.array([stable_group_hash(v.item()
                                       if isinstance(v, np.generic) else v)
                     for v in col], dtype=np.int64)


def partition_batch(batch: ColumnarBatch, key: str, targets: Sequence[str]
                    ) -> Dict[str, ColumnarBatch]:
    """Vectorized twin of :func:`partition_items` over a ColumnarBatch
    (ISSUE 10): one hash pass over the key label column, then an
    order-preserving ``select`` per target — ``np.nonzero`` indices are
    ascending, so each partition keeps the original item order and the
    resulting manifests are byte-identical to the scalar path's."""
    n = len(targets)
    if n == 1:
        # single-target round: the whole batch maps to targets[0] in its
        # original order — hand it through rather than gather-copying it.
        # Callers always build ``batch`` via ``from_items`` (which copies),
        # so the passthrough still owns its payload like a ``select`` would
        return {targets[0]: batch}
    col = batch.label_col(key)
    if col is None:
        # scalar path: label_value(key, 0) defaults missing labels to 0
        pids = np.zeros(len(batch), np.int64)
    else:
        pids = _hash_column(col) % n
    return {t: batch.select(np.nonzero(pids == ti)[0])
            for ti, t in enumerate(targets)}


def build_manifest(out: Sequence[IngestItem], key: Optional[str],
                   targets: Sequence[str],
                   part_fn: Any, self_node: Optional[str] = None
                   ) -> Dict[str, Any]:
    """Partition a stage's output and assemble the metadata-only manifest
    the coordinator relays: ``part_fn(dst, items, nbytes) -> desc`` supplies
    the backend-specific medium (resident / segment / spill file / thread
    bucket) per non-empty partition.  ``key=None`` is a **narrow edge**
    (identity routing, ISSUE 5): the whole output is one partition addressed
    to ``self_node`` — the producer itself — so it stays node-resident.
    Keeping the iteration and manifest shape here means both backends stay
    wire-compatible with ``ShuffleCoordinator.record_manifest`` /
    ``finish_round``.

    ``out`` may also be a :class:`ColumnarBatch` (ISSUE 10): partitioning
    goes through the vectorized :func:`partition_batch` and ``part_fn``
    receives each partition as a sub-batch — same manifest shape, same
    byte accounting (``batch.nbytes == sum(it.nbytes())``)."""
    if key is None:
        if self_node is None:
            raise ValueError("narrow-edge manifest needs the producing node")
        parts: Dict[str, Any] = {
            self_node: out if isinstance(out, ColumnarBatch) else list(out)}
    elif isinstance(out, ColumnarBatch):
        parts = partition_batch(out, key, targets)
    else:
        parts = partition_items(out, key, targets)
    manifest: Dict[str, Any] = {"total_count": len(out), "parts": {}}
    for dst, its in parts.items():
        if not its:
            continue
        nb = (its.nbytes if isinstance(its, ColumnarBatch)
              else sum(it.nbytes() for it in its))
        manifest["parts"][dst] = part_fn(dst, its, nb)
    return manifest


# ---------------------------------------------------------------------------
# Per-edge shared-memory codec (process backend)
# ---------------------------------------------------------------------------
def encode_partition(items: Sequence[IngestItem]
                     ) -> Tuple[Dict[str, Any], ShmLease]:
    """Pack an item batch into ONE shared-memory segment for a peer.

    Unlike ``encode_items`` (whose pickle meta stream rides the pipe), the
    meta stream is appended *inside* the segment, so the returned descriptor
    — what the coordinator relays to the consumer — holds only the segment
    name, the buffer offset table, and sizes: metadata, never item bytes.
    The producer must ``detach()`` the lease once the manifest has been
    delivered; the consumer ``release()``-s (unlink) when done."""
    buffers: List[pickle.PickleBuffer] = []
    meta = pickle.dumps(list(items), protocol=5,
                        buffer_callback=buffers.append)
    views = [b.raw() for b in buffers]
    total = sum(v.nbytes for v in views) + len(meta)
    shm = create_segment(max(total, 1))
    offsets: List[Tuple[int, int]] = []
    off = 0
    for v in views:
        shm.buf[off:off + v.nbytes] = v.cast("B")
        offsets.append((off, v.nbytes))
        off += v.nbytes
    shm.buf[off:off + len(meta)] = meta
    for b in buffers:
        b.release()
    desc = {"kind": "shm", "shm": shm.name, "offsets": offsets,
            "meta": (off, len(meta)), "nbytes": total, "count": len(items)}
    return desc, ShmLease(shm)


def encode_columnar_partition(batch: ColumnarBatch
                              ) -> Tuple[Dict[str, Any], ShmLease]:
    """Columnar twin of :func:`encode_partition` (ISSUE 10): the batch's one
    contiguous column buffer is written straight into the segment — no
    per-item pickling — followed by the pickled batch header.  The
    descriptor carries ``columnar=True`` so ``decode_partition`` dispatches;
    everything the coordinator touches (segment name, sizes, counts) keeps
    the exact shape of the scalar descriptor."""
    header = pickle.dumps(batch.header(), protocol=5)
    pay = np.ascontiguousarray(batch.payload)
    total = pay.nbytes + len(header)
    shm = create_segment(max(total, 1))
    shm.buf[:pay.nbytes] = memoryview(pay).cast("B")
    shm.buf[pay.nbytes:total] = header
    desc = {"kind": "shm", "columnar": True, "shm": shm.name,
            "payload_nbytes": pay.nbytes, "meta": (pay.nbytes, len(header)),
            "nbytes": batch.nbytes, "count": len(batch)}
    return desc, ShmLease(shm)


def decode_partition(desc: Dict[str, Any], copy: bool = False
                     ) -> Tuple[List[IngestItem], Optional[ShmLease]]:
    """Decode a peer partition from its segment descriptor.

    ``copy=False`` returns zero-copy views plus the lease the caller must
    hold while the items are in use and ``release()`` afterwards;
    ``copy=True`` materializes and destroys the segment before returning.

    Columnar descriptors (``columnar=True``) dispatch internally: the items
    come back as views over the batch's column buffer, so every consumer
    call site handles both formats without change."""
    from multiprocessing import shared_memory
    if desc.get("columnar"):
        shm = shared_memory.SharedMemory(name=desc["shm"])
        lease = ShmLease(shm)
        moff, mlen = desc["meta"]
        header = pickle.loads(bytes(shm.buf[moff:moff + mlen]))
        pay = np.frombuffer(shm.buf, np.uint8, count=desc["payload_nbytes"])
        items = ColumnarBatch.from_header(header, pay).to_items()
        if not copy:
            del pay
            return items, lease
        out = [_materialize_item(it) for it in items]
        del items, pay
        lease.release()
        return out, None
    shm = shared_memory.SharedMemory(name=desc["shm"])
    lease = ShmLease(shm)
    base = memoryview(shm.buf)
    moff, mlen = desc["meta"]
    meta = bytes(base[moff:moff + mlen])
    items = pickle.loads(meta,
                         buffers=[base[o:o + l] for o, l in desc["offsets"]])
    if not copy:
        del base
        return items, lease
    out = [_materialize_item(it) for it in items]
    del items, base
    lease.release()
    return out, None


def unlink_segment(name: str) -> None:
    """Best-effort destroy of a segment by name (coordinator-side
    invalidation of a dead epoch's unconsumed partitions)."""
    try:
        from multiprocessing import shared_memory
        seg = shared_memory.SharedMemory(name=name)
        seg.close()
        seg.unlink()
    except (FileNotFoundError, OSError):
        pass


# ---------------------------------------------------------------------------
# Spill files (oversized partitions; peer-readable over the DFS dir)
# ---------------------------------------------------------------------------
def exchange_file_name(epoch: Optional[int], xid: int, src: str,
                       dst: str) -> str:
    e = "B" if epoch is None or epoch < 0 else str(epoch)
    return f"{EXCHANGE_PREFIX}e{e}_x{xid}_{src}_to_{dst}{EXCHANGE_SUFFIX}"


def resident_file_name(epoch: Optional[int], xid: int, node: str) -> str:
    """Spill name for a narrow edge's resident bucket (the node's own stage
    output past the per-edge share): pinned-round naming so a crash mid-slice
    leaves a file ``gc_orphans`` recognizes as exchange garbage."""
    e = "B" if epoch is None or epoch < 0 else str(epoch)
    return f"{RESIDENT_PREFIX}e{e}_x{xid}_{node}{EXCHANGE_SUFFIX}"


def columnar_file_name(epoch: Optional[int], xid: int, src: str,
                       dst: str) -> str:
    """Spill name for a columnar partition (ISSUE 10) — a peer partition
    when ``src != dst``, the node's own resident bucket when ``src == dst``.
    Same naming family as ``exchange_*``/``resident_*`` so ``gc_orphans``
    reclaims a crashed epoch's columnar spills too."""
    e = "B" if epoch is None or epoch < 0 else str(epoch)
    return f"{COLUMNAR_PREFIX}e{e}_x{xid}_{src}_to_{dst}{EXCHANGE_SUFFIX}"


def is_exchange_file(fn: str) -> bool:
    """Spill files — peer partitions (``exchange_*``), resident-bucket spills
    (``resident_*``), columnar partitions (``columnar_*``), and their torn
    temp halves (a crash between the temp write and the rename) — all crash
    garbage the store GC reclaims."""
    return fn.startswith((EXCHANGE_PREFIX, RESIDENT_PREFIX,
                          COLUMNAR_PREFIX)) and (
        fn.endswith(EXCHANGE_SUFFIX) or fn.endswith(EXCHANGE_SUFFIX + ".tmp"))


def write_partition_file(path: str, items: Sequence[IngestItem]
                         ) -> Dict[str, Any]:
    """Spill a partition for a peer: temp-write + rename so a reader (or the
    orphan GC) never sees a torn file."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        pickle.dump(list(items), f, protocol=pickle.HIGHEST_PROTOCOL)
    os.replace(tmp, path)
    return {"kind": "file", "path": path,
            "nbytes": os.path.getsize(path), "count": len(items)}


def write_columnar_file(path: str, batch: ColumnarBatch) -> Dict[str, Any]:
    """Spill a ColumnarBatch: magic + pickled header + raw column buffer,
    temp-write + rename like :func:`write_partition_file`.  Readers sniff
    the magic, so the consumer side needs no format knowledge up front."""
    header = pickle.dumps(batch.header(), protocol=5)
    pay = np.ascontiguousarray(batch.payload)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(COLUMNAR_MAGIC)
        f.write(len(header).to_bytes(8, "little"))
        f.write(header)
        f.write(memoryview(pay).cast("B"))
    os.replace(tmp, path)
    return {"kind": "file", "path": path, "columnar": True,
            "nbytes": os.path.getsize(path), "count": len(batch)}


def decode_columnar_bytes(data: bytes) -> ColumnarBatch:
    """Rebuild a ColumnarBatch from the byte image of a columnar spill file
    (a local read or a streamed degraded-mode fetch)."""
    m = len(COLUMNAR_MAGIC)
    hlen = int.from_bytes(data[m:m + 8], "little")
    header = pickle.loads(data[m + 8:m + 8 + hlen])
    # bytearray copy: downstream operators may mutate the decoded views
    pay = np.frombuffer(bytearray(data[m + 8 + hlen:]), np.uint8)
    return ColumnarBatch.from_header(header, pay)


def read_partition_file(path: str, remove: bool = True) -> List[IngestItem]:
    """Consume-on-read: a spilled partition is deleted once its (final)
    consumer has loaded it.  Dispatches on the columnar magic, so scalar
    and columnar spills share every call site."""
    with open(path, "rb") as f:
        head = f.read(len(COLUMNAR_MAGIC))
        if head == COLUMNAR_MAGIC:
            f.seek(0)
            items = decode_columnar_bytes(f.read()).to_items()
        else:
            f.seek(0)
            items = pickle.load(f)
    if remove:
        try:
            os.remove(path)
        except OSError:
            pass
    return items


def fetch_stream_partition(ref: Dict[str, Any]) -> List[IngestItem]:
    """Degraded-mode fetch (ISSUE 9): pull a partition whose producer is not
    shm-reachable.  The descriptor carries both the producer's stream
    endpoint and the spill path; the socket fetch is tried first (the server
    deletes the file after a successful send — consume-on-read over the
    wire), and a ``None`` reply (endpoint unreachable, or the file already
    served/GC'd server-side) falls back to reading the spill directly — on a
    single host the "remote" producer's DFS dir is this filesystem.  Both
    gone is an honest ``OSError`` (→ NodeFailure replay), never a silently
    empty partition."""
    from .transport import fetch_stream_bytes
    path = ref["path"]
    endpoint = ref.get("endpoint")
    if endpoint:
        data = fetch_stream_bytes((endpoint[0], int(endpoint[1])), path)
        if data is not None:
            if data.startswith(COLUMNAR_MAGIC):
                return decode_columnar_bytes(data).to_items()
            return pickle.loads(data)
    try:
        return read_partition_file(path, remove=True)
    except FileNotFoundError:
        raise OSError(
            f"degraded exchange: partition {path!r} unavailable from "
            f"endpoint {endpoint!r} and the shared dir — producer lost")


# ---------------------------------------------------------------------------
# Node-side partition buffers
# ---------------------------------------------------------------------------
@dataclass
class _Bucket:
    """Partitions addressed to one (round, consumer-node) pair."""

    items: List[IngestItem] = field(default_factory=list)
    nbytes: int = 0
    leases: List[ShmLease] = field(default_factory=list)
    paths: List[str] = field(default_factory=list)   # unread spill files
    batches: List[ColumnarBatch] = field(default_factory=list)  # ISSUE 10


class PartitionExchange:
    """Node-side buffer of shuffle partitions, keyed (round xid, node).

    Thread backend: one instance per engine — a producing stage job deposits
    each partition straight into its target node's bucket (the in-memory
    queue handoff; oversized partitions deposit a spill-file ref instead),
    and the consuming stage job on that node collects it.  Process backend:
    one instance per worker process, holding the worker's *resident*
    partitions (the slice it dealt to itself, possibly aliasing input
    segments via lease shares) and first-consumer-decoded batches kept for
    later consumer stages.

    ``collect(last=False)`` peeks (multi-consumer stage DAGs read a round
    more than once); the final ``collect(last=True)`` pops the bucket and
    returns its lease shares for the caller to release *after* the consuming
    job is done with the items."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._buckets: Dict[Tuple[int, str], _Bucket] = {}

    def deposit(self, xid: int, dst: str, items: Optional[List[IngestItem]],
                nbytes: int, leases: Optional[List[ShmLease]] = None,
                path: Optional[str] = None) -> None:
        with self._lock:
            b = self._buckets.setdefault((xid, dst), _Bucket())
            if items:
                b.items.extend(items)
            b.nbytes += nbytes
            if leases:
                b.leases.extend(leases)
            if path is not None:
                b.paths.append(path)

    def deposit_batch(self, xid: int, dst: str, batch: ColumnarBatch) -> None:
        """Deposit a columnar partition (ISSUE 10): the batch stays packed in
        the bucket — item materialization happens at first collect, so the
        producer side never touches per-item objects.  The batch owns its
        payload (``from_items``/``select`` copy), so no lease rides along."""
        with self._lock:
            b = self._buckets.setdefault((xid, dst), _Bucket())
            b.batches.append(batch)
            b.nbytes += batch.nbytes

    def collect(self, xid: int, node: str, last: bool = True
                ) -> Tuple[List[IngestItem], List[ShmLease]]:
        """Partitions addressed to ``node`` in round ``xid``.  Spilled files
        are loaded (and deleted) on first read; ``last=True`` pops the
        bucket and hands back its lease shares — release them once the
        consuming job no longer references the items."""
        with self._lock:
            b = self._buckets.get((xid, node))
            if b is None:
                return [], []
            paths, b.paths = list(b.paths), []
            batches, b.batches = list(b.batches), []
        for p in paths:   # file I/O outside the lock
            loaded = read_partition_file(p, remove=True)
            with self._lock:
                b.items.extend(loaded)
        for batch in batches:   # unpack outside the lock too
            unpacked = batch.to_items()
            with self._lock:
                b.items.extend(unpacked)
        with self._lock:
            if last:
                self._buckets.pop((xid, node), None)
                return list(b.items), list(b.leases)
            return list(b.items), []

    def drop(self, xids: Sequence[int]) -> None:
        """Invalidate rounds (epoch abort/replay): release lease shares,
        delete unread spill files, forget the buckets."""
        want = set(xids)
        with self._lock:
            victims = [k for k in self._buckets if k[0] in want]
            dropped = [self._buckets.pop(k) for k in victims]
        self._reclaim(dropped)

    def drop_node(self, xids: Sequence[int], node: str) -> None:
        """Per-producer invalidation (ISSUE 8 lineage-cone recovery): forget
        only the buckets addressed to ``node`` in the given rounds.  On a
        narrow (identity-routed) edge the producer's output lives solely in
        its own bucket, so this removes exactly the dead node's contribution
        while every survivor's partition stays live."""
        want = {(x, node) for x in xids}
        with self._lock:
            victims = [k for k in self._buckets if k in want]
            dropped = [self._buckets.pop(k) for k in victims]
        self._reclaim(dropped)

    def _reclaim(self, dropped: Sequence[_Bucket]) -> None:
        for b in dropped:
            for lease in b.leases:
                lease.release()
            for p in b.paths:
                try:
                    os.remove(p)
                except OSError:
                    pass

    def pending_rounds(self) -> List[int]:
        with self._lock:
            return sorted({xid for xid, _ in self._buckets})

    def close(self) -> None:
        self.drop(self.pending_rounds())
