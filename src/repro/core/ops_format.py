"""FORMAT-side ingestion operators: partition / chunk / order / serialize.

Paper Sec. IV-A: ``FORMAT s PARTITION BY p CHUNK BY c ORDER BY o SERIALIZE AS
z`` chains the operators in statement order; operators may repeat (multi-level
partitioning) or be reordered by the user (global vs per-chunk sort).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..layouts import serialize_block
from .items import Columns, Granularity, IngestItem, concat_columns, num_rows, take_rows
from .operators import IngestOp, OpMode, register_op


# ------------------------------------------------------------------- partition
@register_op("partition")
class PartitionOp(IngestOp):
    """CHUNK -> CHUNK split by a partitioning function.

    Built-in schemes: ``hash`` (on ``key``), ``range`` (on ``key`` into
    ``num_partitions`` quantile ranges over ``bounds``), ``field`` (group by
    exact value), ``length`` (token-sequence length buckets — LM packing aid),
    or a custom callable Columns -> int array of partition ids.
    """

    name = "partition"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK
    # already numpy-vectorized per chunk; the default scalar-loop
    # process_batch is identical, and marking it capable lets partition
    # stages anchor columnar edges (ISSUE 10)
    batch_capable = True

    def __init__(self, key: Optional[str] = None, scheme: str = "hash",
                 num_partitions: int = 8, bounds: Optional[Sequence[float]] = None,
                 fn: Optional[Callable[[Columns], np.ndarray]] = None,
                 tag: Optional[str] = None, **kw: Any) -> None:
        super().__init__(key=key, scheme=scheme, num_partitions=num_partitions,
                         bounds=bounds, fn=fn, tag=tag, **kw)
        self.key, self.scheme, self.num_partitions = key, scheme, num_partitions
        self.bounds = None if bounds is None else np.asarray(bounds)
        self.fn = fn
        self.tag = tag

    @property
    def label_key(self) -> str:
        return self.tag or self.name

    def _pids(self, cols: Columns) -> np.ndarray:
        if self.fn is not None:
            return np.asarray(self.fn(cols), dtype=np.int64)
        vals = cols[self.key]
        if self.scheme == "hash":
            if vals.dtype.kind in "iu":
                h = vals.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
                return (h >> np.uint64(33)).astype(np.int64) % self.num_partitions
            return np.array([hash(v) % self.num_partitions for v in vals], dtype=np.int64)
        if self.scheme == "range":
            bounds = self.bounds
            if bounds is None:
                qs = np.linspace(0, 1, self.num_partitions + 1)[1:-1]
                bounds = np.quantile(vals.astype(np.float64), qs)
            return np.searchsorted(bounds, vals, side="right").astype(np.int64)
        if self.scheme == "field":
            _, inv = np.unique(vals, return_inverse=True)
            return inv.astype(np.int64)
        if self.scheme == "length":
            lens = vals if vals.ndim == 1 else (vals >= 0).sum(axis=-1)
            edges = np.asarray(self.bounds if self.bounds is not None
                               else [256, 512, 1024, 2048, 4096])
            return np.searchsorted(edges, lens, side="left").astype(np.int64)
        raise ValueError(f"unknown partition scheme {self.scheme!r}")

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        pids = self._pids(cols)
        for pid in np.unique(pids):
            part = take_rows(cols, np.nonzero(pids == pid)[0])
            yield IngestItem(part, item.granularity, item.labels, dict(item.meta)).with_label(
                self.label_key, int(pid))


# ----------------------------------------------------------------------- chunk
@register_op("chunk")
class ChunkOp(IngestOp):
    """CHUNK -> CHUNK re-chunking into ~``target_bytes`` (or ``target_rows``)
    units — the HDFS "100mbBlocks" analogue.  Buffers rows across inputs with
    the same upstream labels so chunk boundaries do not fragment partitions."""

    name = "chunk"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK
    batch_capable = True

    def __init__(self, target_bytes: Optional[int] = None, target_rows: Optional[int] = None,
                 **kw: Any) -> None:
        super().__init__(target_bytes=target_bytes, target_rows=target_rows, **kw)
        if target_bytes is None and target_rows is None:
            target_bytes = 4 << 20
        self.target_bytes, self.target_rows = target_bytes, target_rows

    def _rows_per_chunk(self, cols: Columns) -> int:
        if self.target_rows is not None:
            return max(1, self.target_rows)
        n = num_rows(cols)
        if n == 0:
            return 1
        bytes_per_row = max(1, sum(v.nbytes for v in cols.values()) // n)
        return max(1, int(self.target_bytes) // bytes_per_row)

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        n = num_rows(cols)
        step = self._rows_per_chunk(cols)
        idx = 0
        for start in range(0, max(n, 1), step):
            part = take_rows(cols, np.arange(start, min(start + step, n)))
            yield IngestItem(part, Granularity.CHUNK, item.labels, dict(item.meta)).with_label(
                self.name, idx)
            idx += 1


# ----------------------------------------------------------------------- order
@register_op("order")
class OrderOp(IngestOp):
    """CHUNK -> CHUNK sort rows by ``key`` (per-item; placing OrderOp before
    ChunkOp in the statement yields a global order, after it a per-chunk
    order — exactly the paper's s2/s3 discussion)."""

    name = "order"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK
    batch_capable = True

    def __init__(self, key: str, descending: bool = False, **kw: Any) -> None:
        super().__init__(key=key, descending=descending, **kw)
        self.key, self.descending = key, descending

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        order = np.argsort(cols[self.key], kind="stable")
        if self.descending:
            order = order[::-1]
        yield IngestItem(take_rows(cols, order), item.granularity, item.labels,
                         dict(item.meta)).with_label(self.name, self.key)


# ------------------------------------------------------------------- serialize
@register_op("serialize")
class SerializeOp(IngestOp):
    """CHUNK -> BLOCK: encode a record batch into a physical layout.

    Granularity changes here, so the pipelining rule keeps a materialization
    barrier after serialize.  CPU-heavy: runs in parallel mode by default
    (paper Sec. VI-A forks one instance per core for serialize).
    """

    name = "serialize"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.BLOCK
    cpu_heavy = True
    batch_capable = True

    def __init__(self, layout: str = "columnar",
                 layouts: Optional[Sequence[str]] = None, **layout_kw: Any) -> None:
        super().__init__(layout=layout, layouts=layouts, **layout_kw)
        self.layout = layout
        # hybrid replicas (paper Sec. II-C): cycle layouts across a replica's
        # blocks so queries likely find some blocks in a favorable layout
        self.layouts = tuple(layouts) if layouts else None
        self._idx = 0
        self.layout_kw = {k: v for k, v in layout_kw.items()
                          if k not in ("num_threads", "layouts")}

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        layout = self.layout
        if self.layouts:
            layout = self.layouts[self._idx % len(self.layouts)]
            self._idx += 1
        block = serialize_block(item.data, layout, **self.layout_kw)
        out = IngestItem(block, Granularity.BLOCK, item.labels, dict(item.meta))
        yield out.with_label(self.name, layout)

    def process_batch(self, items: Sequence[IngestItem]) -> List[IngestItem]:
        """Batch serialize over the columnar chunk dicts (ISSUE 7): layout
        assignment is computed up front (the hybrid-layout cycle becomes
        deterministic, matching the serial iterator's order), then the
        per-chunk encodes fan out over the shared pool."""
        items = list(items)
        if self.layouts:
            layouts = [self.layouts[(self._idx + i) % len(self.layouts)]
                       for i in range(len(items))]
            self._idx += len(items)
        else:
            layouts = [self.layout] * len(items)
        if self.mode is OpMode.PARALLEL and len(items) > 1:
            blocks = list(self._ensure_pool().map(
                lambda p: serialize_block(p[0].data, p[1], **self.layout_kw),
                zip(items, layouts)))
        else:
            blocks = [serialize_block(it.data, ly, **self.layout_kw)
                      for it, ly in zip(items, layouts)]
        return [IngestItem(b, Granularity.BLOCK, it.labels, dict(it.meta))
                .with_label(self.name, ly)
                for b, it, ly in zip(blocks, items, layouts)]


# ------------------------------------------------------------------- pack (LM)
@register_op("pack")
class PackOp(IngestOp):
    """CHUNK -> CHUNK: pack ragged token sequences into fixed (rows, seq_len)
    matrices with loss masks + positions — the TPU-era serialize hot path
    (DESIGN.md §2).  Sequences are greedily packed first-fit into rows; rows
    are emitted when the buffer reaches ``rows_per_block``.

    Input fields: ``tokens`` (object array of 1-D int arrays) or
    (``tokens``, ``length``) padded matrix.  Output fields: ``tokens``,
    ``loss_mask``, ``positions``, ``segment_ids`` each (rows, seq_len).
    """

    name = "pack"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK
    cpu_heavy = True
    batch_capable = True

    def __init__(self, seq_len: int = 2048, rows_per_block: int = 64, pad_id: int = 0,
                 use_pallas: bool = False, **kw: Any) -> None:
        super().__init__(seq_len=seq_len, rows_per_block=rows_per_block,
                         pad_id=pad_id, use_pallas=use_pallas, **kw)
        self.seq_len, self.rows_per_block, self.pad_id = seq_len, rows_per_block, pad_id
        self.use_pallas = use_pallas
        self._pack_kernel = None
        if use_pallas:
            from ..kernels import ops as k_ops  # lazy: jax import
            self._pack_kernel = k_ops.pack_tokens
        self._block_idx = 0

    def _sequences(self, cols: Columns) -> List[np.ndarray]:
        toks = cols["tokens"]
        if toks.dtype == object:
            return [np.asarray(t, dtype=np.int32) for t in toks]
        if "length" in cols:
            return [toks[i, : cols["length"][i]].astype(np.int32) for i in range(len(toks))]
        return [t.astype(np.int32) for t in toks]

    def _pack_rows(self, item: IngestItem) -> List[Dict[str, np.ndarray]]:
        """Stateless packing of one chunk's sequences into row dicts — the
        CPU-heavy half of ``process``, shared with the batch path so both can
        fan it out without racing on ``_block_idx``."""
        seqs = self._sequences(item.data)
        S = self.seq_len
        rows: List[Dict[str, np.ndarray]] = []
        cur_tok = np.full(S, self.pad_id, np.int32)
        cur_mask = np.zeros(S, np.int32)
        cur_pos = np.zeros(S, np.int32)
        cur_seg = np.zeros(S, np.int32)
        fill, seg = 0, 0

        def flush_row():
            nonlocal cur_tok, cur_mask, cur_pos, cur_seg, fill, seg
            rows.append({"tokens": cur_tok, "loss_mask": cur_mask,
                         "positions": cur_pos, "segment_ids": cur_seg})
            cur_tok = np.full(S, self.pad_id, np.int32)
            cur_mask = np.zeros(S, np.int32)
            cur_pos = np.zeros(S, np.int32)
            cur_seg = np.zeros(S, np.int32)
            fill, seg = 0, 0

        for s in seqs:
            # over-long documents are SPLIT across rows (never dropped:
            # packing conserves tokens — tests/test_properties.py)
            for off in range(0, len(s), S):
                piece = s[off : off + S]
                if fill + len(piece) > S and fill > 0:
                    flush_row()
                seg += 1
                n = len(piece)
                cur_tok[fill : fill + n] = piece
                cur_mask[fill : fill + n] = 1
                cur_pos[fill : fill + n] = np.arange(n, dtype=np.int32)
                cur_seg[fill : fill + n] = seg
                fill += n
                if fill == S:
                    flush_row()
        if fill > 0:
            flush_row()
        return rows

    def _emit_blocks(self, item: IngestItem,
                     rows: List[Dict[str, np.ndarray]]) -> Iterable[IngestItem]:
        for start in range(0, len(rows), self.rows_per_block):
            batch = rows[start : start + self.rows_per_block]
            out = {k: np.stack([r[k] for r in batch]) for k in batch[0]}
            yield IngestItem(out, Granularity.CHUNK, item.labels, dict(item.meta)).with_label(
                self.name, self._block_idx)
            self._block_idx += 1

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        yield from self._emit_blocks(item, self._pack_rows(item))

    # --------------------------------------------- kernel route (ISSUE 10)
    def _plan_rows(self, item: IngestItem) -> List[List[np.ndarray]]:
        """First-fit planning only: the exact walk of ``_pack_rows`` (same
        split/flush decisions), recording each row's pieces instead of
        writing row buffers — the host half of the kernel route."""
        seqs = self._sequences(item.data)
        S = self.seq_len
        rows: List[List[np.ndarray]] = []
        cur: List[np.ndarray] = []
        fill = 0
        for s in seqs:
            for off in range(0, len(s), S):
                piece = s[off : off + S]
                if fill + len(piece) > S and fill > 0:
                    rows.append(cur)
                    cur, fill = [], 0
                cur.append(piece)
                fill += len(piece)
                if fill == S:
                    rows.append(cur)
                    cur, fill = [], 0
        if fill > 0:
            rows.append(cur)
        return rows

    def _kernel_pack(self, items: List[IngestItem]
                     ) -> List[List[Dict[str, np.ndarray]]]:
        """Pack every item's rows through ``kernels.pack_tokens`` in ONE
        launch: the host-side first-fit plan concatenates all pieces into a
        flat int32 stream (a row's pieces are adjacent by construction), the
        kernel gathers each row's [start, len) slice into the padded
        (R, seq_len) token matrix and the valid-mask plane (== loss_mask —
        a row fills contiguously from 0).  Per-piece ``positions`` /
        ``segment_ids`` are cheap host-side fills from the plan.  Output
        rows are byte-identical to ``_pack_rows`` — the scalar path stays
        the correctness oracle (tests/test_columnar_plane.py)."""
        plans = [self._plan_rows(it) for it in items]
        all_rows = [row for plan in plans for row in plan]
        if not all_rows:
            return [[] for _ in plans]
        S = self.seq_len
        flat_parts: List[np.ndarray] = []
        starts, lens = [], []
        off = 0
        for row in all_rows:
            n = sum(len(p) for p in row)
            starts.append(off)
            lens.append(n)
            flat_parts.extend(row)
            off += n
        flat = np.concatenate(flat_parts).astype(np.int32, copy=False)
        t0 = time.perf_counter()
        toks, mask, _ = self._pack_kernel(
            flat, np.asarray(starts, np.int32), np.asarray(lens, np.int32),
            S, pad_id=self.pad_id)
        toks, mask = np.asarray(toks), np.asarray(mask)
        self.kernel_ms_total += (time.perf_counter() - t0) * 1000.0
        out_rows: List[Dict[str, np.ndarray]] = []
        for r, row in enumerate(all_rows):
            pos = np.zeros(S, np.int32)
            sid = np.zeros(S, np.int32)
            fill = 0
            for pi, piece in enumerate(row):
                n = len(piece)
                pos[fill : fill + n] = np.arange(n, dtype=np.int32)
                sid[fill : fill + n] = pi + 1
                fill += n
            out_rows.append({"tokens": toks[r], "loss_mask": mask[r],
                             "positions": pos, "segment_ids": sid})
        split: List[List[Dict[str, np.ndarray]]] = []
        i = 0
        for plan in plans:
            split.append(out_rows[i : i + len(plan)])
            i += len(plan)
        return split

    def process_batch(self, items: Sequence[IngestItem]) -> List[IngestItem]:
        """Batch pack (ISSUE 7): the stateless row packing fans out over the
        shared pool; block labels are assigned serially afterwards, so the
        output (and ``_block_idx`` order) is byte-identical to the serial
        iterator — unlike scalar parallel mode, where threads race on the
        block counter.  With ``use_pallas`` the whole batch routes through
        the ``pack_tokens`` kernel instead (ISSUE 10), falling back to the
        scalar packer on any kernel-side failure."""
        items = list(items)
        if self._pack_kernel is not None and items:
            try:
                packed = self._kernel_pack(items)
            except Exception:
                packed = None   # scalar oracle fallback
            if packed is not None:
                out: List[IngestItem] = []
                for item, rows in zip(items, packed):
                    out.extend(self._emit_blocks(item, rows))
                return out
        if self.mode is OpMode.PARALLEL and len(items) > 1:
            packed = list(self._ensure_pool().map(self._pack_rows, items))
        else:
            packed = [self._pack_rows(it) for it in items]
        out = []
        for item, rows in zip(items, packed):
            out.extend(self._emit_blocks(item, rows))
        return out
