"""Ingestion-aware data access (paper Sec. VII).

*What* to access — ``filter_replica`` / ``filter_block`` over the lineage
labels persisted in block names/manifest.  *Where* — ``split_by_key`` /
``co_split_by_key`` assign blocks to computation tasks (here: mesh data-axis
slots / host feeders).  *How* — ``deserialize(projection, selection)``
pushdown through the layout library.

``DataAccess`` is the InputFormat analogue: the training/serving feeders and
the benchmark "query processor" both consume it.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..layouts import SerializedBlock, deserialize_block
from .items import Columns, concat_columns
from .store import BlockEntry, DataStore


@dataclass
class Split:
    """One computation task's input: an ordered set of blocks (+ key)."""

    key: Any
    blocks: List[BlockEntry] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.blocks)


class DataAccess:
    """A lazily-filtered view over a DataStore's blocks."""

    def __init__(self, store: DataStore,
                 entries: Optional[List[BlockEntry]] = None) -> None:
        self.store = store
        # default view: no parity blocks, and no blocks of uncommitted
        # streaming epochs — readers never observe in-flight micro-batches
        self.entries: List[BlockEntry] = (
            list(entries) if entries is not None
            else [e for e in store.blocks()
                  if not e.is_parity
                  and (e.epoch < 0 or store.epoch_committed(e.epoch))])

    # ------------------------------------------------------------ what (Sec VII)
    def filter_replica(self, op: str, value: Any = None) -> "DataAccess":
        """Keep blocks whose lineage carries label l_op(=value): e.g. pick the
        replica serialized as 'sorted', or the sample replica (label 1)."""
        kept = []
        for e in self.entries:
            for lop, lval in e.labels:
                if lop == op and (value is None or lval == value):
                    kept.append(e)
                    break
        return DataAccess(self.store, kept)

    # paper helper variants (Sec. VIII-A)
    def filter_replica_by_layout(self, layout: str) -> "DataAccess":
        return DataAccess(self.store, [e for e in self.entries if e.layout == layout])

    def filter_replica_by_id(self, replica_index: int) -> "DataAccess":
        return DataAccess(self.store,
                          [e for e in self.entries if e.replica_index == replica_index])

    def filter_replica_by_partitioning(self, partition_op: str) -> "DataAccess":
        return self.filter_replica(partition_op)

    def filter_block(self, predicate: Callable[[BlockEntry], bool]) -> "DataAccess":
        """Block-level filter within the chosen replica (e.g. keep partition
        ids overlapping a queried key range — partition pruning)."""
        return DataAccess(self.store, [e for e in self.entries if predicate(e)])

    def filter_block_by_label(self, op: str, value: Any) -> "DataAccess":
        return self.filter_block(
            lambda e: any(lop == op and lval == value for lop, lval in e.labels))

    # -------------------------------------------------------- epochs (streaming)
    def filter_epoch(self, epoch: int) -> "DataAccess":
        """Keep blocks committed by exactly this streaming epoch."""
        if not self.store.epoch_committed(epoch):
            return DataAccess(self.store, [])
        return DataAccess(self.store,
                          [e for e in self.entries if e.epoch == epoch])

    def since_epoch(self, epoch: int) -> "DataAccess":
        """Blocks of every *committed* epoch strictly after ``epoch`` —
        the incremental-consumption surface (``since_epoch(-1)`` = all
        committed streaming data).  In-flight epochs are never visible."""
        committed = set(self.store.committed_epoch_ids())
        return DataAccess(self.store,
                          [e for e in self.entries
                           if e.epoch > epoch and e.epoch in committed])

    def latest_epoch(self) -> int:
        """Highest committed epoch in view (-1 when no streaming data)."""
        committed = set(self.store.committed_epoch_ids())
        eps = [e.epoch for e in self.entries if e.epoch in committed]
        return max(eps, default=-1)

    def committed_frontier(self, start: int = 0) -> int:
        """Highest epoch ``f`` with epochs ``start..f`` *all* committed (-1 =
        none).  Under pipelined ingestion the commit sequencer publishes in
        epoch order, so the frontier equals ``latest_epoch`` — this is the
        gap-free watermark incremental readers can trust (DESIGN.md §3)."""
        committed = set(self.store.committed_epoch_ids())
        f = start - 1
        while f + 1 in committed:
            f += 1
        return f

    def distinct_replicas(self) -> "DataAccess":
        """At most one physical block per logical id (avoid double reads when a
        plan created several copies)."""
        seen: Dict[str, BlockEntry] = {}
        for e in self.entries:
            seen.setdefault(e.logical_id + f"#{self._label_dict(e).get('chunk', 0)}", e)
        return DataAccess(self.store, list(seen.values()))

    @staticmethod
    def _label_dict(e: BlockEntry) -> Dict[str, Any]:
        return {op: val for op, val in e.labels}

    # ----------------------------------------------------------- where (Sec VII)
    def split_by_key(self, key_op: str, max_split_size: Optional[int] = None,
                     num_tasks: Optional[int] = None) -> List[Split]:
        """Group blocks by an ingest label (e.g. the partition id) into splits —
        one split per computation task.  ``num_tasks`` folds keys onto a fixed
        task count (the mesh data-axis size for training feeders)."""
        groups: Dict[Any, List[BlockEntry]] = defaultdict(list)
        for e in self.entries:
            groups[self._label_dict(e).get(key_op)].append(e)
        splits: List[Split] = []
        for k in sorted(groups, key=lambda x: (x is None, x)):
            blocks = groups[k]
            if max_split_size:
                for i in range(0, len(blocks), max_split_size):
                    splits.append(Split(k, blocks[i : i + max_split_size]))
            else:
                splits.append(Split(k, blocks))
        if num_tasks is not None:
            folded = [Split(t, []) for t in range(num_tasks)]
            for i, s in enumerate(splits):
                folded[i % num_tasks].blocks.extend(s.blocks)
            return folded
        return splits

    def co_split_by_key(self, key_op: str, *others: Tuple["DataAccess", str]
                        ) -> List[List[Split]]:
        """Align splits of several datasets on their keys (paper coSplitByKey:
        co-partitioned joins without repartitioning)."""
        mine = {s.key: s for s in self.split_by_key(key_op)}
        theirs = [{s.key: s for s in o.split_by_key(kop)} for o, kop in others]
        keys = sorted(set(mine) | set().union(*[set(t) for t in theirs]) if theirs
                      else set(mine), key=lambda x: (x is None, x))
        out: List[List[Split]] = []
        for k in keys:
            row = [mine.get(k, Split(k))]
            for t in theirs:
                row.append(t.get(k, Split(k)))
            out.append(row)
        return out

    # ------------------------------------------------------------- how (Sec VII)
    def deserialize(self, projection: Optional[Sequence[str]] = None,
                    selection: Optional[Tuple[str, str, Any]] = None
                    ) -> Iterable[Tuple[BlockEntry, Columns]]:
        """Layout-aware read of every selected block with pushdown."""
        for e in self.entries:
            block = self.store.read_block(e.block_id)
            yield e, deserialize_block(block, projection, selection)

    def read_all(self, projection: Optional[Sequence[str]] = None,
                 selection: Optional[Tuple[str, str, Any]] = None) -> Columns:
        parts = [cols for _, cols in self.deserialize(projection, selection)]
        return concat_columns(parts)

    def read_split(self, split: Split,
                   projection: Optional[Sequence[str]] = None,
                   selection: Optional[Tuple[str, str, Any]] = None) -> Columns:
        parts = []
        for e in split.blocks:
            block = self.store.read_block(e.block_id)
            parts.append(deserialize_block(block, projection, selection))
        return concat_columns(parts)

    # -------------------------------------------------------------------- misc
    def __len__(self) -> int:
        return len(self.entries)

    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.entries)
