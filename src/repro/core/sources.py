"""Worker-pull source tier (ISSUE 6): descriptors instead of item pushes.

Every other edge of the dataflow already moves item bytes worker-to-worker
(exchange plane, PR 4/5); this module deletes the last coordinator hop — the
*source*.  A :class:`SourceAdapter` turns a source into **shard
descriptors** (byte ranges / endpoints / seeded generator offsets): the
coordinator plans and distributes the descriptors, and the workers open,
read, parse, and route their shards directly into their local lanes.  The
model is AsterixDB's intake/compute split for fault-tolerant feeds
(arXiv:1405.1705): the coordinator decides *where* data is read, never
touching the data itself.

Descriptors are tiny picklable records, so they cross the process-backend
pipes for free, and they are the unit of replay bookkeeping: each streaming
epoch records which descriptors each node was issued; when a reader dies,
its unfinished descriptors are re-issued to survivors
(``RunReport.source_reissues``) before the standard invalidate-then-replay
of the epoch.  Reads must therefore be deterministic per descriptor — a
re-read yields the same items.

Adapters keep only plain constructor parameters as state (paths, ranges,
specs — never handles or callables), so a default pickle ships them to
process-backend workers; parser hooks are importable ``"pkg.module:attr"``
strings resolved worker-side via :func:`resolve_callable`.
"""
from __future__ import annotations

import fnmatch
import os
import socket
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from .items import Columns, Granularity, IngestItem
from .operators import resolve_callable


@dataclass
class ShardDescriptor:
    """One worker-readable unit of a source: *where* to read, not the data.

    ``spec`` is adapter-kind-specific (path + byte range, endpoint, seed +
    offset).  ``est_items``/``est_bytes`` are planning estimates the epoch
    cutter budgets with — the authoritative counts are worker-reported after
    the read.
    """

    source_id: str
    index: int
    kind: str
    spec: Dict[str, Any] = field(default_factory=dict)
    est_items: int = 1
    est_bytes: int = 0

    def __repr__(self) -> str:  # compact: descriptors appear in fault logs
        return f"ShardDescriptor({self.source_id}#{self.index} {self.kind} {self.spec})"


class SourceAdapter:
    """Coordinator plans descriptors; workers read them.

    ``describe()`` runs coordinator-side and may touch only metadata (file
    sizes, directory listings) — item bytes stay worker-side, which is the
    ``source_coordinator_bytes == 0`` invariant.  ``read()`` runs on a
    worker lane and must be deterministic per descriptor (replay safety).
    Unbounded adapters (directory tails) grow via ``poll()`` and signal end
    of stream through ``exhausted()``.
    """

    kind = "base"

    def describe(self) -> List[ShardDescriptor]:
        raise NotImplementedError

    def poll(self) -> List[ShardDescriptor]:
        """Descriptors that appeared since the last describe()/poll()."""
        return []

    def exhausted(self) -> bool:
        """True once no further descriptors will ever appear."""
        return True

    def read(self, desc: ShardDescriptor) -> List[IngestItem]:
        raise NotImplementedError

    def spec(self) -> Dict[str, Any]:
        """The plan-signature form (mirrors ``plan.source_spec``)."""
        return {"kind": self.kind}


# ---------------------------------------------------------------------------
# line parsing (shared by the file / tail / socket adapters)
# ---------------------------------------------------------------------------

def parse_numeric_lines(lines: Sequence[str], fields: Sequence[str]) -> Columns:
    """Default record parser: comma-separated numerics, columns by position."""
    rows = [ln.split(",") for ln in lines if ln.strip()]
    cols: Columns = {}
    for j, f in enumerate(fields):
        vals = np.array([float(r[j]) for r in rows])
        # integral columns come back as int64 so generator round-trips compare
        if vals.size and np.all(vals == np.floor(vals)):
            cols[f] = vals.astype(np.int64)
        else:
            cols[f] = vals
    return cols


def write_numeric_file(path: str, cols: Columns) -> int:
    """Materialize columns as the line format ``parse_numeric_lines`` reads.
    Returns the file size in bytes (descriptor-planning convenience)."""
    from .items import num_rows
    names = list(cols)
    n = num_rows(cols)
    with open(path, "w") as f:
        for i in range(n):
            f.write(",".join(repr(cols[c][i].item() if hasattr(cols[c][i], "item")
                                  else cols[c][i]) for c in names))
            f.write("\n")
    return os.path.getsize(path)


def _read_line_range(path: str, start: int, end: int) -> List[str]:
    """Hadoop-style split read: a range owns every line that *starts* inside
    [start, end); the line straddling ``end`` is finished by its owner.

    A reader at start > 0 seeks to ``start - 1`` and discards one line: if
    the boundary fell mid-line that consumes the partial line (the previous
    range owns it), and if it fell exactly on a line start it consumes only
    the previous line's terminator — a plain "seek(start) and skip a line"
    would silently drop boundary-aligned lines."""
    lines: List[str] = []
    with open(path, "rb") as f:
        if start > 0:
            f.seek(start - 1)
            f.readline()
        while f.tell() < end:
            raw = f.readline()
            if not raw:
                break
            lines.append(raw.decode())
    return lines


def _parse_with(parser: Optional[str], lines: Sequence[str],
                fields: Sequence[str]) -> Columns:
    if parser is None:
        return parse_numeric_lines(lines, fields)
    fn = resolve_callable(parser)
    return fn(lines, fields)


# ---------------------------------------------------------------------------
# adapters
# ---------------------------------------------------------------------------

class FileRangeSource(SourceAdapter):
    """Files split into byte-range descriptors (one item per range).

    ``paths`` is a file, directory, glob, or explicit list; ``shard_bytes``
    is the target range size; ``fields`` names the columns the default
    line parser produces.  ``delay_s`` throttles each range read (rate-limit
    emulation; also what the fault matrix uses to land a SIGTERM mid-read).
    """

    kind = "files"

    def __init__(self, paths: Union[str, Sequence[str]], *,
                 fields: Sequence[str] = (), shard_bytes: int = 1 << 20,
                 parser: Optional[str] = None, delay_s: float = 0.0) -> None:
        self.paths = paths
        self.fields = tuple(fields)
        self.shard_bytes = int(shard_bytes)
        self.parser = parser
        self.delay_s = float(delay_s)

    def _resolve_paths(self) -> List[str]:
        import glob as _glob
        if isinstance(self.paths, str):
            if os.path.isdir(self.paths):
                return sorted(os.path.join(self.paths, f)
                              for f in os.listdir(self.paths))
            if any(c in self.paths for c in "*?["):
                return sorted(_glob.glob(self.paths))
            return [self.paths]
        return list(self.paths)

    def describe(self) -> List[ShardDescriptor]:
        descs: List[ShardDescriptor] = []
        for path in self._resolve_paths():
            size = os.path.getsize(path)
            step = max(1, self.shard_bytes)
            for start in range(0, max(size, 1), step):
                end = min(start + step, size)
                descs.append(ShardDescriptor(
                    source_id=self.kind, index=len(descs), kind=self.kind,
                    spec={"path": path, "start": start, "end": end},
                    est_items=1, est_bytes=end - start))
        return descs

    def read(self, desc: ShardDescriptor) -> List[IngestItem]:
        if self.delay_s:
            time.sleep(self.delay_s)
        lines = _read_line_range(desc.spec["path"], desc.spec["start"],
                                 desc.spec["end"])
        if not lines:
            return []
        cols = _parse_with(self.parser, lines, self.fields)
        return [IngestItem(cols, Granularity.FILE)]

    def spec(self) -> Dict[str, Any]:
        return {"kind": self.kind, "paths": self.paths,
                "fields": list(self.fields), "shard_bytes": self.shard_bytes,
                "parser": self.parser}


class DirectoryTailSource(SourceAdapter):
    """Tail a directory: every file that appears becomes descriptors.

    ``poll()`` reports newly arrived files; the stream is ``exhausted()``
    once nothing new has appeared for ``idle_timeout_s`` — the paper's
    "files keep landing" intake, bounded for tests by the idle window.
    """

    kind = "tail"

    def __init__(self, directory: str, *, pattern: str = "*",
                 fields: Sequence[str] = (), shard_bytes: int = 1 << 20,
                 parser: Optional[str] = None,
                 idle_timeout_s: float = 1.0) -> None:
        self.directory = directory
        self.pattern = pattern
        self.fields = tuple(fields)
        self.shard_bytes = int(shard_bytes)
        self.parser = parser
        self.idle_timeout_s = float(idle_timeout_s)
        self._seen: set = set()
        self._last_new = time.monotonic()
        self._next_index = 0

    def _scan(self) -> List[ShardDescriptor]:
        descs: List[ShardDescriptor] = []
        try:
            names = sorted(os.listdir(self.directory))
        except FileNotFoundError:
            names = []
        for name in names:
            if not fnmatch.fnmatch(name, self.pattern):
                continue
            path = os.path.join(self.directory, name)
            if path in self._seen or not os.path.isfile(path):
                continue
            self._seen.add(path)
            size = os.path.getsize(path)
            step = max(1, self.shard_bytes)
            for start in range(0, max(size, 1), step):
                end = min(start + step, size)
                descs.append(ShardDescriptor(
                    source_id=self.kind, index=self._next_index,
                    kind=self.kind,
                    spec={"path": path, "start": start, "end": end},
                    est_items=1, est_bytes=end - start))
                self._next_index += 1
        if descs:
            self._last_new = time.monotonic()
        return descs

    def describe(self) -> List[ShardDescriptor]:
        return self._scan()

    def poll(self) -> List[ShardDescriptor]:
        return self._scan()

    def exhausted(self) -> bool:
        return time.monotonic() - self._last_new > self.idle_timeout_s

    def read(self, desc: ShardDescriptor) -> List[IngestItem]:
        lines = _read_line_range(desc.spec["path"], desc.spec["start"],
                                 desc.spec["end"])
        if not lines:
            return []
        return [IngestItem(_parse_with(self.parser, lines, self.fields),
                           Granularity.FILE)]

    def spec(self) -> Dict[str, Any]:
        return {"kind": self.kind, "directory": self.directory,
                "pattern": self.pattern, "fields": list(self.fields),
                "idle_timeout_s": self.idle_timeout_s}


class SocketLineSource(SourceAdapter):
    """Line-stream endpoints: one descriptor per ``host:port``; the owning
    worker connects and drains the stream to EOF.  A socket cannot be range-
    split, so the endpoint is the replay unit — on reader death the whole
    endpoint re-issues to a survivor (the server must replay the stream,
    which the test harness's one-shot servers do)."""

    kind = "socket"

    def __init__(self, endpoints: Sequence[str], *, fields: Sequence[str] = (),
                 parser: Optional[str] = None,
                 connect_timeout_s: float = 5.0) -> None:
        self.endpoints = list(endpoints)
        self.fields = tuple(fields)
        self.parser = parser
        self.connect_timeout_s = float(connect_timeout_s)

    def describe(self) -> List[ShardDescriptor]:
        descs = []
        for i, ep in enumerate(self.endpoints):
            host, _, port = str(ep).rpartition(":")
            descs.append(ShardDescriptor(
                source_id=self.kind, index=i, kind=self.kind,
                spec={"host": host, "port": int(port)}, est_items=1))
        return descs

    def read(self, desc: ShardDescriptor) -> List[IngestItem]:
        with socket.create_connection(
                (desc.spec["host"], desc.spec["port"]),
                timeout=self.connect_timeout_s) as sk:
            chunks = []
            while True:
                buf = sk.recv(1 << 16)
                if not buf:
                    break
                chunks.append(buf)
        lines = b"".join(chunks).decode().splitlines()
        if not lines:
            return []
        return [IngestItem(_parse_with(self.parser, lines, self.fields),
                           Granularity.FILE)]

    def spec(self) -> Dict[str, Any]:
        return {"kind": self.kind, "endpoints": list(self.endpoints),
                "fields": list(self.fields)}


class GeneratorSpecSource(SourceAdapter):
    """Seeded generator shards: the descriptor is ``(seed, rows)`` — the
    worker re-derives the shard from the spec, so replay is free and zero
    bytes ever exist coordinator-side.  ``spec`` is an importable
    ``"pkg.module:fn"`` called as ``fn(rows, seed=seed, **kwargs)``."""

    kind = "generator"

    def __init__(self, spec: str, *, shards: int, rows: int, seed: int = 0,
                 kwargs: Optional[Dict[str, Any]] = None,
                 delay_s: float = 0.0) -> None:
        self.gen_spec = spec
        self.shards = int(shards)
        self.rows = int(rows)
        self.seed = int(seed)
        self.kwargs = dict(kwargs or {})
        self.delay_s = float(delay_s)
        resolve_callable(spec)      # fail fast on an unimportable spec

    def describe(self) -> List[ShardDescriptor]:
        return [ShardDescriptor(
            source_id=self.kind, index=i, kind=self.kind,
            spec={"gen": self.gen_spec, "seed": self.seed + i,
                  "rows": self.rows},
            est_items=1, est_bytes=0) for i in range(self.shards)]

    def read(self, desc: ShardDescriptor) -> List[IngestItem]:
        if self.delay_s:
            time.sleep(self.delay_s)
        fn = resolve_callable(desc.spec["gen"])
        cols = fn(desc.spec["rows"], seed=desc.spec["seed"], **self.kwargs)
        return [IngestItem(cols, Granularity.FILE)]

    def spec(self) -> Dict[str, Any]:
        return {"kind": self.kind, "spec": self.gen_spec,
                "shards": self.shards, "rows": self.rows, "seed": self.seed}


# ---------------------------------------------------------------------------
# registry: what a plan's SOURCE spec compiles to
# ---------------------------------------------------------------------------

SOURCE_KINDS: Dict[str, type] = {
    FileRangeSource.kind: FileRangeSource,
    DirectoryTailSource.kind: DirectoryTailSource,
    SocketLineSource.kind: SocketLineSource,
    GeneratorSpecSource.kind: GeneratorSpecSource,
}


def register_source(kind: str, cls: type) -> None:
    SOURCE_KINDS[kind] = cls


def build_source(spec: Dict[str, Any]) -> SourceAdapter:
    """Compile a plan-level SOURCE spec dict into its adapter."""
    cfg = dict(spec)
    kind = cfg.pop("kind", None)
    if kind not in SOURCE_KINDS:
        raise ValueError(
            f"unknown source kind {kind!r} (have: {sorted(SOURCE_KINDS)})")
    return SOURCE_KINDS[kind](**cfg)
