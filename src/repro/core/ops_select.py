"""SELECT-side ingestion operators: parser / filter / projection / replicator.

Paper Sec. IV-A: ``SELECT projection FROM LID USING parser WHERE filter
REPLICATE BY replicator`` compiles to the chain
``LID -> parser -> filter -> projection -> replicator``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from .items import Columns, Granularity, IngestItem, num_rows, take_rows
from .operators import IngestOp, register_op, resolve_callable


def identity_columns(cols: Columns) -> Columns:
    """Importable no-op transform — a picklable stand-in for ``lambda c: c``
    in plans that must cross a process boundary (``fn="repro.core.ops_select:
    identity_columns"``)."""
    return cols


def _as_text(data: Any) -> str:
    """FILE payload -> str.  uint8 ndarrays are accepted so raw text can ride
    the zero-copy shared-memory data plane to worker processes (bytes pickle
    in-band; arrays go out-of-band into the segment)."""
    if isinstance(data, np.ndarray):
        data = data.tobytes()
    if isinstance(data, (bytes, bytearray)):
        return bytes(data).decode()
    return str(data)


# --------------------------------------------------------------------- parsers
@register_op("parser")
class ParserOp(IngestOp):
    """FILE -> CHUNK: parse raw content into columnar record batches.

    ``schema`` maps field name -> numpy dtype; ``sep`` splits fields within a
    line (the TPC-H ``|`` convention).  ``chunk_rows`` bounds output chunk size
    so downstream operators see bounded working sets.  The parser labels each
    chunk with its index — the paper's example uses the parser label (e.g. a
    timestamp) for stage predicates like ``l_parser > now-1``.
    """

    name = "parser"
    granularity_in = Granularity.FILE
    granularity_out = Granularity.CHUNK
    cpu_heavy = True
    # per-item work with the default scalar-loop process_batch — safe inside
    # a batch-mode block, which makes parser-edges columnar-eligible (ISSUE 10)
    batch_capable = True

    def __init__(self, schema: Optional[Dict[str, str]] = None, sep: str = "|",
                 chunk_rows: int = 65536, label_fn: Optional[Callable[[Columns], Any]] = None,
                 **kw: Any) -> None:
        super().__init__(schema=schema, sep=sep, chunk_rows=chunk_rows, label_fn=label_fn, **kw)
        self.schema = schema
        self.sep = sep
        self.chunk_rows = chunk_rows
        # spec string "module:attr" keeps the op picklable (process backend)
        self.label_fn = resolve_callable(label_fn) if label_fn else None
        self._counter = 0

    def _parse_text(self, text: str) -> Columns:
        lines = [l for l in text.splitlines() if l]
        if self.schema is None:
            return {"line": np.array(lines, dtype=object)}
        fields = list(self.schema)
        rows = [l.split(self.sep) for l in lines]
        cols: Columns = {}
        for i, f in enumerate(fields):
            dt = np.dtype(self.schema[f])
            vals = [r[i] for r in rows]
            if dt.kind in "iuf":
                cols[f] = np.array(vals, dtype=dt)
            else:
                cols[f] = np.array(vals, dtype=dt)
        return cols

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        if isinstance(item.data, dict):
            cols = item.data  # already columnar (in-memory source)
        else:
            cols = self._parse_text(_as_text(item.data))
        n = num_rows(cols)
        for start in range(0, max(n, 1), self.chunk_rows):
            part = take_rows(cols, np.arange(start, min(start + self.chunk_rows, n)))
            label = self.label_fn(part) if self.label_fn else self._counter
            self._counter += 1
            yield IngestItem(part, Granularity.CHUNK, item.labels, dict(item.meta)).with_label(
                self.name, label)


@register_op("identity_parser")
class IdentityParserOp(ParserOp):
    """Pass columnar payloads through unchanged (in-memory ingest sources)."""

    name = "parser"

    def __init__(self, **kw: Any) -> None:
        kw.setdefault("schema", None)
        super().__init__(**kw)


@register_op("regex_parser")
class RegexParserOp(IngestOp):
    """FILE -> CHUNK: parse semi-structured log lines with a named-group
    regex (the paper's cloud-log scenario, Sec. IV-C).

    Each line is matched against ``pattern``; named groups become columns,
    cast per ``schema`` (group name -> numpy dtype; unnamed groups and
    unmatched lines are dropped — the dropped count is recorded in
    ``meta["dropped"]``).  Per-line regex matching is interpreter-bound CPU
    work, which is exactly what the process node backend parallelizes across
    cores; ``pattern`` is a plain string, so the operator ships to worker
    processes by spec.
    """

    name = "parser"
    granularity_in = Granularity.FILE
    granularity_out = Granularity.CHUNK
    cpu_heavy = True
    batch_capable = True

    def __init__(self, pattern: str, schema: Optional[Dict[str, str]] = None,
                 chunk_rows: int = 65536, **kw: Any) -> None:
        super().__init__(pattern=pattern, schema=schema, chunk_rows=chunk_rows, **kw)
        import re
        self._re = re.compile(pattern)
        if not self._re.groupindex:
            raise ValueError("regex_parser pattern needs named groups "
                             "(?P<field>...) to produce columns")
        self.schema = schema or {}
        self.chunk_rows = chunk_rows
        self._counter = 0

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        text = _as_text(item.data)
        match = self._re.match
        lines = text.splitlines()
        rows = [m.groups() for m in map(match, lines) if m]
        dropped = len([l for l in lines if l]) - len(rows)
        fields = sorted(self._re.groupindex, key=self._re.groupindex.get)
        cols: Columns = {}
        for f in fields:
            gi = self._re.groupindex[f] - 1   # groups() is 0-based, all groups
            dt = np.dtype(self.schema.get(f, object))
            cols[f] = np.array([r[gi] for r in rows], dtype=dt)
        n = len(rows)
        for start in range(0, max(n, 1), self.chunk_rows):
            part = take_rows(cols, np.arange(start, min(start + self.chunk_rows, n)))
            label = self._counter
            self._counter += 1
            out = IngestItem(part, Granularity.CHUNK, item.labels,
                             dict(item.meta, dropped=dropped))
            yield out.with_label(self.name, label)


# --------------------------------------------------------------------- filters
@register_op("filter")
class FilterOp(IngestOp):
    """CHUNK -> CHUNK row filter. ``predicate`` is a vectorized Columns -> bool mask.

    A data *reducer* (expansion < 1): the reordering rule pushes it down.
    """

    name = "filter"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK
    expansion = 0.5
    batch_capable = True

    def __init__(self, predicate: Callable[[Columns], np.ndarray], fields: Sequence[str] = (),
                 selectivity: float = 0.5, **kw: Any) -> None:
        super().__init__(predicate=predicate, fields=tuple(fields), selectivity=selectivity, **kw)
        if isinstance(predicate, tuple):
            # layouts-style (field, op, value) selection triple — a picklable
            # predicate spec (the process backend ships these, not closures)
            from ..layouts.blocks import _OPS
            f, o, v = predicate
            fields = tuple(fields) or (f,)
            predicate = lambda cols: _OPS[o](cols[f], v)
        else:
            predicate = resolve_callable(predicate)
        self.predicate = predicate
        self.fields = tuple(fields)  # fields the predicate reads (for reorder legality)
        self.expansion = selectivity

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        mask = np.asarray(self.predicate(cols), dtype=bool)
        kept = take_rows(cols, np.nonzero(mask)[0])
        yield IngestItem(kept, item.granularity, item.labels, dict(item.meta)).with_label(
            self.name, int(mask.sum()))


@register_op("project")
class ProjectOp(IngestOp):
    """CHUNK -> CHUNK column projection (a reducer along the field axis)."""

    name = "project"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK
    expansion = 0.7
    batch_capable = True

    def __init__(self, fields: Sequence[str], **kw: Any) -> None:
        super().__init__(fields=tuple(fields), **kw)
        self.fields = tuple(fields)

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = {k: v for k, v in item.data.items() if k in self.fields}
        yield IngestItem(cols, item.granularity, item.labels, dict(item.meta)).with_label(
            self.name, len(cols))


@register_op("map")
class MapOp(IngestOp):
    """CHUNK -> CHUNK arbitrary vectorized transform (custom ingest operator
    hook, e.g. ML feature projection per the paper's example)."""

    name = "map"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK
    batch_capable = True

    def __init__(self, fn: Callable[[Columns], Columns], label: Any = 1, **kw: Any) -> None:
        super().__init__(fn=fn, label=label, **kw)
        # fn may be an import spec "module:attr" so the op stays picklable
        self.fn = resolve_callable(fn)
        self.label = label

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        yield IngestItem(self.fn(item.data), item.granularity, item.labels,
                         dict(item.meta)).with_label(self.name, self.label)


# ------------------------------------------------------------------ replicator
@register_op("replicate")
class ReplicateOp(IngestOp):
    """Emit ``copies`` labelled replicas of each item (a data *expander*:
    the reordering rule pushes it up / as late as possible).

    Labels are 1..copies — the paper's stage predicates (``l_replicate1=2``)
    route each replica to a different sub-plan.  ``probability`` < 1 gives the
    probabilistic replication used for Bernoulli sampling.
    """

    name = "replicate"
    expansion = 3.0
    batch_capable = True

    def __init__(self, copies: int = 3, probability: float = 1.0, seed: int = 0,
                 tag: Optional[str] = None, **kw: Any) -> None:
        super().__init__(copies=copies, probability=probability, seed=seed, tag=tag, **kw)
        self.copies = copies
        self.probability = probability
        self.tag = tag  # distinguishes replicate1 / replicate2 in nested plans
        self.expansion = float(copies) * probability
        self._rng = np.random.default_rng(seed)

    @property
    def label_key(self) -> str:
        return self.tag or self.name

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        for i in range(1, self.copies + 1):
            if self.probability < 1.0 and self._rng.random() > self.probability:
                continue
            yield item.with_label(self.label_key, i)
