"""The INGESTBASE runtime engine (paper Sec. VI).

* **Inter-node parallelism** — the client ships the *optimized* plan to every
  node in the slaves list and runs it over node-local shards ("ship the plan
  to the data").  Nodes here are persistent ``NodeExecutor`` workers over
  per-node directories; the remote-shell seam is ``launch_remote``
  (DESIGN.md §2), invoked once per compiled plan, not once per stage barrier.
  ``backend="process"`` realizes the seam with one long-lived worker
  *process* per node (``core/procexec.py``, DESIGN.md §6) — real CPU
  parallelism for GIL-bound operators; ``backend="thread"`` is the default.
* **Intra-node parallelism** — parallel-mode operators fan out over a thread
  pool (see operators.IngestOp._parallel_iter).
* **Work stealing** — when sources are given as a shared list, nodes pull
  shards from a global queue, so stragglers simply take fewer shards.
* **Distributed I/O** — shuffle via the ``ShuffleCoordinator`` control plane
  (DESIGN.md §4): node workers partition their own output by the plan's
  routing key and exchange partitions peer-to-peer (shared-memory segments /
  in-memory deposits / DFS spill files past the per-edge share); the
  coordinator relays only manifests — zero item bytes cross its pipes on
  the shuffle path.  ``synchronous=True`` (and cross-segment boundaries)
  fall back to the legacy coordinator barrier.  Placement via location IDs,
  replication decoupled from placement.
* **In-flight fault tolerance** — pipeline blocks are checkpoints: a failing
  operator retries its block from the previous materialization; after
  ``max_retries`` failures it is replaced by a dummy pass-through operator
  labelling items with -1.  Node failures reassign shards + location IDs to
  the next node in the slaves order.
"""
from __future__ import annotations

import itertools
import os
import pickle
import queue
import shutil
import threading
import time
from collections import defaultdict
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple, Union

from .exchange import (PartitionExchange, build_manifest, columnar_file_name,
                       exchange_file_name, partition_items, resident_file_name,
                       unlink_segment, write_columnar_file,
                       write_partition_file)
from .items import ColumnarBatch, IngestItem, items_nbytes
from .operators import (IngestOp, OperatorFailure, PassThroughOp,
                        run_ops_batched)
from .optimizer import IngestionOptimizer
from .plan import (IngestPlan, StagePlan, failed_op_index, route_items,
                   stage_consumers)
from .procexec import ProcessNodeExecutor, WorkerDeath
from .sources import ShardDescriptor, SourceAdapter, build_source
from .store import DataStore


class NodeFailure(RuntimeError):
    """Simulated machine failure during ingestion.

    ``stage_index`` records which stage the death surfaced at (None when it
    happened outside stage execution, e.g. at plan install): the streaming
    engine's lineage-cone recovery needs to know whether the survivors had
    already completed the ingest segment when the node died (ISSUE 8)."""

    stage_index: Optional[int] = None


class _CohortReplay(RuntimeError):
    """Batch-mode recovery escalation (ROADMAP "batch shuffle cohort
    replay"): a node died at or after a shuffle-consuming stage, so its
    processed groups mixed other nodes' lineages and cannot be recovered
    from its own source shards.  The only exact recovery is replaying the
    whole run as one epoch on the survivors — ``RuntimeEngine.run`` catches
    this, aborts the run's staged epoch, invalidates its exchange rounds,
    and re-executes on the live set."""


#: legacy static shuffle spill threshold (used when no memory budget is set)
DEFAULT_SPILL_BYTES = 32 << 20
#: floor under budget-derived spill thresholds — a tiny budget must not turn
#: every shuffle round into a blocking DFS round-trip
MIN_SPILL_BYTES = 1 << 20


def derive_spill_bytes(memory_budget_bytes: int, reserved_bytes: int = 0) -> int:
    """Shuffle spill threshold from a shared memory budget: whatever the
    ingest queues are expected to hold (``reserved_bytes``) is carved out
    first, the remainder bounds in-memory shuffle rounds (ROADMAP
    "spill-aware shuffle sizing")."""
    return max(MIN_SPILL_BYTES, int(memory_budget_bytes) - int(reserved_bytes))


@dataclass
class RunReport:
    """What the engine observed while executing a plan."""

    stage_items: Dict[str, int] = field(default_factory=dict)
    op_failures: Dict[str, int] = field(default_factory=dict)
    dummy_substitutions: List[str] = field(default_factory=list)
    node_failures: List[str] = field(default_factory=list)
    reassigned_shards: int = 0
    shuffled_items: int = 0
    shuffle_spills: int = 0        # rounds that materialized DFS spill files
    shuffle_async_rounds: int = 0  # rounds handled fully off the DFS
    shuffle_exchange_rounds: int = 0   # peer-to-peer exchange rounds
    # item bytes the *coordinator's* shuffle path moved (legacy barrier only
    # — a peer-exchange round keeps this at zero: the coordinator relays
    # manifests, never item bytes)
    shuffle_coordinator_bytes: int = 0
    # partition bytes handed worker-to-worker (shm segments, spill files,
    # and the thread backend's direct in-memory deposits)
    shuffle_peer_bytes: int = 0
    # --- node-resident dataflow (ISSUE 5): narrow stage edges -------------
    # item bytes that crossed a coordinator pipe at a *stage boundary*
    # (stage outputs returned to / re-shipped from the coordinator).  With
    # the resident exchange plane this stays zero end-to-end: only the
    # final store-stage registration metadata reaches the coordinator.
    stage_coordinator_bytes: int = 0
    stage_exchange_rounds: int = 0     # narrow (identity-routed) rounds
    stage_resident_bytes: int = 0      # bytes kept node-resident across edges
    resident_spills: int = 0           # resident buckets spilled to the DFS
    cohort_replays: int = 0            # batch whole-run replays (post-shuffle death)
    # --- lineage-cone recovery + liveness (ISSUE 8) -------------------------
    cone_replays: int = 0              # deaths repaired by a cone patch alone
    replayed_rows: int = 0             # rows re-executed by recovery (cone or epoch)
    spawn_retries: int = 0             # worker spawn attempts beyond the first
    # --- worker-pull sources (ISSUE 6): the source hop ---------------------
    # item bytes the coordinator routed on the source hop.  Descriptor-backed
    # sources keep this at zero on both backends — the coordinator hands out
    # shard descriptors, workers read the data; only the legacy pushed-
    # iterator path (feed joints, raw iterators) still counts bytes here.
    source_coordinator_bytes: int = 0
    source_descriptors: int = 0        # shard descriptors issued to workers
    source_reissues: int = 0           # descriptors re-issued after a reader death
    source_items: int = 0              # items workers materialized from descriptors
    # --- batch operator tier (ISSUE 7): optimizer-selected vectorization ----
    vectorized_rows: int = 0           # rows that entered batch-mode blocks
    batch_fallbacks: int = 0           # ops that dropped back to the scalar path
    kernel_ms: float = 0.0             # time inside vectorized encode kernels
    # --- columnar data plane (ISSUE 10): column buffers across stage edges --
    columnar_rounds: int = 0           # exchange rounds with >=1 columnar part
    columnar_bytes: int = 0            # partition bytes that crossed columnar
    columnar_fallbacks: int = 0        # producers that fell back to items
    # --- socket fabric + degraded exchange (ISSUE 9) ------------------------
    degraded_exchange_rounds: int = 0  # rounds with >=1 streamed (cross-host) part
    degraded_peer_bytes: int = 0       # partition bytes that crossed host-to-host
    sweep_skipped_remote: int = 0      # shm sweeps skipped: worker not local
    wall_time_s: float = 0.0
    per_node_shards: Dict[str, int] = field(default_factory=dict)


@dataclass
class FaultInjection:
    """Test hooks: deterministic failures."""

    # (stage_name, op_index) -> number of consecutive failures to inject
    op_failures: Dict[Tuple[str, int], int] = field(default_factory=dict)
    # node -> stage name after which the node dies
    node_death_after_stage: Dict[str, str] = field(default_factory=dict)


# --------------------------------------------------------------------------
# Persistent node executors (DESIGN.md §4)
# --------------------------------------------------------------------------
class _ExecutorLane:
    """One FIFO worker thread: jobs run in submission order."""

    def __init__(self, name: str) -> None:
        self.jobs: "queue.Queue[Optional[Tuple[Callable, tuple, Future]]]" = queue.Queue()
        self.thread = threading.Thread(target=self._loop,
                                       name=f"nodeexec-{name}", daemon=True)
        self.thread.start()

    def submit(self, fn: Callable, *args: Any) -> Future:
        fut: Future = Future()
        self.jobs.put((fn, args, fut))
        return fut

    def _loop(self) -> None:
        while True:
            job = self.jobs.get()
            if job is None:
                return
            fn, args, fut = job
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                fut.set_result(fn(*args))
            except BaseException as e:  # delivered via Future.result()
                fut.set_exception(e)

    def stop(self) -> None:
        self.jobs.put(None)


class NodeExecutor:
    """One long-lived worker per node, owning the node's plan clone.

    The plan-clone cache is bounded (``PLAN_CACHE``): a long-lived engine
    running many different plans re-clones an evicted one instead of pinning
    every plan it ever saw.

    The engine used to create a fresh ``ThreadPoolExecutor`` at every stage
    barrier and re-clone ("re-ship") the whole plan per ``_execute`` call.  A
    NodeExecutor instead persists for the engine's lifetime and owns

    * the node's **plan clone** — installed once per compiled plan, so
      streaming epochs stop re-shipping plans (operator state, including
      dummy substitutions after repeated failures, survives across epochs
      exactly as it would in a long-running per-node JVM), and
    * one or more **lanes** — named FIFO worker threads.  Batch stages run on
      the default ``"main"`` lane; the pipelined streaming engine runs epoch
      N+1's ingest segment on the ``"ingest"`` lane while epoch N's store
      segment occupies the ``"store"`` lane, overlapping transform compute
      with commit I/O on every node (DESIGN.md §4).
    """

    PLAN_CACHE = 4

    def __init__(self, node: str) -> None:
        self.node = node
        self._lock = threading.Lock()
        self._lanes: Dict[str, _ExecutorLane] = {}
        # id(original) -> (original, clone); the original is pinned so its id
        # cannot be recycled while the cache entry lives
        self._plans: Dict[int, Tuple[List[StagePlan], List[StagePlan]]] = {}

    def install_plan(self, stage_plans: List[StagePlan],
                     cloner: Callable[[str, List[StagePlan]], List[StagePlan]]
                     ) -> List[StagePlan]:
        """This node's clone of ``stage_plans`` — cloned on first sight only
        ("ship the plan to the data" happens once, not per barrier)."""
        key = id(stage_plans)
        with self._lock:
            cached = self._plans.get(key)
            if cached is not None and cached[0] is stage_plans:
                return cached[1]
            clone = cloner(self.node, stage_plans)
            while len(self._plans) >= self.PLAN_CACHE:   # bounded: evict oldest
                self._plans.pop(next(iter(self._plans)))
            self._plans[key] = (stage_plans, clone)
            return clone

    def submit(self, fn: Callable, *args: Any, lane: str = "main") -> Future:
        with self._lock:
            ln = self._lanes.get(lane)
            if ln is None:
                ln = self._lanes[lane] = _ExecutorLane(f"{self.node}:{lane}")
        return ln.submit(fn, *args)

    def shutdown(self) -> None:
        with self._lock:
            lanes, self._lanes = list(self._lanes.values()), {}
            self._plans.clear()
        for ln in lanes:
            ln.stop()


# --------------------------------------------------------------------------
# Shuffle: control-plane coordinator + worker-side data plane (DESIGN.md §4)
# --------------------------------------------------------------------------
@dataclass
class ExchangeRound:
    """Control-plane record of one peer-to-peer exchange round.

    Since ISSUE 5 a round covers *any* stage edge, not just shuffles:
    ``key=None`` is a **narrow** round (identity routing — every producer's
    output stays resident on its own node), a non-None key partitions across
    the peers.  ``pinned=True`` marks a round whose consuming stage lies
    outside the slice that produced it (the ingest/store segment boundary):
    it survives the ``_execute`` call in the coordinator's pinned registry
    and the next slice adopts it.

    Everything here is metadata: stage/epoch identity, the pinned target
    set, per-producer manifests (counts, sizes, segment/file refs), and the
    consumer-delivery cursor.  Item bytes never enter this structure."""

    xid: int
    stage: str
    key: Optional[str]                # routing key; None = narrow (identity)
    epoch: int                        # -1 = batch run
    targets: List[str]                # pinned executing-node set = partition targets
    consumers: List[str]              # ALL consuming stage names (DAG order)
    spill_share: int                  # per-edge spill threshold, bytes
    pinned: bool = False              # consumed (partly) by a later slice
    manifests: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    total_count: int = 0              # items partitioned (all producers)
    total_bytes: int = 0              # peer-bound partition bytes
    resident_bytes: int = 0           # bytes that stayed on their own node
    served: Dict[str, int] = field(default_factory=dict)   # node -> stages served
    # nodes that were ever handed refs — unlike `served` (reset when a
    # consumer fails, so finish_round reclaims best-effort), this is never
    # cleared: refs once delivered may already be consumed and must not be
    # re-served to a redirect target
    delivered: Set[str] = field(default_factory=set)
    consumers_done: int = 0
    spilled: bool = False
    degraded_parts: int = 0           # cross-host (streamed) partitions
    degraded_bytes: int = 0           # their bytes (subset of total_bytes)
    # columnar data plane (ISSUE 10): the optimizer proved every consuming
    # block pair batch-capable, so producers may cross this edge as a
    # ColumnarBatch (column buffers, no per-item pickling).  A producer whose
    # output doesn't pack (mixed payload kinds, exotic labels) falls back to
    # the scalar path per-manifest — counted, never wrong.
    columnar: bool = False
    columnar_parts: int = 0           # partitions that crossed as column buffers
    columnar_bytes: int = 0           # their bytes (subset of total+resident)
    columnar_fallbacks: int = 0       # producers that fell back to item lists

    def worker_ctx(self, spill_dir: str,
                   hosts: Optional[Dict[str, str]] = None) -> Dict[str, Any]:
        """The shuffle instruction shipped to a producing worker.  ``hosts``
        (node -> host label, ISSUE 9) tells the worker which targets are NOT
        shm-reachable: partitions for another host go degraded (spill file +
        stream endpoint) instead of a shared-memory segment."""
        ctx = {"xid": self.xid, "key": self.key,
               "targets": list(self.targets), "epoch": self.epoch,
               "spill_share": self.spill_share, "spill_dir": spill_dir}
        if hosts:
            ctx["hosts"] = dict(hosts)
        if self.columnar:
            ctx["columnar"] = True
        return ctx


def _desc_paths(desc: Dict[str, Any]) -> List[str]:
    """Every spill path a partition descriptor references: the primary
    ``path``/``spilled`` plus the ``extra_paths`` a manifest merge stacked
    (ISSUE 8 cone patches deal into an already-recorded round)."""
    paths: List[str] = []
    p = desc.get("path") or desc.get("spilled")
    if p:
        paths.append(p)
    paths.extend(desc.get("extra_paths", ()))
    return paths


def _merge_manifest(prev: Dict[str, Any], fresh: Dict[str, Any]) -> None:
    """Fold a producer's second manifest for the same round into its first
    (the node-side buckets extended on deposit, so the union is what the
    consumers will actually collect)."""
    prev["total_count"] = (int(prev.get("total_count", 0))
                           + int(fresh.get("total_count", 0)))
    parts = prev.setdefault("parts", {})
    for dst, desc in fresh.get("parts", {}).items():
        have = parts.get(dst)
        if have is None:
            parts[dst] = desc
            continue
        have["count"] = int(have.get("count", 0)) + int(desc.get("count", 0))
        have["nbytes"] = (int(have.get("nbytes", 0))
                          + int(desc.get("nbytes", 0)))
        known = set(_desc_paths(have))
        for p in _desc_paths(desc):
            if p not in known:
                have.setdefault("extra_paths", []).append(p)


class ShuffleCoordinator:
    """The shuffle's *control plane* (DESIGN.md §4).

    Since ISSUE 4 the default data path is a **decentralized peer exchange**:
    after a shuffle-boundary stage, each node worker partitions its own
    output by the plan's routing key (``StagePlan.shuffle_key``) and hands
    partitions directly to peer workers — per-edge shared-memory segments
    (process backend, ``exchange.encode_partition``) or direct in-memory
    deposits (thread backend), with oversized partitions crossing as
    peer-readable spill files under the DFS dir.  This coordinator only

    * opens a round per boundary (``plan_round``) and pins its target set,
    * collects per-producer **manifests** — stage, epoch, counts, sizes,
      segment names / file paths — never item bytes,
    * hands each consumer its incoming refs (``refs_for`` / ``serve``), and
    * reclaims a round's segments/files when it finishes or its epoch is
      invalidated (node death -> epoch replay).

    The **legacy barrier** (groups collected and redistributed through the
    coordinator) remains for two cases: ``synchronous=True`` (the paper-
    verbatim in-barrier DFS round-trip, kept for debugging and as the
    benchmark baseline) and boundaries whose consuming stage lies outside
    the executing stage slice (cross-segment shuffles), where the items
    must outlive the worker call anyway.  Only this legacy path moves item
    bytes through the coordinator — counted in
    ``RunReport.shuffle_coordinator_bytes``, which a peer-exchange round
    keeps at zero.
    """

    def __init__(self, store: DataStore, spill_bytes: int = 32 << 20,
                 synchronous: bool = False, columnar: bool = True) -> None:
        self.store = store
        self.spill_bytes = spill_bytes
        self.synchronous = synchronous
        #: columnar data plane master switch (ISSUE 10): when False every
        #: round stays item-at-a-time — the byte-identical oracle path
        self.columnar = columnar
        self._lock = threading.Lock()
        self._stage_locks: Dict[str, threading.Lock] = {}
        self._pending: Dict[str, Future] = {}
        self._writer: Optional[_ExecutorLane] = None
        self._spilled_stages: set = set()   # stages with DFS group files
        self._xids = itertools.count()
        self._rounds: Dict[int, ExchangeRound] = {}
        self._epoch_rounds: Dict[int, Set[int]] = {}
        # rounds pinned across _execute slices, keyed (epoch, producing
        # stage): the ingest segment leaves them here, the store segment
        # adopts them (ISSUE 5 cross-segment exchange)
        self._pinned: Dict[Tuple[int, str], ExchangeRound] = {}
        #: test hook: called as (round, producer_node) when a manifest lands
        #: — lets fault tests kill a worker exactly mid-exchange
        self.test_on_manifest: Optional[Callable[[ExchangeRound, str], None]] = None

    # ------------------------------------------------------------------ util
    def _stage_lock(self, stage: str) -> threading.Lock:
        with self._lock:
            lk = self._stage_locks.get(stage)
            if lk is None:
                lk = self._stage_locks[stage] = threading.Lock()
            return lk

    def _writer_lane(self) -> _ExecutorLane:
        with self._lock:
            if self._writer is None:
                self._writer = _ExecutorLane("shuffle-journal")
            return self._writer

    def _dfs_dir(self, stage: str) -> str:
        return os.path.join(self.store.dfs_dir, f"shuffle_{stage}")

    @staticmethod
    def _shuffle_key(sp: StagePlan) -> Optional[str]:
        return sp.shuffle_key or sp.compute_shuffle_key()

    # ------------------------------------------- peer-exchange control plane
    def plan_round(self, stage_plans: List[StagePlan], si: int, stop: int,
                   live: List[str],
                   epoch: Optional[int]) -> Optional[ExchangeRound]:
        """Open a peer-exchange round for stage ``si``'s outgoing edges.

        Since ISSUE 5 every edge gets a round: shuffle edges partition by
        the routing key (``shuffle_key``), narrow edges keep the output
        resident on the producing node (``key=None``), and an edge whose
        consumer lies outside the executing slice [si+1, stop) pins the
        round across slices instead of falling back to the coordinator
        barrier.  Returns None only for terminal stages (no consumer in the
        DAG) and in ``synchronous`` legacy mode."""
        sp = stage_plans[si]
        if self.synchronous or not sp.ops or not live:
            return None
        consumers = stage_consumers(stage_plans, si)
        if not consumers:
            return None
        in_slice = {stage_plans[j].name for j in range(si + 1, stop)}
        pinned = any(c not in in_slice for c in consumers)
        e = -1 if epoch is None else epoch
        if pinned:
            with self._lock:
                existing = self._pinned.get((e, sp.name))
            if existing is not None:
                # a lineage-cone replay (ISSUE 8) re-runs the ingest segment
                # for a patch of shards: the survivors' partitions already
                # live in this pinned round, so the patch producers merge
                # into it (deposits extend node-side buckets, manifests
                # merge in record_manifest) instead of opening a second
                # round the store slice would never adopt.  Whole-epoch
                # replay never reuses: it invalidates the epoch (clearing
                # the pinned registry) before re-executing.
                for n in live:
                    if n not in existing.targets:
                        existing.targets.append(n)
                return existing
        rnd = ExchangeRound(
            xid=next(self._xids), stage=sp.name, key=self._shuffle_key(sp),
            epoch=e, targets=list(live),
            consumers=consumers,
            spill_share=max(1, self.spill_bytes // max(1, len(live))),
            pinned=pinned,
            # the edge goes columnar only when the optimizer proved EVERY
            # consuming stage's first block batch-capable (ISSUE 10) — a
            # single scalar consumer keeps the whole round item-at-a-time
            columnar=bool(self.columnar and consumers and
                          all(sp.columnar_edges.get(c, False)
                              for c in consumers)))
        with self._lock:
            self._rounds[rnd.xid] = rnd
            self._epoch_rounds.setdefault(rnd.epoch, set()).add(rnd.xid)
            if rnd.pinned:
                self._pinned[(rnd.epoch, rnd.stage)] = rnd
        return rnd

    def adopt_pinned(self, epoch: Optional[int],
                     slice_stages: Sequence[str]) -> List[ExchangeRound]:
        """Hand a starting ``_execute`` slice the rounds an earlier slice of
        the same epoch pinned for it (producing stage outside the slice, at
        least one consuming stage inside).  Adoption removes the pinned
        registration — the consuming slice owns the round's lifecycle from
        here (``finish_round`` on drain, epoch invalidation on failure)."""
        e = -1 if epoch is None else epoch
        names = set(slice_stages)
        with self._lock:
            keys = [k for k, r in self._pinned.items()
                    if k[0] == e and (set(r.consumers) & names)]
            return [self._pinned.pop(k) for k in keys]

    def record_manifest(self, rnd: ExchangeRound, node: str,
                        manifest: Dict[str, Any]) -> None:
        """A producer's partition manifest arrived: lease its spill files,
        account sizes — metadata only, the partitions themselves went (or
        stayed) worker-side."""
        for dst, desc in manifest.get("parts", {}).items():
            path = desc.get("path") or desc.get("spilled")
            if path:
                rnd.spilled = True
                self.store.lease_exchange_path(path)
            if desc.get("kind") == "stream":
                # degraded mode (ISSUE 9): this partition crosses hosts as
                # a streamed spill file, not a shared-memory segment
                rnd.degraded_parts += 1
                rnd.degraded_bytes += int(desc.get("nbytes", 0))
            if desc.get("columnar"):
                # ISSUE 10: this partition crossed as a column buffer —
                # no per-item pickling on either side of the edge
                rnd.columnar_parts += 1
                rnd.columnar_bytes += int(desc.get("nbytes", 0))
            if dst != node:
                rnd.total_bytes += int(desc.get("nbytes", 0))
            else:
                # the node's own slice: stayed resident (narrow edges keep
                # the entire output here — zero-coordinator dataflow)
                rnd.resident_bytes += int(desc.get("nbytes", 0))
        if manifest.get("columnar_fallback"):
            rnd.columnar_fallbacks += 1
        prev = rnd.manifests.get(node)
        if prev is not None:
            # a cone replay's patch producer (ISSUE 8) dealt a second time
            # into the same pinned round: node-side deposits extend the
            # bucket, so the manifests merge — counts and sizes sum, and a
            # second spill path stacks under "extra_paths" so every cleanup
            # walk still reaches it
            _merge_manifest(prev, manifest)
        else:
            rnd.manifests[node] = manifest
        rnd.total_count += int(manifest.get("total_count", 0))
        if self.test_on_manifest is not None:
            self.test_on_manifest(rnd, node)

    def serve(self, rnd: ExchangeRound, node: str) -> bool:
        """Advance the consumer-stage cursor for ``node``; True when this is
        the round's final consuming stage (the node-side collect pops)."""
        served = rnd.served.get(node, 0)
        rnd.served[node] = served + 1
        rnd.delivered.add(node)
        return served + 1 >= len(rnd.consumers)

    def refs_for(self, rnd: ExchangeRound, node: str) -> List[Dict[str, Any]]:
        """Fetch descriptors for the consumer job on ``node`` (process
        backend).  The first consuming stage receives the real refs —
        segments, spill files, the node's resident marker; later consuming
        stages replay the worker's cached bucket.  ``keep`` tells the worker
        another consuming stage follows."""
        served = rnd.served.get(node, 0)
        last = self.serve(rnd, node)
        if served:
            return [{"kind": "cached", "xid": rnd.xid, "keep": not last}]
        refs: List[Dict[str, Any]] = []
        for src, m in rnd.manifests.items():
            desc = m.get("parts", {}).get(node)
            if not desc:
                continue
            kind = desc["kind"]
            if kind == "mem":        # thread backend: bucket handoff, no ref
                continue
            if kind == "resident":
                if src == node:
                    refs.append({"kind": "resident", "xid": rnd.xid,
                                 "keep": not last})
                continue
            refs.append({**desc, "xid": rnd.xid, "src": src, "keep": not last})
        return refs

    def finish_round(self, rnd: ExchangeRound) -> bool:
        """A round's final consuming stage drained: drop the bookkeeping,
        release file leases, and reclaim refs addressed to nodes that never
        fetched (a consumer died mid-round).  Returns True when node-side
        buckets may still hold data — the engine then drops the round from
        the exchanges."""
        with self._lock:
            self._rounds.pop(rnd.xid, None)
            self._pinned.pop((rnd.epoch, rnd.stage), None)
            er = self._epoch_rounds.get(rnd.epoch)
            if er is not None:
                er.discard(rnd.xid)
                if not er:
                    self._epoch_rounds.pop(rnd.epoch, None)
        leftovers = False
        for src, m in rnd.manifests.items():
            for dst, desc in m.get("parts", {}).items():
                kind = desc["kind"]
                fetched = rnd.served.get(dst, 0) > 0
                for path in _desc_paths(desc):
                    if not fetched and kind in ("file", "resident", "stream"):
                        # an unfetched resident spill's owning worker may be
                        # dead (its bucket died with it) — reclaim the file
                        # here; a live holder's later drop no-ops on it
                        try:
                            os.remove(path)
                        except OSError:
                            pass
                    self.store.release_exchange_path(path)
                if kind == "shm" and not fetched:
                    unlink_segment(desc["shm"])
                if kind in ("mem", "resident") and not fetched:
                    leftovers = True
        return leftovers

    def invalidate_epoch(self, epoch: Optional[int]) -> List[int]:
        """Epoch abort/replay: destroy every live round of the epoch —
        unlink unconsumed segments, delete spill files, release leases.
        Returns the dead round ids so the engine can clear node-side
        buckets (``PartitionExchange.drop`` / worker drop messages)."""
        e = -1 if epoch is None else epoch
        with self._lock:
            xids = sorted(self._epoch_rounds.pop(e, ()))
            rounds = [self._rounds.pop(x) for x in xids if x in self._rounds]
            for k in [k for k in self._pinned if k[0] == e]:
                del self._pinned[k]
        for rnd in rounds:
            for src, m in rnd.manifests.items():
                for dst, desc in m.get("parts", {}).items():
                    if desc["kind"] == "shm":
                        unlink_segment(desc["shm"])
                    for path in _desc_paths(desc):
                        try:
                            os.remove(path)
                        except OSError:
                            pass
                        self.store.release_exchange_path(path)
        return xids

    def invalidate_producer(self, epoch: Optional[int], node: str) -> List[int]:
        """Lineage-cone recovery (ISSUE 8): strip ONE dead producer's
        contribution from the epoch's live rounds, leaving every survivor's
        partitions intact.  Sound only when the epoch's rounds are
        identity-routed (``key=None``) — then a producer's output lives
        solely in its own bucket and separates cleanly; a shuffle round
        commingles producers per target, which is why callers gate on
        ``plan.cone_replay_capable``.  The dead node's unconsumed segments
        and spill files are reclaimed, its manifests and delivery cursors
        forgotten, and it leaves the rounds' target sets (a later cone
        patch re-deals over the survivors).  Returns the touched round ids
        so the engine can drop the matching node-side buckets."""
        e = -1 if epoch is None else epoch
        with self._lock:
            xids = sorted(self._epoch_rounds.get(e, ()))
            rounds = [self._rounds[x] for x in xids if x in self._rounds]
        touched: List[int] = []
        for rnd in rounds:
            if node in rnd.targets:
                rnd.targets.remove(node)
            rnd.served.pop(node, None)
            rnd.delivered.discard(node)
            m = rnd.manifests.pop(node, None)
            if m is None:
                continue
            touched.append(rnd.xid)
            rnd.total_count -= int(m.get("total_count", 0))
            for dst, desc in m.get("parts", {}).items():
                if desc["kind"] == "shm":
                    unlink_segment(desc["shm"])
                nb = int(desc.get("nbytes", 0))
                if dst != node:
                    rnd.total_bytes -= nb
                else:
                    rnd.resident_bytes -= nb
                for path in _desc_paths(desc):
                    try:
                        os.remove(path)
                    except OSError:
                        pass
                    self.store.release_exchange_path(path)
        return touched

    # --------------------------------------------------------------- barrier
    def barrier(self, sp: StagePlan,
                outputs: Dict[str, Dict[str, List[IngestItem]]],
                live: List[str], report: RunReport) -> None:
        """Legacy coordinator-side redistribution (synchronous mode and
        cross-slice boundaries).  ``live`` is the caller's pinned
        executing-node set — groups are collected from and reassigned over
        exactly these nodes.  This is the only path that moves item bytes
        through the coordinator (``shuffle_coordinator_bytes``)."""
        if not sp.ops:
            return
        shuffle_by = self._shuffle_key(sp)
        if shuffle_by is None:
            return
        with self._stage_lock(sp.name):
            with self._lock:
                prev = self._pending.pop(sp.name, None)
            if prev is not None:
                prev.result()  # double buffer: last round's journal must land

            groups: Dict[Any, List[IngestItem]] = {}
            nbytes = 0
            for n in live:
                for it in outputs[n][sp.name]:
                    g = it.label_value(shuffle_by, 0)
                    groups.setdefault(g, []).append(it)
                    nbytes += it.nbytes()
                    report.shuffled_items += 1
                outputs[n][sp.name] = []
            if not groups:
                return
            report.shuffle_coordinator_bytes += nbytes
            order = sorted(groups, key=str)
            if self.synchronous:
                # legacy path: DFS round-trip inside the barrier
                report.shuffle_spills += 1
                dfs = self._write_groups(sp.name, order, groups)
                groups.clear()
                for gi, fn in enumerate(sorted(os.listdir(dfs))):
                    target = live[gi % len(live)]
                    with open(os.path.join(dfs, fn), "rb") as f:
                        outputs[target][sp.name].extend(pickle.load(f))
                # consume-on-read: the next round must not merge these files
                shutil.rmtree(dfs, ignore_errors=True)
                self.store.release_exchange_path(dfs)
                return
            for gi, g in enumerate(order):
                outputs[live[gi % len(live)]][sp.name].extend(groups[g])
            if nbytes > self.spill_bytes:
                # oversized round: materialize the group files on the DFS in
                # the background — overlapped with the next epoch's ingest
                report.shuffle_spills += 1
                fut = self._writer_lane().submit(
                    self._write_groups, sp.name, order, groups)
                with self._lock:
                    self._pending[sp.name] = fut
                    self._spilled_stages.add(sp.name)
            else:
                report.shuffle_async_rounds += 1

    # ----------------------------------------------------------------- paths
    def _write_groups(self, stage: str, order: List[Any],
                      groups: Dict[Any, List[IngestItem]]) -> str:
        """Local groups -> one DFS file per group (consume-on-write: a fresh
        round never merges an earlier round's leftovers).  The dir is leased
        so ``gc_orphans`` spares it while this service lives."""
        dfs = self._dfs_dir(stage)
        shutil.rmtree(dfs, ignore_errors=True)
        self.store.lease_exchange_path(dfs)
        os.makedirs(dfs, exist_ok=True)
        for g in order:
            with open(os.path.join(dfs, f"group{g}.pkl"), "wb") as f:
                pickle.dump(groups[g], f, protocol=pickle.HIGHEST_PROTOCOL)
        return dfs

    # ------------------------------------------------------------- lifecycle
    def drain(self) -> None:
        """Wait for every outstanding journal write (end-of-stream barrier)."""
        with self._lock:
            pending, self._pending = list(self._pending.values()), {}
        for fut in pending:
            fut.result()

    def close(self) -> None:
        self.drain()
        with self._lock:
            writer, self._writer = self._writer, None
            spilled, self._spilled_stages = set(self._spilled_stages), set()
            epochs = list(self._epoch_rounds)
        for e in epochs:           # leftover exchange rounds die with us
            self.invalidate_epoch(e)
        if writer is not None:
            writer.stop()
        for stage in spilled:   # spilled group files die with the service
            dfs = self._dfs_dir(stage)
            shutil.rmtree(dfs, ignore_errors=True)
            self.store.release_exchange_path(dfs)


#: pre-ISSUE-4 name, kept for callers that predate the control/data split
ShuffleService = ShuffleCoordinator


class RuntimeEngine:
    def __init__(self, store: DataStore, optimizer: Optional[IngestionOptimizer] = None,
                 max_retries: int = 3, shuffle_spill_bytes: Optional[int] = None,
                 shuffle_synchronous: bool = False,
                 backend: str = "thread",
                 memory_budget_bytes: Optional[int] = None,
                 transport: str = "pipe",
                 node_hosts: Optional[Dict[str, str]] = None,
                 network_chaos: bool = False,
                 columnar: bool = True) -> None:
        """``backend`` selects the node substrate: ``"thread"`` (default —
        in-process ``NodeExecutor`` lanes) or ``"process"`` (one long-lived
        worker process per node, real CPU parallelism; DESIGN.md §6).

        ``memory_budget_bytes`` is the engine's shared memory budget: when
        set and no explicit ``shuffle_spill_bytes`` is given, the shuffle
        spill threshold is derived from it (minus the ingest queues' share,
        for the streaming engine) instead of the static default.

        ``transport`` (process backend, ISSUE 9) selects the control/store
        medium: ``"pipe"`` (default — ``multiprocessing.Pipe``, the
        byte-identical oracle) or ``"socket"`` (the framed TCP fabric,
        DESIGN.md §7).  ``node_hosts`` maps node -> host label; nodes on
        different hosts are treated as not shm-reachable — their shuffle
        partitions cross in degraded mode (streamed spill files) and the
        liveness monitor applies the per-host partition quorum.
        ``network_chaos`` inserts the ChaosProxy shim on each socket pair
        so the chaos harness can render partition/drop/delay events.

        ``columnar`` (ISSUE 10) enables the columnar data plane: stage
        edges whose producing AND consuming blocks the optimizer proved
        batch-capable cross as ColumnarBatch column buffers instead of
        item lists.  ``columnar=False`` pins every edge to the
        item-at-a-time path — the byte-identical correctness oracle."""
        if backend not in ("thread", "process"):
            raise ValueError(f"unknown backend {backend!r} (thread|process)")
        if transport not in ("pipe", "socket"):
            raise ValueError(f"unknown transport {transport!r} (pipe|socket)")
        self.store = store
        self.nodes = list(store.nodes)
        self.optimizer = optimizer or IngestionOptimizer()
        self.max_retries = max_retries
        self.backend = backend
        self.transport = transport
        self.node_hosts = dict(node_hosts) if node_hosts else {}
        self.network_chaos = network_chaos
        self.memory_budget_bytes = memory_budget_bytes
        self.columnar = columnar
        self._explicit_spill = shuffle_spill_bytes is not None
        if shuffle_spill_bytes is None:
            shuffle_spill_bytes = (derive_spill_bytes(memory_budget_bytes)
                                   if memory_budget_bytes is not None
                                   else DEFAULT_SPILL_BYTES)
        self.shuffle = ShuffleCoordinator(store, spill_bytes=shuffle_spill_bytes,
                                          synchronous=shuffle_synchronous,
                                          columnar=columnar)
        # thread-backend data plane: node lanes deposit/collect partitions
        # here directly — the coordinator thread never touches the items
        self._exchange = PartitionExchange()
        self._executors: Dict[str, Any] = {}
        self._exec_lock = threading.Lock()

    # ------------------------------------------------------------------ remote
    def launch_remote(self, node: str, stage_plans: List[StagePlan]) -> List[StagePlan]:
        """The remote-shell seam: in a real deployment this SSHes the optimized
        plan to ``node`` (paper Sec. VI-A).  The thread backend clones operator
        instances so every node runs its own state, exactly as separate JVMs
        would; the process backend ships the same plan by pickle to the node's
        worker process (``ProcessNodeExecutor.install_plan``)."""
        return [sp.clone() for sp in stage_plans]

    def executor(self, node: str) -> Any:
        """The node's persistent executor (created on first use, kept for the
        engine's lifetime — stage barriers stop re-creating thread pools).
        Thread backend: ``NodeExecutor``; process backend:
        ``ProcessNodeExecutor`` (a live worker process)."""
        with self._exec_lock:
            ex = self._executors.get(node)
            if ex is None:
                if self.backend == "process":
                    # the fork is always local in this repo — ``host`` is
                    # the *placement label* driving quorum grouping and
                    # degraded exchange, so local_worker stays True and
                    # shm sweeps keep running (no leaked segments in the
                    # simulated-multi-host soaks)
                    ex = ProcessNodeExecutor(
                        node, self.store, transport=self.transport,
                        host=self.node_hosts.get(node),
                        chaos_shim=self.network_chaos,
                        bulk_registration=self.columnar)
                else:
                    ex = NodeExecutor(node)
                self._executors[node] = ex
            return ex

    def prewarm_executors(self) -> None:
        """Spawn every node's executor up front.  The process backend forks
        here — before feeder/committer threads exist — so worker processes
        never inherit mid-operation thread state."""
        for n in self.nodes:
            self.executor(n)

    def close(self) -> None:
        """Shut down persistent node executors and the shuffle planes."""
        self.shuffle.close()
        self._exchange.close()
        with self._exec_lock:
            execs, self._executors = list(self._executors.values()), {}
        for ex in execs:
            ex.shutdown()

    def invalidate_exchange(self, epoch: Optional[int]) -> None:
        """Tear down a dead epoch's in-flight exchange state everywhere:
        the coordinator unlinks unconsumed segments and deletes spill files
        (metadata bookkeeping), then every node-side exchange drops its
        buckets — a replay of the epoch starts from clean rounds."""
        self._drop_rounds(self.shuffle.invalidate_epoch(epoch))

    def invalidate_producer(self, epoch: Optional[int], node: str) -> None:
        """Per-producer exchange invalidation (ISSUE 8 cone recovery): the
        coordinator strips the dead node's manifests from the epoch's live
        rounds, then the engine-side exchange forgets only that node's
        buckets.  Survivors' partitions stay live for the store segment.  A
        process worker's resident buckets died with the worker itself, and
        identity-routed rounds never placed the producer's data on a peer —
        so no worker drop message is needed."""
        xids = self.shuffle.invalidate_producer(epoch, node)
        if xids:
            self._exchange.drop_node(xids, node)

    def _drop_rounds(self, xids: Sequence[int]) -> None:
        """Clear node-side exchange buckets for dead rounds — the engine's
        own exchange (thread backend) and every live worker process (their
        resident buckets hold refcounted segment leases)."""
        if not xids:
            return
        self._exchange.drop(xids)
        if self.backend == "process":
            with self._exec_lock:
                execs = list(self._executors.values())
            for ex in execs:
                drop = getattr(ex, "drop_exchange", None)
                if drop is not None:
                    drop(xids)

    def _deposit_partitions(self, rnd: ExchangeRound, node: str,
                            out: List[IngestItem]) -> Dict[str, Any]:
        """Thread-backend data plane: partition this node's stage output by
        the routing key and hand each partition straight to its target's
        bucket (the in-memory queue handoff) — for a narrow round
        (``rnd.key is None``) the whole output deposits into the node's own
        bucket, staying resident.  A partition past the per-edge spill share
        crosses as a DFS file instead (``resident_*`` naming for the node's
        own slice).  Runs on the node's executor lane — only the returned
        manifest (counts, sizes, paths) ever reaches the coordinator.

        On a columnar round (ISSUE 10) the output packs into a
        ColumnarBatch first: partitioning is one vectorized hash pass and
        each partition deposits (or spills) as a column buffer.  A batch
        that doesn't pack falls back to the scalar path and flags the
        manifest (``columnar_fallback``) so the coordinator counts it."""
        def part_fn(dst: str, its: Any, nb: int) -> Dict[str, Any]:
            if isinstance(its, ColumnarBatch):
                if nb > rnd.spill_share:
                    path = os.path.join(
                        self.store.dfs_dir,
                        columnar_file_name(rnd.epoch, rnd.xid, node, dst))
                    write_columnar_file(path, its)
                    self._exchange.deposit(rnd.xid, dst, None, nb, path=path)
                    return {"kind": "mem", "count": len(its), "nbytes": nb,
                            "spilled": path, "columnar": True}
                self._exchange.deposit_batch(rnd.xid, dst, its)
                return {"kind": "mem", "count": len(its), "nbytes": nb,
                        "columnar": True}
            if nb > rnd.spill_share:
                path = os.path.join(
                    self.store.dfs_dir,
                    resident_file_name(rnd.epoch, rnd.xid, node)
                    if dst == node else
                    exchange_file_name(rnd.epoch, rnd.xid, node, dst))
                write_partition_file(path, its)
                self._exchange.deposit(rnd.xid, dst, None, nb, path=path)
                return {"kind": "mem", "count": len(its), "nbytes": nb,
                        "spilled": path}
            self._exchange.deposit(rnd.xid, dst, its, nb)
            return {"kind": "mem", "count": len(its), "nbytes": nb}

        payload: Any = out
        fallback = False
        if rnd.columnar and out:
            batch = ColumnarBatch.from_items(out)
            if batch is None:
                fallback = True
            else:
                payload = batch
        manifest = build_manifest(payload, rnd.key, rnd.targets, part_fn,
                                  self_node=node)
        if fallback:
            manifest["columnar_fallback"] = True
        return {"kind": "xmanifest", "manifest": manifest}

    def __enter__(self) -> "RuntimeEngine":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # --------------------------------------------------------------------- run
    def run(self, plan: IngestPlan,
            sources: Union[Dict[str, List[IngestItem]], List[IngestItem],
                           "SourceAdapter", None] = None,
            faults: Optional[FaultInjection] = None,
            optimize: bool = True) -> RunReport:
        t0 = time.time()
        faults = faults or FaultInjection()
        report = RunReport()
        if self.backend == "process":
            self.prewarm_executors()   # fork before any run-scoped threads

        stage_plans = plan.compile()
        if optimize:
            stage_plans = self.optimizer.optimize(stage_plans)

        # worker-pull source (ISSUE 6): the coordinator distributes shard
        # descriptors; workers read them.  Everything downstream treats the
        # descriptors as opaque shards — reassignment/cohort replay move
        # them between nodes exactly like items, but no item bytes ever
        # exist coordinator-side.
        adapter = sources if isinstance(sources, SourceAdapter) else None
        if adapter is None and getattr(plan, "source_spec", None) and sources is None:
            adapter = build_source(plan.source_spec)
        if adapter is not None:
            sources = adapter.describe()
            report.source_descriptors = len(sources)
        elif not isinstance(sources, dict):
            sources = list(sources)   # cohort replay re-distributes them

        alive = {n: True for n in self.nodes}
        # a fresh batch run starts from full liveness — clear placement marks
        # a previous run's (injected) deaths left on the shared store
        for n in self.nodes:
            self.store.mark_node_live(n)

        # ---- cohort-replay guard (ROADMAP "batch shuffle cohort replay"):
        # a DAG that consumes a shuffle stages its blocks under an epoch, so
        # a node death at/after a shuffle-consuming stage — whose groups
        # mixed other nodes' lineages and cannot be replayed from the dead
        # node's own shards — can abort the staged blocks and replay the
        # *whole run* on the survivors, exactly-once (the streaming engine's
        # epoch-granular recovery applied to batch).
        wrap = self._has_shuffle_consumer(stage_plans)
        eid: Optional[int] = None
        try:
            while True:
                live = [n for n in self.nodes if alive[n]]
                if not live:
                    raise RuntimeError("all nodes failed")
                node_sources = self._distribute_sources(sources, live)
                report.per_node_shards = {n: len(v)
                                          for n, v in node_sources.items()}
                if adapter is None:
                    # legacy pushed path: the coordinator held and routed
                    # every source item — count the hop it paid
                    report.source_coordinator_bytes = sum(
                        items_nbytes(v) for v in node_sources.values())
                if wrap:
                    eid = self.store.next_epoch_id()
                    self.store.begin_epoch(eid)
                try:
                    self._execute(stage_plans, node_sources, faults, report,
                                  alive, epoch=eid, source=adapter)
                    break
                except _CohortReplay:
                    self.store.abort_epoch(eid)
                    self.invalidate_exchange(eid)
                    report.cohort_replays += 1
                    eid = None   # rolled back; the retry stages afresh
            self.shuffle.drain()
            if eid is not None:
                self.store.commit_epoch(
                    eid, n_items=sum(report.per_node_shards.values()))
        except BaseException:
            # don't strand a staging epoch: a stuck staging id would block
            # every later commit on this store (the commit sequencer waits
            # on smaller staging ids forever)
            if eid is not None and not self.store.epoch_committed(eid):
                self.store.abort_epoch(eid)
                self.invalidate_exchange(eid)
            raise

        report.wall_time_s = time.time() - t0
        report.spawn_retries = self._spawn_retry_total()
        report.sweep_skipped_remote = self._sweep_skip_total()
        self.store.flush_manifest()
        return report

    def _spawn_retry_total(self) -> int:
        """Process-worker spawn attempts beyond the first, over every
        executor this engine created (ISSUE 8 bounded spawn retry)."""
        with self._exec_lock:
            execs = list(self._executors.values())
        return sum(getattr(ex, "spawn_retries", 0) for ex in execs)

    def _sweep_skip_total(self) -> int:
        """Shm sweep passes skipped because a worker was remote (ISSUE 9
        satellite): reported instead of silently pretending the remote
        host's segments were reclaimed."""
        with self._exec_lock:
            execs = list(self._executors.values())
        return sum(getattr(ex, "sweep_skips", 0) for ex in execs)

    def _redistribute(self, batch: Dict[str, List[IngestItem]],
                      live: List[str]) -> Dict[str, List[IngestItem]]:
        """Node affinity where the node is in the live set; round-robin onto
        survivors otherwise — the one rebalancing policy shared by batch
        cohort replay and the streaming engine's epoch replay."""
        node_sources: Dict[str, List[IngestItem]] = {n: [] for n in self.nodes}
        spill: List[IngestItem] = []
        for n, its in batch.items():
            (node_sources[n] if n in live else spill).extend(its)
        for i, it in enumerate(spill):
            node_sources[live[i % len(live)]].append(it)
        return node_sources

    def _distribute_sources(self, sources: Union[Dict[str, List[IngestItem]],
                                                 List[IngestItem]],
                            live: List[str]) -> Dict[str, List[IngestItem]]:
        """Distribute source shards over the live nodes: node-local dict
        (a dead node's shards move round-robin onto survivors), or a shared
        queue (work stealing / straggler mitigation: slow nodes take fewer
        shards)."""
        if isinstance(sources, dict):
            return self._redistribute(sources, live)
        node_sources: Dict[str, List[IngestItem]] = {n: [] for n in self.nodes}
        shared: "queue.Queue[IngestItem]" = queue.Queue()
        for it in sources:
            shared.put(it)
        while True:
            grabbed = False
            for n in live:
                try:
                    node_sources[n].append(shared.get_nowait())
                    grabbed = True
                except queue.Empty:
                    break
            if not grabbed:
                break
        return node_sources

    @staticmethod
    def _has_shuffle_consumer(stage_plans: List[StagePlan],
                              upto: Optional[int] = None) -> bool:
        """True when some stage at index <= ``upto`` (whole DAG when None)
        consumes a shuffle boundary — the condition under which a dead
        node's state cannot be rebuilt from its own source shards.  Reads
        the compiled per-edge metadata (``edge_kinds`` consumer map +
        ``shuffle_key``), falling back to an upstream scan for hand-built
        plans that never went through ``annotate_edges``."""
        in_range = {sp.name for sp in (stage_plans if upto is None
                                       else stage_plans[:upto + 1])}
        for si, sp in enumerate(stage_plans):
            if not (sp.shuffle_key or sp.compute_shuffle_key()):
                continue
            consumers = stage_consumers(stage_plans, si,
                                        downstream_only=False)
            if any(c in in_range for c in consumers):
                return True
        return False

    # ----------------------------------------------------------- stage dataflow
    def _mark_dead(self, node: str, alive: Dict[str, bool], report: RunReport) -> None:
        alive[node] = False
        report.node_failures.append(node)
        # location IDs of the dead node flow to the survivors (Sec. VI-C1):
        # the upload operator maps location ids over live nodes only
        self.store.mark_node_dead(node)

    def _execute(self, stage_plans: List[StagePlan],
                 node_sources: Dict[str, List[IngestItem]],
                 faults: FaultInjection, report: RunReport,
                 alive: Dict[str, bool],
                 on_node_death: str = "reassign",
                 lane: str = "main",
                 epoch: Optional[int] = None,
                 outputs: Optional[Dict[str, Dict[str, List[IngestItem]]]] = None,
                 start_stage: int = 0,
                 end_stage: Optional[int] = None,
                 node_set: Optional[List[str]] = None,
                 source: Optional["SourceAdapter"] = None
                 ) -> Dict[str, Dict[str, List[IngestItem]]]:
        """Run (a slice of) the stage DAG over per-node shards — the body
        shared by the batch engine and the streaming engine's per-epoch
        execution.  Stage jobs run on the persistent per-node executors.

        ``on_node_death`` selects the recovery policy:
          * ``"reassign"`` (batch): the dead node's shards move to the next
            live node, which replays stages 0..si for them (Sec. VI-C1).
          * ``"raise"`` (streaming): mark the node dead and raise NodeFailure —
            the caller aborts the staged epoch and replays it on the
            surviving nodes (epoch-granular recovery).

        ``lane`` picks the NodeExecutor lane (pipelined streaming keeps epoch
        N+1's ingest and epoch N's store on separate lanes); ``epoch`` binds
        ``DataStore.put_block`` attribution for concurrent staging epochs;
        ``outputs``/``start_stage``/``end_stage`` execute a slice of the DAG
        over pre-seeded upstream outputs (the ingest/store segment split).

        ``node_set`` pins the executing nodes for the whole call: with two
        epochs in flight, ``alive`` can flip concurrently from the *other*
        epoch's thread, and a per-stage liveness read could silently skip a
        node whose inputs this epoch still holds.  Raise-mode callers pass
        their consistent snapshot; batch recomputes per stage (it owns
        ``alive`` exclusively and needs reassignment to see deaths).

        ``source`` flips the source hop to worker-pull (ISSUE 6): the
        source-stage entries of ``node_sources`` are :class:`ShardDescriptor`
        lists, and each node opens/reads/parses its shards on its own lane
        (thread backend) or inside its worker process (process backend,
        ``ctx["source"]``) — no item bytes ever transit the coordinator.
        Predicates of the source stage apply to the *read* items.
        """
        if on_node_death == "reassign" and (start_stage != 0 or end_stage is not None):
            raise ValueError("shard reassignment requires the full stage DAG")
        use_proc = self.backend == "process"
        # ---- plan is resident on every node executor (installed once);
        # thread backend: in-process clone; process backend: pickled ship to
        # the worker.  A worker already dead at install time takes the same
        # fault path as one dying mid-stage.
        node_plans: Dict[str, List[StagePlan]] = {}
        plan_keys: Dict[str, str] = {}
        install_failed: List[str] = []
        exec_nodes = (list(node_set) if node_set is not None
                      else [n for n in self.nodes if alive.get(n)])
        for n in exec_nodes:
            try:
                if use_proc:
                    plan_keys[n] = self.executor(n).install_plan(stage_plans)
                else:
                    node_plans[n] = self.executor(n).install_plan(
                        stage_plans, self.launch_remote)
            except WorkerDeath:
                install_failed.append(n)
        for n in install_failed:
            self._mark_dead(n, alive, report)
        if install_failed and on_node_death == "raise":
            raise NodeFailure(install_failed[0])
        if outputs is None:
            outputs = {n: defaultdict(list) for n in self.nodes}
        stop = len(stage_plans) if end_stage is None else end_stage
        failure_counts: Dict[Tuple[str, str, int], int] = defaultdict(int)

        # dedicated lock for report mutation from worker threads
        rlock = threading.Lock()

        def read_descs(descs: List[Any]) -> List[IngestItem]:
            """Worker-pull: materialize a node's shard descriptors (runs on
            the node's own lane — the thread backend's equivalent of the
            process worker's in-worker read)."""
            pulled: List[IngestItem] = []
            for d in descs:
                pulled.extend(source.read(d))
            with rlock:
                report.source_items += len(pulled)
            return pulled

        # peer-exchange rounds still awaiting consuming stage(s), keyed by
        # producing stage name.  A slice starting mid-DAG (the store segment)
        # first adopts the rounds an earlier slice pinned for it — node-
        # resident buckets crossing the ingest/store boundary (ISSUE 5)
        active_rounds: Dict[str, ExchangeRound] = {}
        if start_stage:
            active_rounds = {
                r.stage: r for r in self.shuffle.adopt_pinned(
                    epoch, [sp.name for sp in stage_plans[start_stage:stop]])}

        for si in range(start_stage, stop):
            sp = stage_plans[si]

            live_nodes = (list(node_set) if node_set is not None
                          else [n for n in self.nodes if alive[n]])
            # exchange plumbing for this stage: rounds it consumes, and the
            # round it produces (None -> legacy barrier handles the boundary)
            incoming = [r for r in active_rounds.values()
                        if sp.name in r.consumers]
            produce = self.shuffle.plan_round(stage_plans, si, stop,
                                              live_nodes, epoch)
            if produce is not None:
                active_rounds[sp.name] = produce
            # a terminal stage (no consumer anywhere in the DAG) is a sink:
            # process workers reply a count instead of shipping the output
            # items back over the coordinator pipe (zero-coordinator bytes
            # end-to-end; the thread backend's outputs dict is in-process)
            has_consumers = bool(sp.edge_kinds) or any(
                sp.name in sq.upstream for sq in stage_plans[si + 1:])
            sink = (use_proc and produce is None and not has_consumers
                    and not self.shuffle.synchronous and bool(sp.ops))
            sink_counts: Dict[str, int] = {}
            # worker-pull: this stage's inputs are shard descriptors, read
            # node-side (source stages only — stages with upstream consume
            # prior outputs as usual)
            src_mode = source is not None and not sp.upstream

            # -------------------------------------------------- stage barrier
            def run_stage_on(node: str, nsp: StagePlan,
                             input_items: List[Any],
                             fetches: List[Tuple[int, bool]],
                             prnd: Optional[ExchangeRound]) -> Any:
                with self.store.epoch_context(epoch):
                    if src_mode:
                        items = route_items(read_descs(input_items),
                                            nsp.predicates)
                    else:
                        items = input_items
                    for xid, last, owner in fetches:
                        # thread backend: partitions hand off in memory —
                        # collect on the node's own lane, route, and merge.
                        # `owner` is normally this node; a redirected fetch
                        # drains a dead consumer's bucket instead.
                        got, _ = self._exchange.collect(xid, owner, last=last)
                        items = items + route_items(got, nsp.predicates)
                    out = self._run_stage(node, nsp, items, faults,
                                          failure_counts, report, rlock)
                    if prnd is None:
                        return out
                    return self._deposit_partitions(prnd, node, out)

            def stage_inputs(node: str, nsp: StagePlan) -> List[IngestItem]:
                if not nsp.upstream:
                    base = node_sources[node]
                    if src_mode:
                        # descriptors are routed post-read, node-side
                        return list(base)
                else:
                    base = []
                    for up in nsp.upstream:  # CHAIN = union all (Sec. IV-B)
                        base = base + outputs[node][up]
                return route_items(base, nsp.predicates)

            # ---- batch-mode redirection: a target that died between the
            # producing and consuming stage never fetches its bucket.  Its
            # *peer-held* partitions (segments / files / thread buckets) are
            # location-independent, so they deliver to the next live node —
            # the same node its replayed shards land on — instead of being
            # reclaimed as leftovers.  (Raise mode never gets here: a death
            # aborts the epoch before the consumer stage is submitted.)
            redirects: Dict[str, List[Any]] = {}
            if on_node_death == "reassign":
                final_consuming_stage = {
                    rnd.xid: rnd.consumers_done == len(rnd.consumers) - 1
                    for rnd in incoming}
                for rnd in incoming:
                    for t in rnd.targets:
                        if t in live_nodes:
                            continue
                        tgt = self._next_live(t, alive)
                        if tgt is None:
                            continue
                        if use_proc:
                            # redirect once, and never to a node that was
                            # already handed refs (they may be consumed —
                            # segments unlinked, files deleted); the target
                            # worker caches the decoded batch (keep flag),
                            # so its later "cached" collects include it.  A
                            # node that consumed before dying took its cache
                            # with it — unrecoverable (pre-existing corner).
                            if t in rnd.delivered:
                                continue
                            refs = [r for r in self.shuffle.refs_for(rnd, t)
                                    if r["kind"] in ("shm", "file", "stream")]
                            redirects.setdefault(tgt, []).extend(refs)
                        else:
                            # thread buckets outlive the node (peek keeps
                            # them): redirect at EVERY consuming stage, and
                            # pop exactly at the round's final one — the
                            # dead node's own cursor may have been reset by
                            # the failure bookkeeping
                            redirects.setdefault(tgt, []).append(
                                (rnd.xid, final_consuming_stage[rnd.xid], t))

            futs = {}
            if use_proc:
                # injected op failures are assigned to the first live node
                # (the thread backend's shared-dict race picks an arbitrary
                # winner; the process backend makes it deterministic)
                injections: Dict[int, int] = {}
                for (sname, oi), cnt in list(faults.op_failures.items()):
                    if sname == sp.name and cnt > 0:
                        injections[oi] = cnt
                        faults.op_failures[(sname, oi)] = 0
                for ni, n in enumerate(live_nodes):
                    fetch: List[Dict[str, Any]] = []
                    for rnd in incoming:
                        fetch.extend(self.shuffle.refs_for(rnd, n))
                    fetch.extend(redirects.get(n, []))
                    futs[n] = self.executor(n).run_stage(
                        plan_keys[n], si,
                        [] if src_mode else stage_inputs(n, sp), lane=lane,
                        epoch=epoch, live_nodes=live_nodes,
                        injections=injections if ni == 0 else None,
                        max_retries=self.max_retries,
                        shuffle_ctx=(produce.worker_ctx(self.store.dfs_dir,
                                                        self.node_hosts)
                                     if produce is not None else None),
                        fetch_refs=fetch or None, sink=sink,
                        source_ctx=({"adapter": source,
                                     "descs": node_sources[n]}
                                    if src_mode else None))
            else:
                for n in live_nodes:
                    nsp = node_plans[n][si]
                    fetches = [(rnd.xid, self.shuffle.serve(rnd, n), n)
                               for rnd in incoming]
                    fetches.extend(redirects.get(n, []))
                    futs[n] = self.executor(n).submit(
                        run_stage_on, n, nsp, stage_inputs(n, nsp),
                        fetches, produce, lane=lane)
            failed: List[str] = []
            for n, fut in futs.items():  # drain ALL jobs before acting on death
                try:
                    res = fut.result()
                except (NodeFailure, WorkerDeath):
                    failed.append(n)
                    continue
                except Exception:
                    # a SIGTERM'd worker can emit one garbled/partial reply
                    # before the pipe EOF lands — if the worker is gone, the
                    # failure IS the death, not a stage error.  (Exception,
                    # not BaseException: a KeyboardInterrupt landing in this
                    # wait must abort the run, not mark the node dead.)
                    if use_proc and not getattr(self.executor(n), "alive", True):
                        failed.append(n)
                        continue
                    raise
                if use_proc:
                    payload, stats = res
                    with rlock:
                        for k, v in stats["op_failures"].items():
                            report.op_failures[k] = max(
                                report.op_failures.get(k, 0), v)
                        report.dummy_substitutions.extend(stats["dummy"])
                        report.source_items += stats.get("source_items", 0)
                        report.vectorized_rows += stats.get(
                            "vectorized_rows", 0)
                        report.batch_fallbacks += stats.get(
                            "batch_fallbacks", 0)
                        report.kernel_ms += stats.get("kernel_ms", 0.0)
                else:
                    payload = res
                if (produce is not None and isinstance(payload, dict)
                        and payload.get("kind") == "xmanifest"):
                    # partitions went peer-to-peer (or stayed resident);
                    # only metadata came back
                    outputs[n][sp.name] = []
                    self.shuffle.record_manifest(produce, n,
                                                 payload["manifest"])
                elif isinstance(payload, dict) and payload.get("kind") == "sink":
                    # terminal stage: the worker dropped its outputs locally
                    # — only the count crossed the coordinator pipe
                    outputs[n][sp.name] = []
                    sink_counts[n] = int(payload.get("count", 0))
                else:
                    outputs[n][sp.name] = payload
                    if has_consumers:
                        # legacy boundary: the stage output round-tripped
                        # through the coordinator as item bytes
                        report.stage_coordinator_bytes += items_nbytes(payload)
            if produce is not None:
                report.stage_resident_bytes += produce.resident_bytes
                if produce.degraded_parts:
                    report.degraded_exchange_rounds += 1
                    report.degraded_peer_bytes += produce.degraded_bytes
                if produce.columnar_parts:
                    report.columnar_rounds += 1
                    report.columnar_bytes += produce.columnar_bytes
                report.columnar_fallbacks += produce.columnar_fallbacks
                if produce.key is None:        # narrow (identity) round
                    report.stage_exchange_rounds += 1
                    if produce.spilled:
                        report.resident_spills += 1
                else:
                    report.shuffled_items += produce.total_count
                    report.shuffle_peer_bytes += produce.total_bytes
                    report.shuffle_exchange_rounds += 1
                    if produce.spilled:
                        report.shuffle_spills += 1
                    else:
                        report.shuffle_async_rounds += 1
            for n in failed:
                self._mark_dead(n, alive, report)
                for rnd in incoming:
                    # the consumer died mid-fetch: count it as never served
                    # so finish_round reclaims its unconsumed refs (a
                    # double-unlink of a ref it did consume is a no-op)
                    rnd.served.pop(n, None)
            if failed and on_node_death == "raise":
                err = NodeFailure(failed[0])
                err.stage_index = si
                raise err

            # ---- legacy shuffle barrier (Sec. VI-B) for boundaries the
            # exchange does not cover: synchronous mode, or the consuming
            # stage lies outside this slice.  With a pinned node_set (raise
            # mode) a stage failure raised above, so the whole set
            # redistributes — re-reading `alive` here would race with the
            # other epoch's thread and silently skip a node's outputs.
            # Batch mode re-reads it so a node that just failed this stage
            # takes no groups.
            if produce is None:
                barrier_live = (live_nodes if node_set is not None
                                else [n for n in live_nodes if alive[n]])
                self.shuffle.barrier(sp, outputs, barrier_live, report)

            # ---- exchange rounds whose final consuming stage just drained:
            # release control-plane bookkeeping; drop node-side leftovers of
            # consumers that never fetched (died mid-round)
            for rnd in incoming:
                rnd.consumers_done += 1
                if rnd.consumers_done >= len(rnd.consumers):
                    if self.shuffle.finish_round(rnd):
                        self._drop_rounds([rnd.xid])
                    active_rounds.pop(rnd.stage, None)

            # ---- injected node deaths after this stage
            died_here = list(failed)
            for n, after in faults.node_death_after_stage.items():
                if after == sp.name and alive.get(n):
                    self._mark_dead(n, alive, report)
                    died_here.append(n)
                    if on_node_death == "raise":
                        err = NodeFailure(n)
                        err.stage_index = si
                        raise err

            # ---- cohort-replay escalation (ROADMAP "batch shuffle cohort
            # replay"): once a shuffle-consuming stage has run, a dead
            # node's state mixed other nodes' lineages — replaying its own
            # source shards would double-count or lose groups.  Escalate to
            # whole-run replay (run() aborts the staged epoch and restarts
            # on the survivors) instead of shard reassignment.
            if (died_here and on_node_death == "reassign"
                    and self._has_shuffle_consumer(stage_plans, upto=si)):
                raise _CohortReplay(died_here[0])

            # ---- node-failure recovery: reassign dead nodes' shards to the
            # next live node in the slaves order and re-run stages 0..si for
            # them (their in-flight state is lost with the node).  Only the
            # batch policy reassigns here — under "raise" the epoch replays
            # wholesale, and a death observed from a *concurrent* epoch's
            # thread must not trigger a partial replay inside this one.
            # Recomputed until quiescent: a *target* worker dying mid-replay
            # (process backend) is marked dead, its shards — including the
            # ones just moved onto it — reassign to the next survivor.
            while on_node_death == "reassign":
                dead = [n for n in self.nodes if not alive[n] and node_sources[n]]
                if not dead:
                    break
                n = dead[0]
                target = self._next_live(n, alive)
                if target is None:
                    raise RuntimeError("all nodes failed")
                shards = node_sources.pop(n)
                node_sources[n] = []
                node_sources[target].extend(shards)
                report.reassigned_shards += len(shards)
                if source is not None:
                    # the moved shards are descriptors: the reader died, the
                    # survivor re-reads them (descriptor-granular re-issue)
                    report.source_reissues += len(shards)
                # re-run all stages so far for the moved shards on the target
                replay_out: Dict[str, List[IngestItem]] = defaultdict(list)
                target_died = False

                def lost_slices_only(stage_name: str, dead_node: str,
                                     out: List[IngestItem]) -> List[IngestItem]:
                    """Replay of a shuffle-producer stage whose round is
                    still in flight must contribute only the slices whose
                    exchange copies actually died — everything the dead
                    node managed to deal (its manifest: peer segments,
                    spill files, engine-held thread buckets) is delivered
                    or redirected, and replaying it would double-count.
                    Only a process worker's *resident* slice dies with it;
                    a node that never dealt (died mid-stage) replays in
                    full."""
                    rnd = active_rounds.get(stage_name)
                    if rnd is None:
                        return out
                    m = rnd.manifests.get(dead_node)
                    if m is None:
                        return out
                    lost = {dst for dst, desc in m.get("parts", {}).items()
                            if desc["kind"] == "resident"}
                    if not lost:
                        return []
                    if rnd.key is None:
                        # narrow round: the whole output was the node's own
                        # resident slice and died with it — recompute all of
                        # it from the shards (self-contained lineage)
                        return out
                    parts = partition_items(out, rnd.key, rnd.targets)
                    return [it for dst in lost for it in parts.get(dst, ())]

                for sj in range(si + 1):
                    rp = stage_plans[sj] if use_proc else node_plans[target][sj]
                    replay_src = source is not None and not rp.upstream
                    if not rp.upstream:
                        base = shards
                        if replay_src and not use_proc:
                            # descriptors: the survivor re-reads them here
                            base = read_descs(shards)
                    else:
                        base = []
                        for up in rp.upstream:
                            base = base + replay_out[up]
                    routed = route_items(base, rp.predicates)
                    if use_proc:
                        # replay runs on the target's worker (its resident
                        # plan state absorbs the moved shards)
                        try:
                            rout, rstats = self.executor(
                                target).run_stage(
                                    plan_keys[target], sj,
                                    [] if replay_src else routed, lane=lane,
                                    epoch=epoch, live_nodes=live_nodes,
                                    max_retries=self.max_retries,
                                    source_ctx=({"adapter": source,
                                                 "descs": shards}
                                                if replay_src else None)
                                    ).result()
                        except (NodeFailure, WorkerDeath):
                            # the shards sit in node_sources[target]; the
                            # next loop pass moves them to a survivor
                            self._mark_dead(target, alive, report)
                            target_died = True
                            break
                        replay_out[rp.name] = lost_slices_only(rp.name, n, rout)
                        with rlock:
                            report.dummy_substitutions.extend(rstats["dummy"])
                    else:
                        replay_out[rp.name] = lost_slices_only(
                            rp.name, n, self._run_stage(
                                target, self.launch_remote(target, [rp])[0],
                                routed, faults, failure_counts, report, rlock))
                if not target_died:
                    for k, v in replay_out.items():
                        outputs[target][k].extend(v)

            total = sum(len(outputs[n][sp.name]) for n in self.nodes if alive[n])
            if produce is not None:
                # exchange stages keep their outputs worker-side; the
                # manifests carry the count
                total = produce.total_count
            elif sink_counts:
                # sink stages dropped their outputs worker-side; the counts
                # came back as metadata.  Alive-filtered like the outputs
                # sum: a node that died after replying gets its shards
                # replayed (re-counted via the survivor's outputs)
                total += sum(c for n2, c in sink_counts.items()
                             if alive.get(n2))
            report.stage_items[sp.name] = total

        return outputs

    # ------------------------------------------------------------- stage exec
    def _run_stage(self, node: str, sp: StagePlan, items: List[IngestItem],
                   faults: FaultInjection,
                   failure_counts: Dict[Tuple[str, str, int], int],
                   report: RunReport, rlock: threading.Lock) -> List[IngestItem]:
        """Run one stage's pipeline blocks over a node's items.

        Each block boundary is a materialization = checkpoint: on operator
        failure the block is retried from its checkpointed input; after
        ``max_retries`` the failing operator is replaced by a dummy
        pass-through (paper Sec. VI-C1).
        """
        current = items
        for bi, block in enumerate(
                sp.pipeline_blocks or [[i] for i in range(len(sp.ops))]):
            batched = bool(sp.batch_blocks[bi]) if bi < len(sp.batch_blocks) \
                else False
            checkpoint = current  # materialized input of this block
            while True:
                try:
                    out = checkpoint
                    if batched:
                        # batch tier (ISSUE 7): the whole block runs through
                        # the ops' vectorized process_batch path; injected
                        # failures fire up front (the retry reruns the block
                        # from its checkpoint either way)
                        for oi in block:
                            key = (sp.name, oi)
                            if faults.op_failures.get(key, 0) > 0:
                                faults.op_failures[key] -= 1
                                raise OperatorFailure(
                                    f"injected @ {sp.name}[{oi}]")
                        out, bstats = run_ops_batched(
                            [sp.ops[oi] for oi in block], out)
                        with rlock:
                            report.vectorized_rows += bstats["vectorized_rows"]
                            report.batch_fallbacks += bstats["batch_fallbacks"]
                            report.kernel_ms += bstats["kernel_ms"]
                    else:
                        for oi in block:
                            op = sp.ops[oi]
                            # injected failures (tests)
                            key = (sp.name, oi)
                            if faults.op_failures.get(key, 0) > 0:
                                faults.op_failures[key] -= 1
                                raise OperatorFailure(
                                    f"injected @ {sp.name}[{oi}]")
                            out = op.run(out)
                    current = out
                    break
                except OperatorFailure as e:
                    oi = block[0] if len(block) == 1 else self._failed_op_index(sp, block, e)
                    fkey = (node, sp.name, oi)
                    failure_counts[fkey] += 1
                    with rlock:
                        report.op_failures[f"{sp.name}[{oi}]"] = failure_counts[fkey]
                    if failure_counts[fkey] >= self.max_retries:
                        failing = sp.ops[oi]
                        sp.ops[oi] = PassThroughOp(replaces=failing.name)
                        with rlock:
                            report.dummy_substitutions.append(
                                f"{sp.name}[{oi}]:{type(failing).__name__}")
                    # retry block from the checkpoint (resume from previous
                    # materialization, not from scratch)
                    continue
        return current

    # shared with the process backend's worker (plan.failed_op_index)
    _failed_op_index = staticmethod(failed_op_index)

    def _next_live(self, node: str, alive: Dict[str, bool]) -> Optional[str]:
        """Round-robin successor in the slaves file order (paper Sec. VI-C1)."""
        if node in self.nodes:
            start = self.nodes.index(node)
        else:
            start = 0
        for k in range(1, len(self.nodes) + 1):
            cand = self.nodes[(start + k) % len(self.nodes)]
            if alive.get(cand):
                return cand
        return None


def ingest(plan: IngestPlan, sources: Union[Dict[str, List[IngestItem]], List[IngestItem]],
           store: DataStore, optimize: bool = True,
           faults: Optional[FaultInjection] = None) -> RunReport:
    """One-call entry point: optimize + run an ingestion plan against a store."""
    with RuntimeEngine(store) as eng:
        return eng.run(plan, sources, faults=faults, optimize=optimize)
