"""The INGESTBASE runtime engine (paper Sec. VI).

* **Inter-node parallelism** — the client ships the *optimized* plan to every
  node in the slaves list and runs it over node-local shards ("ship the plan
  to the data").  Nodes here are persistent ``NodeExecutor`` workers over
  per-node directories; the remote-shell seam is ``launch_remote``
  (DESIGN.md §2), invoked once per compiled plan, not once per stage barrier.
  ``backend="process"`` realizes the seam with one long-lived worker
  *process* per node (``core/procexec.py``, DESIGN.md §6) — real CPU
  parallelism for GIL-bound operators; ``backend="thread"`` is the default.
* **Intra-node parallelism** — parallel-mode operators fan out over a thread
  pool (see operators.IngestOp._parallel_iter).
* **Work stealing** — when sources are given as a shared list, nodes pull
  shards from a global queue, so stragglers simply take fewer shards.
* **Distributed I/O** — shuffle via the ``ShuffleService`` (DESIGN.md §4):
  in-memory group handoff with a write-behind DFS journal, double-buffered so
  the DFS write of one round overlaps the next epoch's ingest; rounds past
  the spill threshold take the classic blocking DFS round-trip.  Placement
  via location IDs, replication decoupled from placement.
* **In-flight fault tolerance** — pipeline blocks are checkpoints: a failing
  operator retries its block from the previous materialization; after
  ``max_retries`` failures it is replaced by a dummy pass-through operator
  labelling items with -1.  Node failures reassign shards + location IDs to
  the next node in the slaves order.
"""
from __future__ import annotations

import os
import pickle
import queue
import shutil
import threading
import time
from collections import defaultdict
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .items import IngestItem
from .operators import IngestOp, OperatorFailure, PassThroughOp
from .optimizer import IngestionOptimizer
from .plan import IngestPlan, StagePlan, failed_op_index, route_items
from .procexec import ProcessNodeExecutor, WorkerDeath
from .store import DataStore


class NodeFailure(RuntimeError):
    """Simulated machine failure during ingestion."""


#: legacy static shuffle spill threshold (used when no memory budget is set)
DEFAULT_SPILL_BYTES = 32 << 20
#: floor under budget-derived spill thresholds — a tiny budget must not turn
#: every shuffle round into a blocking DFS round-trip
MIN_SPILL_BYTES = 1 << 20


def derive_spill_bytes(memory_budget_bytes: int, reserved_bytes: int = 0) -> int:
    """Shuffle spill threshold from a shared memory budget: whatever the
    ingest queues are expected to hold (``reserved_bytes``) is carved out
    first, the remainder bounds in-memory shuffle rounds (ROADMAP
    "spill-aware shuffle sizing")."""
    return max(MIN_SPILL_BYTES, int(memory_budget_bytes) - int(reserved_bytes))


@dataclass
class RunReport:
    """What the engine observed while executing a plan."""

    stage_items: Dict[str, int] = field(default_factory=dict)
    op_failures: Dict[str, int] = field(default_factory=dict)
    dummy_substitutions: List[str] = field(default_factory=list)
    node_failures: List[str] = field(default_factory=list)
    reassigned_shards: int = 0
    shuffled_items: int = 0
    shuffle_spills: int = 0        # blocking DFS round-trips (size > threshold)
    shuffle_async_rounds: int = 0  # in-memory handoffs w/ write-behind journal
    wall_time_s: float = 0.0
    per_node_shards: Dict[str, int] = field(default_factory=dict)


@dataclass
class FaultInjection:
    """Test hooks: deterministic failures."""

    # (stage_name, op_index) -> number of consecutive failures to inject
    op_failures: Dict[Tuple[str, int], int] = field(default_factory=dict)
    # node -> stage name after which the node dies
    node_death_after_stage: Dict[str, str] = field(default_factory=dict)


# --------------------------------------------------------------------------
# Persistent node executors (DESIGN.md §4)
# --------------------------------------------------------------------------
class _ExecutorLane:
    """One FIFO worker thread: jobs run in submission order."""

    def __init__(self, name: str) -> None:
        self.jobs: "queue.Queue[Optional[Tuple[Callable, tuple, Future]]]" = queue.Queue()
        self.thread = threading.Thread(target=self._loop,
                                       name=f"nodeexec-{name}", daemon=True)
        self.thread.start()

    def submit(self, fn: Callable, *args: Any) -> Future:
        fut: Future = Future()
        self.jobs.put((fn, args, fut))
        return fut

    def _loop(self) -> None:
        while True:
            job = self.jobs.get()
            if job is None:
                return
            fn, args, fut = job
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                fut.set_result(fn(*args))
            except BaseException as e:  # delivered via Future.result()
                fut.set_exception(e)

    def stop(self) -> None:
        self.jobs.put(None)


class NodeExecutor:
    """One long-lived worker per node, owning the node's plan clone.

    The plan-clone cache is bounded (``PLAN_CACHE``): a long-lived engine
    running many different plans re-clones an evicted one instead of pinning
    every plan it ever saw.

    The engine used to create a fresh ``ThreadPoolExecutor`` at every stage
    barrier and re-clone ("re-ship") the whole plan per ``_execute`` call.  A
    NodeExecutor instead persists for the engine's lifetime and owns

    * the node's **plan clone** — installed once per compiled plan, so
      streaming epochs stop re-shipping plans (operator state, including
      dummy substitutions after repeated failures, survives across epochs
      exactly as it would in a long-running per-node JVM), and
    * one or more **lanes** — named FIFO worker threads.  Batch stages run on
      the default ``"main"`` lane; the pipelined streaming engine runs epoch
      N+1's ingest segment on the ``"ingest"`` lane while epoch N's store
      segment occupies the ``"store"`` lane, overlapping transform compute
      with commit I/O on every node (DESIGN.md §4).
    """

    PLAN_CACHE = 4

    def __init__(self, node: str) -> None:
        self.node = node
        self._lock = threading.Lock()
        self._lanes: Dict[str, _ExecutorLane] = {}
        # id(original) -> (original, clone); the original is pinned so its id
        # cannot be recycled while the cache entry lives
        self._plans: Dict[int, Tuple[List[StagePlan], List[StagePlan]]] = {}

    def install_plan(self, stage_plans: List[StagePlan],
                     cloner: Callable[[str, List[StagePlan]], List[StagePlan]]
                     ) -> List[StagePlan]:
        """This node's clone of ``stage_plans`` — cloned on first sight only
        ("ship the plan to the data" happens once, not per barrier)."""
        key = id(stage_plans)
        with self._lock:
            cached = self._plans.get(key)
            if cached is not None and cached[0] is stage_plans:
                return cached[1]
            clone = cloner(self.node, stage_plans)
            while len(self._plans) >= self.PLAN_CACHE:   # bounded: evict oldest
                self._plans.pop(next(iter(self._plans)))
            self._plans[key] = (stage_plans, clone)
            return clone

    def submit(self, fn: Callable, *args: Any, lane: str = "main") -> Future:
        with self._lock:
            ln = self._lanes.get(lane)
            if ln is None:
                ln = self._lanes[lane] = _ExecutorLane(f"{self.node}:{lane}")
        return ln.submit(fn, *args)

    def shutdown(self) -> None:
        with self._lock:
            lanes, self._lanes = list(self._lanes.values()), {}
            self._plans.clear()
        for ln in lanes:
            ln.stop()


# --------------------------------------------------------------------------
# Asynchronous double-buffered shuffle (paper Sec. VI-B, DESIGN.md §4)
# --------------------------------------------------------------------------
class ShuffleService:
    """Redistributes a stage's output across nodes by group label.

    The old barrier round-tripped every shuffled item through pickled DFS
    files *inside* the epoch barrier.  Now:

    * groups hand off **in memory** to their target nodes immediately — the
      next stage starts without any DFS traffic (round memory is already
      bounded upstream: bounded ingest queues cap the epoch, and the
      committer's job queue caps epochs in flight);
    * only a round past ``spill_bytes`` is spilled to the DFS (the group
      files other nodes would fetch in a real deployment), and the write is
      *asynchronous and double-buffered*: the DFS write of epoch N's groups
      overlaps epoch N+1's ingest, and the next barrier for the same stage
      first drains the previous round's write — at most two rounds are ever
      in flight per stage (the two buffers).

    ``synchronous=True`` restores the pre-pipelining barrier (paper Sec.
    VI-B verbatim, and what this engine did before ISSUE 2): every round is
    written to the DFS and read back *inside* the barrier.  Kept as a mode
    for debugging and as the baseline of the pipelining benchmark.
    """

    def __init__(self, store: DataStore, spill_bytes: int = 32 << 20,
                 synchronous: bool = False) -> None:
        self.store = store
        self.spill_bytes = spill_bytes
        self.synchronous = synchronous
        self._lock = threading.Lock()
        self._stage_locks: Dict[str, threading.Lock] = {}
        self._pending: Dict[str, Future] = {}
        self._writer: Optional[_ExecutorLane] = None
        self._spilled_stages: set = set()   # stages with DFS group files

    # ------------------------------------------------------------------ util
    def _stage_lock(self, stage: str) -> threading.Lock:
        with self._lock:
            lk = self._stage_locks.get(stage)
            if lk is None:
                lk = self._stage_locks[stage] = threading.Lock()
            return lk

    def _writer_lane(self) -> _ExecutorLane:
        with self._lock:
            if self._writer is None:
                self._writer = _ExecutorLane("shuffle-journal")
            return self._writer

    def _dfs_dir(self, stage: str) -> str:
        return os.path.join(self.store.dfs_dir, f"shuffle_{stage}")

    @staticmethod
    def _shuffle_key(sp: StagePlan) -> Optional[str]:
        key = None
        for op in sp.ops:
            if "shuffle_by" in op.params:
                key = op.params["shuffle_by"]
        return key

    # --------------------------------------------------------------- barrier
    def barrier(self, sp: StagePlan,
                outputs: Dict[str, Dict[str, List[IngestItem]]],
                live: List[str], report: RunReport) -> None:
        """``live`` is the caller's pinned executing-node set — groups are
        collected from and reassigned over exactly these nodes."""
        if not sp.ops:
            return
        shuffle_by = self._shuffle_key(sp)
        if shuffle_by is None:
            return
        with self._stage_lock(sp.name):
            with self._lock:
                prev = self._pending.pop(sp.name, None)
            if prev is not None:
                prev.result()  # double buffer: last round's journal must land

            groups: Dict[Any, List[IngestItem]] = {}
            nbytes = 0
            for n in live:
                for it in outputs[n][sp.name]:
                    g = it.label_value(shuffle_by, 0)
                    groups.setdefault(g, []).append(it)
                    nbytes += it.nbytes()
                    report.shuffled_items += 1
                outputs[n][sp.name] = []
            if not groups:
                return
            order = sorted(groups, key=str)
            if self.synchronous:
                # legacy path: DFS round-trip inside the barrier
                report.shuffle_spills += 1
                dfs = self._write_groups(sp.name, order, groups)
                groups.clear()
                for gi, fn in enumerate(sorted(os.listdir(dfs))):
                    target = live[gi % len(live)]
                    with open(os.path.join(dfs, fn), "rb") as f:
                        outputs[target][sp.name].extend(pickle.load(f))
                # consume-on-read: the next round must not merge these files
                shutil.rmtree(dfs, ignore_errors=True)
                return
            for gi, g in enumerate(order):
                outputs[live[gi % len(live)]][sp.name].extend(groups[g])
            if nbytes > self.spill_bytes:
                # oversized round: materialize the group files on the DFS in
                # the background — overlapped with the next epoch's ingest
                report.shuffle_spills += 1
                fut = self._writer_lane().submit(
                    self._write_groups, sp.name, order, groups)
                with self._lock:
                    self._pending[sp.name] = fut
                    self._spilled_stages.add(sp.name)
            else:
                report.shuffle_async_rounds += 1

    # ----------------------------------------------------------------- paths
    def _write_groups(self, stage: str, order: List[Any],
                      groups: Dict[Any, List[IngestItem]]) -> str:
        """Local groups -> one DFS file per group (consume-on-write: a fresh
        round never merges an earlier round's leftovers)."""
        dfs = self._dfs_dir(stage)
        shutil.rmtree(dfs, ignore_errors=True)
        os.makedirs(dfs, exist_ok=True)
        for g in order:
            with open(os.path.join(dfs, f"group{g}.pkl"), "wb") as f:
                pickle.dump(groups[g], f, protocol=pickle.HIGHEST_PROTOCOL)
        return dfs

    # ------------------------------------------------------------- lifecycle
    def drain(self) -> None:
        """Wait for every outstanding journal write (end-of-stream barrier)."""
        with self._lock:
            pending, self._pending = list(self._pending.values()), {}
        for fut in pending:
            fut.result()

    def close(self) -> None:
        self.drain()
        with self._lock:
            writer, self._writer = self._writer, None
            spilled, self._spilled_stages = set(self._spilled_stages), set()
        if writer is not None:
            writer.stop()
        for stage in spilled:   # spilled group files die with the service
            shutil.rmtree(self._dfs_dir(stage), ignore_errors=True)


class RuntimeEngine:
    def __init__(self, store: DataStore, optimizer: Optional[IngestionOptimizer] = None,
                 max_retries: int = 3, shuffle_spill_bytes: Optional[int] = None,
                 shuffle_synchronous: bool = False,
                 backend: str = "thread",
                 memory_budget_bytes: Optional[int] = None) -> None:
        """``backend`` selects the node substrate: ``"thread"`` (default —
        in-process ``NodeExecutor`` lanes) or ``"process"`` (one long-lived
        worker process per node, real CPU parallelism; DESIGN.md §6).

        ``memory_budget_bytes`` is the engine's shared memory budget: when
        set and no explicit ``shuffle_spill_bytes`` is given, the shuffle
        spill threshold is derived from it (minus the ingest queues' share,
        for the streaming engine) instead of the static default."""
        if backend not in ("thread", "process"):
            raise ValueError(f"unknown backend {backend!r} (thread|process)")
        self.store = store
        self.nodes = list(store.nodes)
        self.optimizer = optimizer or IngestionOptimizer()
        self.max_retries = max_retries
        self.backend = backend
        self.memory_budget_bytes = memory_budget_bytes
        self._explicit_spill = shuffle_spill_bytes is not None
        if shuffle_spill_bytes is None:
            shuffle_spill_bytes = (derive_spill_bytes(memory_budget_bytes)
                                   if memory_budget_bytes is not None
                                   else DEFAULT_SPILL_BYTES)
        self.shuffle = ShuffleService(store, spill_bytes=shuffle_spill_bytes,
                                      synchronous=shuffle_synchronous)
        self._executors: Dict[str, Any] = {}
        self._exec_lock = threading.Lock()

    # ------------------------------------------------------------------ remote
    def launch_remote(self, node: str, stage_plans: List[StagePlan]) -> List[StagePlan]:
        """The remote-shell seam: in a real deployment this SSHes the optimized
        plan to ``node`` (paper Sec. VI-A).  The thread backend clones operator
        instances so every node runs its own state, exactly as separate JVMs
        would; the process backend ships the same plan by pickle to the node's
        worker process (``ProcessNodeExecutor.install_plan``)."""
        return [sp.clone() for sp in stage_plans]

    def executor(self, node: str) -> Any:
        """The node's persistent executor (created on first use, kept for the
        engine's lifetime — stage barriers stop re-creating thread pools).
        Thread backend: ``NodeExecutor``; process backend:
        ``ProcessNodeExecutor`` (a live worker process)."""
        with self._exec_lock:
            ex = self._executors.get(node)
            if ex is None:
                ex = (ProcessNodeExecutor(node, self.store)
                      if self.backend == "process" else NodeExecutor(node))
                self._executors[node] = ex
            return ex

    def prewarm_executors(self) -> None:
        """Spawn every node's executor up front.  The process backend forks
        here — before feeder/committer threads exist — so worker processes
        never inherit mid-operation thread state."""
        for n in self.nodes:
            self.executor(n)

    def close(self) -> None:
        """Shut down persistent node executors and the shuffle writer."""
        self.shuffle.close()
        with self._exec_lock:
            execs, self._executors = list(self._executors.values()), {}
        for ex in execs:
            ex.shutdown()

    def __enter__(self) -> "RuntimeEngine":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # --------------------------------------------------------------------- run
    def run(self, plan: IngestPlan,
            sources: Union[Dict[str, List[IngestItem]], List[IngestItem]],
            faults: Optional[FaultInjection] = None,
            optimize: bool = True) -> RunReport:
        t0 = time.time()
        faults = faults or FaultInjection()
        report = RunReport()
        if self.backend == "process":
            self.prewarm_executors()   # fork before any run-scoped threads

        stage_plans = plan.compile()
        if optimize:
            stage_plans = self.optimizer.optimize(stage_plans)

        # ---- distribute source shards: node-local dict, or shared queue
        # (work stealing / straggler mitigation: slow nodes take fewer shards)
        node_sources: Dict[str, List[IngestItem]] = {n: [] for n in self.nodes}
        if isinstance(sources, dict):
            for n, items in sources.items():
                node_sources[n].extend(items)
        else:
            shared: "queue.Queue[IngestItem]" = queue.Queue()
            for it in sources:
                shared.put(it)
            while True:
                grabbed = False
                for n in self.nodes:
                    try:
                        node_sources[n].append(shared.get_nowait())
                        grabbed = True
                    except queue.Empty:
                        break
                if not grabbed:
                    break
        report.per_node_shards = {n: len(v) for n, v in node_sources.items()}

        alive = {n: True for n in self.nodes}
        # a fresh batch run starts from full liveness — clear placement marks
        # a previous run's (injected) deaths left on the shared store
        for n in self.nodes:
            self.store.mark_node_live(n)
        self._execute(stage_plans, node_sources, faults, report, alive)
        self.shuffle.drain()

        report.wall_time_s = time.time() - t0
        self.store.flush_manifest()
        return report

    # ----------------------------------------------------------- stage dataflow
    def _mark_dead(self, node: str, alive: Dict[str, bool], report: RunReport) -> None:
        alive[node] = False
        report.node_failures.append(node)
        # location IDs of the dead node flow to the survivors (Sec. VI-C1):
        # the upload operator maps location ids over live nodes only
        self.store.mark_node_dead(node)

    def _execute(self, stage_plans: List[StagePlan],
                 node_sources: Dict[str, List[IngestItem]],
                 faults: FaultInjection, report: RunReport,
                 alive: Dict[str, bool],
                 on_node_death: str = "reassign",
                 lane: str = "main",
                 epoch: Optional[int] = None,
                 outputs: Optional[Dict[str, Dict[str, List[IngestItem]]]] = None,
                 start_stage: int = 0,
                 end_stage: Optional[int] = None,
                 node_set: Optional[List[str]] = None
                 ) -> Dict[str, Dict[str, List[IngestItem]]]:
        """Run (a slice of) the stage DAG over per-node shards — the body
        shared by the batch engine and the streaming engine's per-epoch
        execution.  Stage jobs run on the persistent per-node executors.

        ``on_node_death`` selects the recovery policy:
          * ``"reassign"`` (batch): the dead node's shards move to the next
            live node, which replays stages 0..si for them (Sec. VI-C1).
          * ``"raise"`` (streaming): mark the node dead and raise NodeFailure —
            the caller aborts the staged epoch and replays it on the
            surviving nodes (epoch-granular recovery).

        ``lane`` picks the NodeExecutor lane (pipelined streaming keeps epoch
        N+1's ingest and epoch N's store on separate lanes); ``epoch`` binds
        ``DataStore.put_block`` attribution for concurrent staging epochs;
        ``outputs``/``start_stage``/``end_stage`` execute a slice of the DAG
        over pre-seeded upstream outputs (the ingest/store segment split).

        ``node_set`` pins the executing nodes for the whole call: with two
        epochs in flight, ``alive`` can flip concurrently from the *other*
        epoch's thread, and a per-stage liveness read could silently skip a
        node whose inputs this epoch still holds.  Raise-mode callers pass
        their consistent snapshot; batch recomputes per stage (it owns
        ``alive`` exclusively and needs reassignment to see deaths).
        """
        if on_node_death == "reassign" and (start_stage != 0 or end_stage is not None):
            raise ValueError("shard reassignment requires the full stage DAG")
        use_proc = self.backend == "process"
        # ---- plan is resident on every node executor (installed once);
        # thread backend: in-process clone; process backend: pickled ship to
        # the worker.  A worker already dead at install time takes the same
        # fault path as one dying mid-stage.
        node_plans: Dict[str, List[StagePlan]] = {}
        plan_keys: Dict[str, str] = {}
        install_failed: List[str] = []
        exec_nodes = (list(node_set) if node_set is not None
                      else [n for n in self.nodes if alive.get(n)])
        for n in exec_nodes:
            try:
                if use_proc:
                    plan_keys[n] = self.executor(n).install_plan(stage_plans)
                else:
                    node_plans[n] = self.executor(n).install_plan(
                        stage_plans, self.launch_remote)
            except WorkerDeath:
                install_failed.append(n)
        for n in install_failed:
            self._mark_dead(n, alive, report)
        if install_failed and on_node_death == "raise":
            raise NodeFailure(install_failed[0])
        if outputs is None:
            outputs = {n: defaultdict(list) for n in self.nodes}
        stop = len(stage_plans) if end_stage is None else end_stage
        failure_counts: Dict[Tuple[str, str, int], int] = defaultdict(int)

        # dedicated lock for report mutation from worker threads
        rlock = threading.Lock()

        for si in range(start_stage, stop):
            sp = stage_plans[si]

            # -------------------------------------------------- stage barrier
            def run_stage_on(node: str, nsp: StagePlan,
                             input_items: List[IngestItem]) -> List[IngestItem]:
                with self.store.epoch_context(epoch):
                    return self._run_stage(node, nsp, input_items, faults,
                                           failure_counts, report, rlock)

            def stage_inputs(node: str, nsp: StagePlan) -> List[IngestItem]:
                if not nsp.upstream:
                    base = node_sources[node]
                else:
                    base = []
                    for up in nsp.upstream:  # CHAIN = union all (Sec. IV-B)
                        base = base + outputs[node][up]
                return route_items(base, nsp.predicates)

            live_nodes = (list(node_set) if node_set is not None
                          else [n for n in self.nodes if alive[n]])
            futs = {}
            if use_proc:
                # injected op failures are assigned to the first live node
                # (the thread backend's shared-dict race picks an arbitrary
                # winner; the process backend makes it deterministic)
                injections: Dict[int, int] = {}
                for (sname, oi), cnt in list(faults.op_failures.items()):
                    if sname == sp.name and cnt > 0:
                        injections[oi] = cnt
                        faults.op_failures[(sname, oi)] = 0
                for ni, n in enumerate(live_nodes):
                    futs[n] = self.executor(n).run_stage(
                        plan_keys[n], si, stage_inputs(n, sp), lane=lane,
                        epoch=epoch, live_nodes=live_nodes,
                        injections=injections if ni == 0 else None,
                        max_retries=self.max_retries)
            else:
                for n in live_nodes:
                    nsp = node_plans[n][si]
                    futs[n] = self.executor(n).submit(
                        run_stage_on, n, nsp, stage_inputs(n, nsp), lane=lane)
            failed: List[str] = []
            for n, fut in futs.items():  # drain ALL jobs before acting on death
                try:
                    res = fut.result()
                except (NodeFailure, WorkerDeath):
                    failed.append(n)
                    continue
                if use_proc:
                    outputs[n][sp.name], stats = res
                    with rlock:
                        for k, v in stats["op_failures"].items():
                            report.op_failures[k] = max(
                                report.op_failures.get(k, 0), v)
                        report.dummy_substitutions.extend(stats["dummy"])
                else:
                    outputs[n][sp.name] = res
            for n in failed:
                self._mark_dead(n, alive, report)
            if failed and on_node_death == "raise":
                raise NodeFailure(failed[0])

            # ---- shuffle barrier: redistribute groups (Sec. VI-B).  With a
            # pinned node_set (raise mode) a stage failure raised above, so
            # the whole set redistributes — re-reading `alive` here would
            # race with the other epoch's thread and silently skip a node's
            # outputs.  Batch mode re-reads it so a node that just failed
            # this stage takes no groups.
            barrier_live = (live_nodes if node_set is not None
                            else [n for n in live_nodes if alive[n]])
            self.shuffle.barrier(sp, outputs, barrier_live, report)

            # ---- injected node deaths after this stage
            for n, after in faults.node_death_after_stage.items():
                if after == sp.name and alive.get(n):
                    self._mark_dead(n, alive, report)
                    if on_node_death == "raise":
                        raise NodeFailure(n)

            # ---- node-failure recovery: reassign dead nodes' shards to the
            # next live node in the slaves order and re-run stages 0..si for
            # them (their in-flight state is lost with the node).  Only the
            # batch policy reassigns here — under "raise" the epoch replays
            # wholesale, and a death observed from a *concurrent* epoch's
            # thread must not trigger a partial replay inside this one.
            # Recomputed until quiescent: a *target* worker dying mid-replay
            # (process backend) is marked dead, its shards — including the
            # ones just moved onto it — reassign to the next survivor.
            while on_node_death == "reassign":
                dead = [n for n in self.nodes if not alive[n] and node_sources[n]]
                if not dead:
                    break
                n = dead[0]
                target = self._next_live(n, alive)
                if target is None:
                    raise RuntimeError("all nodes failed")
                shards = node_sources.pop(n)
                node_sources[n] = []
                node_sources[target].extend(shards)
                report.reassigned_shards += len(shards)
                # re-run all stages so far for the moved shards on the target
                replay_out: Dict[str, List[IngestItem]] = defaultdict(list)
                target_died = False
                for sj in range(si + 1):
                    rp = stage_plans[sj] if use_proc else node_plans[target][sj]
                    if not rp.upstream:
                        base = shards
                    else:
                        base = []
                        for up in rp.upstream:
                            base = base + replay_out[up]
                    routed = route_items(base, rp.predicates)
                    if use_proc:
                        # replay runs on the target's worker (its resident
                        # plan state absorbs the moved shards)
                        try:
                            replay_out[rp.name], rstats = self.executor(
                                target).run_stage(
                                    plan_keys[target], sj, routed, lane=lane,
                                    epoch=epoch, live_nodes=live_nodes,
                                    max_retries=self.max_retries).result()
                        except (NodeFailure, WorkerDeath):
                            # the shards sit in node_sources[target]; the
                            # next loop pass moves them to a survivor
                            self._mark_dead(target, alive, report)
                            target_died = True
                            break
                        with rlock:
                            report.dummy_substitutions.extend(rstats["dummy"])
                    else:
                        replay_out[rp.name] = self._run_stage(
                            target, self.launch_remote(target, [rp])[0], routed,
                            faults, failure_counts, report, rlock)
                if not target_died:
                    for k, v in replay_out.items():
                        outputs[target][k].extend(v)

            total = sum(len(outputs[n][sp.name]) for n in self.nodes if alive[n])
            report.stage_items[sp.name] = total

        return outputs

    # ------------------------------------------------------------- stage exec
    def _run_stage(self, node: str, sp: StagePlan, items: List[IngestItem],
                   faults: FaultInjection,
                   failure_counts: Dict[Tuple[str, str, int], int],
                   report: RunReport, rlock: threading.Lock) -> List[IngestItem]:
        """Run one stage's pipeline blocks over a node's items.

        Each block boundary is a materialization = checkpoint: on operator
        failure the block is retried from its checkpointed input; after
        ``max_retries`` the failing operator is replaced by a dummy
        pass-through (paper Sec. VI-C1).
        """
        current = items
        for block in sp.pipeline_blocks or [[i] for i in range(len(sp.ops))]:
            checkpoint = current  # materialized input of this block
            while True:
                try:
                    out = checkpoint
                    for oi in block:
                        op = sp.ops[oi]
                        # injected failures (tests)
                        key = (sp.name, oi)
                        if faults.op_failures.get(key, 0) > 0:
                            faults.op_failures[key] -= 1
                            raise OperatorFailure(f"injected @ {sp.name}[{oi}]")
                        out = op.run(out)
                    current = out
                    break
                except OperatorFailure as e:
                    oi = block[0] if len(block) == 1 else self._failed_op_index(sp, block, e)
                    fkey = (node, sp.name, oi)
                    failure_counts[fkey] += 1
                    with rlock:
                        report.op_failures[f"{sp.name}[{oi}]"] = failure_counts[fkey]
                    if failure_counts[fkey] >= self.max_retries:
                        failing = sp.ops[oi]
                        sp.ops[oi] = PassThroughOp(replaces=failing.name)
                        with rlock:
                            report.dummy_substitutions.append(
                                f"{sp.name}[{oi}]:{type(failing).__name__}")
                    # retry block from the checkpoint (resume from previous
                    # materialization, not from scratch)
                    continue
        return current

    # shared with the process backend's worker (plan.failed_op_index)
    _failed_op_index = staticmethod(failed_op_index)

    def _next_live(self, node: str, alive: Dict[str, bool]) -> Optional[str]:
        """Round-robin successor in the slaves file order (paper Sec. VI-C1)."""
        if node in self.nodes:
            start = self.nodes.index(node)
        else:
            start = 0
        for k in range(1, len(self.nodes) + 1):
            cand = self.nodes[(start + k) % len(self.nodes)]
            if alive.get(cand):
                return cand
        return None


def ingest(plan: IngestPlan, sources: Union[Dict[str, List[IngestItem]], List[IngestItem]],
           store: DataStore, optimize: bool = True,
           faults: Optional[FaultInjection] = None) -> RunReport:
    """One-call entry point: optimize + run an ingestion plan against a store."""
    with RuntimeEngine(store) as eng:
        return eng.run(plan, sources, faults=faults, optimize=optimize)
