"""The INGESTBASE runtime engine (paper Sec. VI).

* **Inter-node parallelism** — the client ships the *optimized* plan to every
  node in the slaves list and runs it over node-local shards ("ship the plan
  to the data").  Nodes here are worker threads over per-node directories; the
  remote-shell seam is ``launch_remote`` (DESIGN.md §2).
* **Intra-node parallelism** — parallel-mode operators fan out over a thread
  pool (see operators.IngestOp._parallel_iter).
* **Work stealing** — when sources are given as a shared list, nodes pull
  shards from a global queue, so stragglers simply take fewer shards.
* **Distributed I/O** — shuffle via the store's DFS directory (local groups ->
  DFS -> group-directories read back per node), placement via location IDs,
  replication decoupled from placement.
* **In-flight fault tolerance** — pipeline blocks are checkpoints: a failing
  operator retries its block from the previous materialization; after
  ``max_retries`` failures it is replaced by a dummy pass-through operator
  labelling items with -1.  Node failures reassign shards + location IDs to
  the next node in the slaves order.
"""
from __future__ import annotations

import os
import pickle
import queue
import shutil
import threading
import time
from collections import defaultdict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .items import IngestItem
from .operators import IngestOp, OperatorFailure, PassThroughOp
from .optimizer import IngestionOptimizer
from .plan import IngestPlan, StagePlan, route_items
from .store import DataStore


class NodeFailure(RuntimeError):
    """Simulated machine failure during ingestion."""


@dataclass
class RunReport:
    """What the engine observed while executing a plan."""

    stage_items: Dict[str, int] = field(default_factory=dict)
    op_failures: Dict[str, int] = field(default_factory=dict)
    dummy_substitutions: List[str] = field(default_factory=list)
    node_failures: List[str] = field(default_factory=list)
    reassigned_shards: int = 0
    shuffled_items: int = 0
    wall_time_s: float = 0.0
    per_node_shards: Dict[str, int] = field(default_factory=dict)


@dataclass
class FaultInjection:
    """Test hooks: deterministic failures."""

    # (stage_name, op_index) -> number of consecutive failures to inject
    op_failures: Dict[Tuple[str, int], int] = field(default_factory=dict)
    # node -> stage name after which the node dies
    node_death_after_stage: Dict[str, str] = field(default_factory=dict)


class RuntimeEngine:
    def __init__(self, store: DataStore, optimizer: Optional[IngestionOptimizer] = None,
                 max_retries: int = 3) -> None:
        self.store = store
        self.nodes = list(store.nodes)
        self.optimizer = optimizer or IngestionOptimizer()
        self.max_retries = max_retries

    # ------------------------------------------------------------------ remote
    def launch_remote(self, node: str, stage_plans: List[StagePlan]) -> List[StagePlan]:
        """The remote-shell seam: in a real deployment this SSHes the optimized
        plan to ``node`` (paper Sec. VI-A).  Here it clones operator instances
        so every node runs its own state, exactly as separate JVMs would."""
        return [StagePlan(sp.name, [op.clone() for op in sp.ops], list(sp.upstream),
                          dict(sp.predicates), [list(b) for b in sp.pipeline_blocks])
                for sp in stage_plans]

    # --------------------------------------------------------------------- run
    def run(self, plan: IngestPlan,
            sources: Union[Dict[str, List[IngestItem]], List[IngestItem]],
            faults: Optional[FaultInjection] = None,
            optimize: bool = True) -> RunReport:
        t0 = time.time()
        faults = faults or FaultInjection()
        report = RunReport()

        stage_plans = plan.compile()
        if optimize:
            stage_plans = self.optimizer.optimize(stage_plans)

        # ---- distribute source shards: node-local dict, or shared queue
        # (work stealing / straggler mitigation: slow nodes take fewer shards)
        node_sources: Dict[str, List[IngestItem]] = {n: [] for n in self.nodes}
        if isinstance(sources, dict):
            for n, items in sources.items():
                node_sources[n].extend(items)
        else:
            shared: "queue.Queue[IngestItem]" = queue.Queue()
            for it in sources:
                shared.put(it)
            while True:
                grabbed = False
                for n in self.nodes:
                    try:
                        node_sources[n].append(shared.get_nowait())
                        grabbed = True
                    except queue.Empty:
                        break
                if not grabbed:
                    break
        report.per_node_shards = {n: len(v) for n, v in node_sources.items()}

        alive = {n: True for n in self.nodes}
        self._execute(stage_plans, node_sources, faults, report, alive)

        report.wall_time_s = time.time() - t0
        self.store.flush_manifest()
        return report

    # ----------------------------------------------------------- stage dataflow
    def _execute(self, stage_plans: List[StagePlan],
                 node_sources: Dict[str, List[IngestItem]],
                 faults: FaultInjection, report: RunReport,
                 alive: Dict[str, bool],
                 on_node_death: str = "reassign") -> Dict[str, Dict[str, List[IngestItem]]]:
        """Run the stage DAG over per-node shards (the body shared by the batch
        engine and the streaming engine's per-epoch execution).

        ``on_node_death`` selects the recovery policy:
          * ``"reassign"`` (batch): the dead node's shards move to the next
            live node, which replays stages 0..si for them (Sec. VI-C1).
          * ``"raise"`` (streaming): mark the node dead and raise NodeFailure —
            the caller aborts the staged epoch and replays it on the
            surviving nodes (epoch-granular recovery).
        """
        # ---- ship plan to every node
        node_plans = {n: self.launch_remote(n, stage_plans) for n in self.nodes}
        # per-node stage outputs
        outputs: Dict[str, Dict[str, List[IngestItem]]] = {
            n: defaultdict(list) for n in self.nodes}
        failure_counts: Dict[Tuple[str, str, int], int] = defaultdict(int)

        # dedicated lock for report mutation from worker threads
        rlock = threading.Lock()

        for si, sp in enumerate(stage_plans):
            # -------------------------------------------------- stage barrier
            def run_stage_on(node: str, nsp: StagePlan,
                             input_items: List[IngestItem]) -> List[IngestItem]:
                return self._run_stage(node, nsp, input_items, faults,
                                       failure_counts, report, rlock)

            def stage_inputs(node: str, nsp: StagePlan) -> List[IngestItem]:
                if not nsp.upstream:
                    base = node_sources[node]
                else:
                    base = []
                    for up in nsp.upstream:  # CHAIN = union all (Sec. IV-B)
                        base = base + outputs[node][up]
                return route_items(base, nsp.predicates)

            live_nodes = [n for n in self.nodes if alive[n]]
            with ThreadPoolExecutor(max_workers=max(1, len(live_nodes))) as pool:
                futs = {}
                for n in live_nodes:
                    nsp = node_plans[n][si]
                    futs[n] = pool.submit(run_stage_on, n, nsp, stage_inputs(n, nsp))
                for n, fut in futs.items():
                    try:
                        outputs[n][sp.name] = fut.result()
                    except NodeFailure:
                        alive[n] = False
                        report.node_failures.append(n)
                        if on_node_death == "raise":
                            raise NodeFailure(n)

            # ---- shuffle barrier: redistribute DFS groups (Sec. VI-B)
            self._shuffle_barrier(sp, outputs, alive, report)

            # ---- injected node deaths after this stage
            for n, after in faults.node_death_after_stage.items():
                if after == sp.name and alive.get(n):
                    alive[n] = False
                    report.node_failures.append(n)
                    if on_node_death == "raise":
                        raise NodeFailure(n)

            # ---- node-failure recovery: reassign dead nodes' shards to the
            # next live node in the slaves order and re-run stages 0..si for
            # them (their in-flight state is lost with the node).
            dead = [n for n in self.nodes if not alive[n] and node_sources[n]]
            for n in dead:
                target = self._next_live(n, alive)
                if target is None:
                    raise RuntimeError("all nodes failed")
                shards = node_sources.pop(n)
                node_sources[n] = []
                node_sources[target].extend(shards)
                report.reassigned_shards += len(shards)
                # location IDs of the dead node flow to the target (Sec. VI-C1)
                # re-run all stages so far for the moved shards on the target
                replay_out: Dict[str, List[IngestItem]] = defaultdict(list)
                for sj in range(si + 1):
                    rp = node_plans[target][sj]
                    if not rp.upstream:
                        base = shards
                    else:
                        base = []
                        for up in rp.upstream:
                            base = base + replay_out[up]
                    routed = route_items(base, rp.predicates)
                    replay_out[rp.name] = self._run_stage(
                        target, self.launch_remote(target, [rp])[0], routed, faults,
                        failure_counts, report, rlock)
                for k, v in replay_out.items():
                    outputs[target][k].extend(v)

            total = sum(len(outputs[n][sp.name]) for n in self.nodes if alive[n])
            report.stage_items[sp.name] = total

        return outputs

    # ------------------------------------------------------------- stage exec
    def _run_stage(self, node: str, sp: StagePlan, items: List[IngestItem],
                   faults: FaultInjection,
                   failure_counts: Dict[Tuple[str, str, int], int],
                   report: RunReport, rlock: threading.Lock) -> List[IngestItem]:
        """Run one stage's pipeline blocks over a node's items.

        Each block boundary is a materialization = checkpoint: on operator
        failure the block is retried from its checkpointed input; after
        ``max_retries`` the failing operator is replaced by a dummy
        pass-through (paper Sec. VI-C1).
        """
        current = items
        for block in sp.pipeline_blocks or [[i] for i in range(len(sp.ops))]:
            checkpoint = current  # materialized input of this block
            while True:
                try:
                    out = checkpoint
                    for oi in block:
                        op = sp.ops[oi]
                        # injected failures (tests)
                        key = (sp.name, oi)
                        if faults.op_failures.get(key, 0) > 0:
                            faults.op_failures[key] -= 1
                            raise OperatorFailure(f"injected @ {sp.name}[{oi}]")
                        out = op.run(out)
                    current = out
                    break
                except OperatorFailure as e:
                    oi = block[0] if len(block) == 1 else self._failed_op_index(sp, block, e)
                    fkey = (node, sp.name, oi)
                    failure_counts[fkey] += 1
                    with rlock:
                        report.op_failures[f"{sp.name}[{oi}]"] = failure_counts[fkey]
                    if failure_counts[fkey] >= self.max_retries:
                        failing = sp.ops[oi]
                        sp.ops[oi] = PassThroughOp(replaces=failing.name)
                        with rlock:
                            report.dummy_substitutions.append(
                                f"{sp.name}[{oi}]:{type(failing).__name__}")
                    # retry block from the checkpoint (resume from previous
                    # materialization, not from scratch)
                    continue
        return current

    @staticmethod
    def _failed_op_index(sp: StagePlan, block: List[int], exc: Exception) -> int:
        """Recover which op in a multi-op block failed from the message."""
        msg = str(exc)
        for oi in block:
            if f"[{oi}]" in msg or sp.ops[oi].name in msg:
                return oi
        return block[0]

    def _next_live(self, node: str, alive: Dict[str, bool]) -> Optional[str]:
        """Round-robin successor in the slaves file order (paper Sec. VI-C1)."""
        if node in self.nodes:
            start = self.nodes.index(node)
        else:
            start = 0
        for k in range(1, len(self.nodes) + 1):
            cand = self.nodes[(start + k) % len(self.nodes)]
            if alive.get(cand):
                return cand
        return None

    # ---------------------------------------------------------------- shuffle
    def _shuffle_barrier(self, sp: StagePlan,
                         outputs: Dict[str, Dict[str, List[IngestItem]]],
                         alive: Dict[str, bool], report: RunReport) -> None:
        """Redistribute a stage's output across nodes by group label.

        If the stage's last operator declared ``shuffle_by`` in its params, the
        engine (1) writes each node's local groups into the DFS directory, and
        (2) reassigns each group directory to the node ``group % n_live``
        (paper Sec. VI-B Shuffling).
        """
        if not sp.ops:
            return
        shuffle_by = None
        for op in sp.ops:
            if "shuffle_by" in op.params:
                shuffle_by = op.params["shuffle_by"]
        if shuffle_by is None:
            return
        dfs = os.path.join(self.store.dfs_dir, f"shuffle_{sp.name}")
        # a fresh round never merges leftovers: an epoch attempt aborted
        # between shuffle write and read leaves files behind
        shutil.rmtree(dfs, ignore_errors=True)
        os.makedirs(dfs, exist_ok=True)
        live = [n for n in alive if alive[n]]
        # phase 1: local groups -> DFS group directories
        for n in live:
            for i, it in enumerate(outputs[n][sp.name]):
                g = it.label_value(shuffle_by, 0)
                gdir = os.path.join(dfs, f"group{g}")
                os.makedirs(gdir, exist_ok=True)
                with open(os.path.join(gdir, f"{n}_{i}.pkl"), "wb") as f:
                    pickle.dump(it, f)
                report.shuffled_items += 1
            outputs[n][sp.name] = []
        # phase 2: each group directory is read back by one node
        groups = sorted(os.listdir(dfs))
        for gi, g in enumerate(groups):
            target = live[gi % len(live)]
            gdir = os.path.join(dfs, g)
            merged: List[IngestItem] = []
            for fn in sorted(os.listdir(gdir)):
                with open(os.path.join(gdir, fn), "rb") as f:
                    merged.append(pickle.load(f))
            outputs[target][sp.name].extend(merged)
        # consume-on-read: a later barrier for the same stage (next epoch, or
        # an epoch replay after abort) must not merge this round's files
        shutil.rmtree(dfs, ignore_errors=True)


def ingest(plan: IngestPlan, sources: Union[Dict[str, List[IngestItem]], List[IngestItem]],
           store: DataStore, optimize: bool = True,
           faults: Optional[FaultInjection] = None) -> RunReport:
    """One-call entry point: optimize + run an ingestion plan against a store."""
    return RuntimeEngine(store).run(plan, sources, faults=faults, optimize=optimize)
