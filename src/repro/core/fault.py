"""Post-ingestion fault tolerance (paper Sec. VI-C2, Fig. 3).

Users control how *their* data recovers via two UDFs:

    detect:  f -> {r1, r2, .., rn}     # which blocks can recover block f
    recover: {B_r1, .., B_rn} -> B_f   # rebuild the failed block

A fault-tolerance daemon polls the store for failing blocks and invokes the
registered recovery UDFs.  Three built-ins (paper):

  ReplicationRecovery    — point at an identical replica, bump its replication
  TransformationRecovery — copy a differently-serialized replica and re-encode
                           it into the failed block's layout
  ErasureRecovery        — fetch surviving stripe members, Reed-Solomon decode
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..erasure import ReedSolomon
from ..layouts import SerializedBlock, deserialize_block, serialize_block
from .store import BlockEntry, DataStore


class RecoveryError(RuntimeError):
    pass


class RecoveryUDF:
    """detect/recover pair bound to a store."""

    name = "recovery"

    def detect(self, store: DataStore, failed: BlockEntry) -> List[str]:
        """Which block ids are needed to recover ``failed``?"""
        raise NotImplementedError

    def recover(self, store: DataStore, failed: BlockEntry,
                recovery_ids: List[str]) -> bytes:
        """Reconstruct the failed block's payload from the recovery blocks."""
        raise NotImplementedError

    def applies_to(self, store: DataStore, failed: BlockEntry) -> bool:
        try:
            return len(self.detect(store, failed)) > 0
        except RecoveryError:
            return False


class ReplicationRecovery(RecoveryUDF):
    """Find a bitwise-identical replica; re-publish its bytes (HDFS would bump
    the replication factor; here we rewrite the lost file from the replica)."""

    name = "replication"

    def detect(self, store: DataStore, failed: BlockEntry) -> List[str]:
        sibs = [e for e in store.replicas_of(failed.logical_id)
                if e.block_id != failed.block_id and e.layout == failed.layout
                and not e.is_parity and store.verify_block(e.block_id)]
        return [sibs[0].block_id] if sibs else []

    def recover(self, store: DataStore, failed: BlockEntry,
                recovery_ids: List[str]) -> bytes:
        if not recovery_ids:
            raise RecoveryError(f"no identical replica for {failed.block_id}")
        return store.read_payload(recovery_ids[0])


class TransformationRecovery(RecoveryUDF):
    """Recover from a replica in a *different* layout: deserialize it and
    re-serialize into the failed layout (per-replica / Trojan layouts)."""

    name = "transformation"

    def detect(self, store: DataStore, failed: BlockEntry) -> List[str]:
        sibs = [e for e in store.replicas_of(failed.logical_id)
                if e.block_id != failed.block_id and not e.is_parity
                and e.layout not in ("raw",) and store.verify_block(e.block_id)]
        return [sibs[0].block_id] if sibs else []

    def recover(self, store: DataStore, failed: BlockEntry,
                recovery_ids: List[str]) -> bytes:
        if not recovery_ids:
            raise RecoveryError(f"no transformable replica for {failed.block_id}")
        src = store.read_block(recovery_ids[0])
        cols = deserialize_block(src)
        layout_kw: Dict[str, Any] = {}
        if failed.layout == "sorted":
            layout_kw["key"] = failed.meta.get("sort_key")
        out = serialize_block(cols, failed.layout, **layout_kw)
        return out.tobytes()


class ErasureRecovery(RecoveryUDF):
    """Reed-Solomon stripe decode (paper Sec. VI-C2 erasure-coding based)."""

    name = "erasure"

    def detect(self, store: DataStore, failed: BlockEntry) -> List[str]:
        if not failed.stripe_id:
            return []
        members = [e for e in store.stripe_members(failed.stripe_id)
                   if e.block_id != failed.block_id and store.verify_block(e.block_id)]
        k = int(failed.meta.get("stripe_k", 0)) or max(
            (e.stripe_pos for e in members if not e.is_parity), default=-1) + 1
        # a partial stripe's trailing data rows are virtual zero blocks — they
        # count as (implicitly intact) survivors
        stored = {e.stripe_pos for e in store.stripe_members(failed.stripe_id)}
        virtual = [p for p in range(k) if p not in stored]
        if len(members) + len(virtual) < k:
            raise RecoveryError(
                f"stripe {failed.stripe_id}: only {len(members)} survivors, need {k}")
        return [e.block_id for e in members[:k]]

    def recover(self, store: DataStore, failed: BlockEntry,
                recovery_ids: List[str]) -> bytes:
        k = int(failed.meta.get("stripe_k"))
        m = int(failed.meta.get("stripe_m"))
        rs = ReedSolomon(k, m)
        L = None
        shards: Dict[int, np.ndarray] = {}
        for bid in recovery_ids:
            e = store.entries[bid]
            raw = np.frombuffer(store.read_payload(bid), dtype=np.uint8)
            if L is None:
                L = max(len(raw), 1)
                L = -(-L // 128) * 128
            row = np.zeros(L, dtype=np.uint8)
            row[: len(raw)] = raw
            shards[e.stripe_pos] = row
        if L is None:
            L = max(1, -(-failed.logical_nbytes() // 128) * 128)
        # virtual zero rows of a partial stripe (never stored, implicitly intact)
        stored = {e.stripe_pos for e in store.stripe_members(failed.stripe_id)}
        for p in range(k):
            if len(shards) >= k:
                break
            if p not in shards and p not in stored:
                shards[p] = np.zeros(L, dtype=np.uint8)
        out = rs.recover_block(failed.stripe_pos, shards)
        return out.tobytes()[: failed.logical_nbytes()]


@dataclass
class RecoveryReport:
    recovered: List[Tuple[str, str]] = field(default_factory=list)  # (block, udf)
    unrecoverable: List[str] = field(default_factory=list)
    per_block_seconds: Dict[str, float] = field(default_factory=dict)
    # set by stop() when the poller outlived its join timeout (a recovery
    # UDF still running); the daemon thread is daemonic so the process can
    # exit, but callers must see the overrun rather than assume quiescence
    stop_overrun: bool = False


class FaultToleranceDaemon:
    """Polls the store for failing blocks and applies recovery UDFs.

    The catalog maps ingestion plans to their UDF chain (paper: "INGESTBASE
    maintains a catalog of detect and recover UDFs for each ingestion plan");
    ``udfs`` here is that chain, tried in order per failed block.
    """

    def __init__(self, store: DataStore,
                 udfs: Optional[Sequence[RecoveryUDF]] = None,
                 poll_interval_s: float = 0.05) -> None:
        self.store = store
        self.udfs = list(udfs) if udfs is not None else [
            ReplicationRecovery(), TransformationRecovery(), ErasureRecovery()]
        self.poll_interval_s = poll_interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.report = RecoveryReport()

    # -------------------------------------------------------------- one sweep
    def sweep(self) -> RecoveryReport:
        for bid in self.store.failed_blocks():
            # a stop request aborts the sweep between blocks: without this,
            # stop() could wait out its whole join timeout behind a long
            # recovery backlog and leak the poller thread mid-recovery
            if self._stop.is_set() and self._thread is not None:
                break
            entry = self.store.entries[bid]
            t0 = time.time()
            for udf in self.udfs:
                try:
                    rec_ids = udf.detect(self.store, entry)
                except RecoveryError:
                    continue
                if not rec_ids:
                    continue
                try:
                    payload = udf.recover(self.store, entry, rec_ids)
                except RecoveryError:
                    continue
                # place the rebuilt block; if its node died (runtime liveness
                # mark, e.g. a dead worker process, or its storage is gone),
                # move it to a node that is both live and present
                node = entry.node
                runtime_live = set(self.store.live_nodes())
                if (node not in runtime_live
                        or not os.path.isdir(self.store.node_dir(node))):
                    present = [n for n in self.store.nodes
                               if os.path.isdir(self.store.node_dir(n))]
                    live = [n for n in present if n in runtime_live] or present
                    node = live[0] if live else node
                self.store.restore_file(entry, payload, node=node)
                self.report.recovered.append((bid, udf.name))
                self.report.per_block_seconds[bid] = time.time() - t0
                break
            else:
                self.report.unrecoverable.append(bid)
        self.store.flush_manifest()
        return self.report

    # ------------------------------------------------------------- background
    def start(self) -> None:
        def loop() -> None:
            while not self._stop.is_set():
                self.sweep()
                self._stop.wait(self.poll_interval_s)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self, timeout_s: float = 5.0) -> bool:
        """Stop the poller and join it.  Returns True when the thread exited
        within ``timeout_s``; on timeout the overrun is recorded in
        ``report.stop_overrun`` (never swallowed — the thread is mid-recovery
        and will exit at its next between-block stop check)."""
        self._stop.set()
        t = self._thread
        if t is None:
            return True
        t.join(timeout=timeout_s)
        if t.is_alive():
            self.report.stop_overrun = True
            return False
        self._thread = None
        return True
