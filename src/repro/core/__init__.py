"""IngestBase core: the paper's contribution as a composable library.

Typical flow::

    plan  = IngestPlan("logs")
    s1    = select(plan, parser="parser", replicate=3)
    s2    = format_(plan, s1, chunk={"target_rows": 4096}, serialize="columnar")
    s3    = store(plan, s2, locate="roundrobin", upload=data_store)
    create_stage(plan, using=[s1, s2, s3])
    report = ingest(plan, items, data_store)
    cols   = DataAccess(data_store).filter_replica("serialize", "columnar") \
                 .read_all(projection=["tokens"])
"""
from .access import DataAccess, Split
from .catalog import Catalog
from .chaos import (ChaosController, ChaosEvent, ChaosPlan, SoakResult,
                    chaos_soak)
from .exchange import (PartitionExchange, decode_partition, encode_partition,
                       fetch_stream_partition, partition_items,
                       resident_file_name, stable_group_hash)
from .fault import (ErasureRecovery, FaultToleranceDaemon, RecoveryUDF,
                    ReplicationRecovery, TransformationRecovery)
from .items import (Granularity, IngestItem, Label, ShmLease,
                    as_device_array, as_device_columns, decode_items,
                    encode_items)
from .language import (FeedSpec, LanguageSession, chain_stage, create_stage,
                       format_, parse_feed_script, parse_ingestion_script,
                       select, store, unparse_source, unparse_stream,
                       with_epochs, with_source)
from .liveness import LivenessMonitor, retry_call
from .operators import (BatchFallback, IngestOp, MaterializeOp,
                        OperatorFailure, OpMode, PassThroughOp, register_op,
                        registered_ops, resolve_callable, resolve_op,
                        run_ops_batched)
from .optimizer import (FilterFusionRule, IngestionOptimizer, IngestOpExpr,
                        ParallelModeRule, PipelineRule, ReorderRule, Rule,
                        VectorizeRule, split_pipeline_segments)
from .plan import (IngestPlan, Stage, StagePlan, Statement, annotate_edges,
                   cone_replay_capable, segment_split, serialize_plans,
                   stage_consumers)
from .procexec import ProcessNodeExecutor, WorkerDeath
from .runtime import (ExchangeRound, FaultInjection, NodeExecutor,
                      NodeFailure, RunReport, RuntimeEngine,
                      ShuffleCoordinator, ShuffleService, derive_spill_bytes,
                      ingest)
from .sources import (SOURCE_KINDS, DirectoryTailSource, FileRangeSource,
                      GeneratorSpecSource, ShardDescriptor, SocketLineSource,
                      SourceAdapter, build_source, parse_numeric_lines,
                      register_source, write_numeric_file)
from .store import BlockEntry, DataStore, EpochEntry
from .transport import (ChaosProxy, FramedConnection, FrameError,
                        FrameListener, PartitionStreamServer, SendTimeout,
                        connect_framed, fetch_stream_bytes)
from .streaming import (EpochPolicy, EpochReport, FeedDistributor,
                        IngestQueues, StreamFaultInjection,
                        StreamingRuntimeEngine, StreamReport, stream_ingest,
                        stream_ingest_multi)

# operator implementations register themselves on import
from . import ops_select as _ops_select  # noqa: F401
from . import ops_format as _ops_format  # noqa: F401
from . import ops_store as _ops_store    # noqa: F401

__all__ = [
    "DataAccess", "Split", "Catalog",
    "ChaosController", "ChaosEvent", "ChaosPlan", "SoakResult", "chaos_soak",
    "LivenessMonitor", "retry_call",
    "ErasureRecovery", "FaultToleranceDaemon", "RecoveryUDF",
    "ReplicationRecovery", "TransformationRecovery",
    "Granularity", "IngestItem", "Label", "ShmLease", "as_device_array",
    "as_device_columns", "decode_items", "encode_items",
    "FeedSpec", "LanguageSession", "chain_stage", "create_stage", "format_",
    "parse_feed_script", "parse_ingestion_script", "select", "store",
    "unparse_source", "unparse_stream", "with_epochs", "with_source",
    "BatchFallback", "IngestOp", "MaterializeOp", "OperatorFailure", "OpMode",
    "PassThroughOp", "register_op", "registered_ops", "resolve_callable",
    "resolve_op", "run_ops_batched",
    "FilterFusionRule", "IngestionOptimizer", "IngestOpExpr", "ParallelModeRule",
    "PipelineRule", "ReorderRule", "Rule", "VectorizeRule",
    "split_pipeline_segments",
    "IngestPlan", "Stage", "StagePlan", "Statement", "annotate_edges",
    "cone_replay_capable", "segment_split", "serialize_plans",
    "stage_consumers",
    "PartitionExchange", "decode_partition", "encode_partition",
    "fetch_stream_partition", "partition_items", "resident_file_name",
    "stable_group_hash",
    "ProcessNodeExecutor", "WorkerDeath",
    "ExchangeRound", "FaultInjection", "NodeExecutor", "NodeFailure",
    "RunReport", "RuntimeEngine", "ShuffleCoordinator", "ShuffleService",
    "derive_spill_bytes", "ingest",
    "SOURCE_KINDS", "DirectoryTailSource", "FileRangeSource",
    "GeneratorSpecSource", "ShardDescriptor", "SocketLineSource",
    "SourceAdapter", "build_source", "parse_numeric_lines", "register_source",
    "write_numeric_file",
    "BlockEntry", "DataStore", "EpochEntry",
    "ChaosProxy", "FramedConnection", "FrameError", "FrameListener",
    "PartitionStreamServer", "SendTimeout", "connect_framed",
    "fetch_stream_bytes",
    "EpochPolicy", "EpochReport", "FeedDistributor", "IngestQueues",
    "StreamFaultInjection", "StreamingRuntimeEngine", "StreamReport",
    "stream_ingest", "stream_ingest_multi",
]
