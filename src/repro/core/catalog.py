"""Plan + UDF catalog (paper Sec. VII): serialized ingestion plans (operator
params, not instances) and the per-plan recovery-UDF registry, persisted next
to the store so ingestion-aware access can re-instantiate what it needs."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Sequence

from .fault import ErasureRecovery, RecoveryUDF, ReplicationRecovery, TransformationRecovery
from .plan import IngestPlan
from .store import DataStore

_UDFS = {
    "replication": ReplicationRecovery,
    "transformation": TransformationRecovery,
    "erasure": ErasureRecovery,
}


class Catalog:
    def __init__(self, store: DataStore) -> None:
        self.store = store
        self.path = os.path.join(store.root, "catalog.json")
        self.data: Dict[str, Any] = {"plans": {}, "udfs": {}}
        if os.path.exists(self.path):
            with open(self.path) as f:
                self.data = json.load(f)

    def register_plan(self, plan: IngestPlan,
                      recovery_udfs: Sequence[str] = ("replication",
                                                      "transformation",
                                                      "erasure")) -> None:
        self.data["plans"][plan.name] = plan.signature()
        self.data["udfs"][plan.name] = list(recovery_udfs)
        self.flush()

    def recovery_chain(self, plan_name: str) -> List[RecoveryUDF]:
        names = self.data["udfs"].get(
            plan_name, ["replication", "transformation", "erasure"])
        return [_UDFS[n]() for n in names if n in _UDFS]

    def plan_signature(self, plan_name: str) -> Optional[Dict[str, Any]]:
        return self.data["plans"].get(plan_name)

    def flush(self) -> None:
        with open(self.path, "w") as f:
            json.dump(self.data, f, indent=1)
