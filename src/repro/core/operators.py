"""Ingestion operator base: the paper's iterator model (Sec. III).

    IngestOp: LID -> LID'   with API
      initialize / setInput / hasNext / next / finalize

Operators are *vectorized* internally (DESIGN.md §2) — ``next()`` yields whole
labelled items (usually CHUNK/BLOCK granularity) — but the control-plane
contract is exactly the paper's iterator API so the runtime, optimizer, and
fault-tolerance machinery reason about operators uniformly.

Each operator also carries:
  * ``name``        — the label key it writes (``l_<name>`` in the language),
  * ``mode``        — SERIAL or PARALLEL (paper Sec. VI-A intra-node parallelism),
  * ``granularity_in/out`` — used by the pipelining rule (materialize only at
    granularity changes, paper Sec. V) and by plan validation (Sec. IV-A:
    consecutive operators must match in granularity/schema),
  * ``expansion``   — data-volume factor estimate used by the reordering rule
    (push-down reducers / push-up expanders, paper Sec. V).
"""
from __future__ import annotations

import enum
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import (Any, Deque, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

from .items import Granularity, IngestItem


class OpMode(enum.Enum):
    SERIAL = "serial"
    PARALLEL = "parallel"


class OperatorFailure(RuntimeError):
    """Raised by an operator when processing fails (drives in-flight FT)."""


class BatchFallback(RuntimeError):
    """Raised by ``process_batch`` when a batch cannot run vectorized (e.g. a
    payload type the kernel path does not cover).  The caller falls back to
    the scalar iterator path for that operator — the batch tier degrades, it
    never fails (ISSUE 7)."""


class IngestOp:
    """Base ingestion operator implementing the paper's iterator API."""

    #: label key; subclasses override (e.g. "filter", "serialize")
    name: str = "op"
    #: granularity contract; None = any / unchanged
    granularity_in: Optional[Granularity] = None
    granularity_out: Optional[Granularity] = None
    #: estimated output/input volume ratio (<1 reducer, >1 expander)
    expansion: float = 1.0
    #: CPU-heavy operators default to parallel mode (paper Sec. VI-A)
    cpu_heavy: bool = False
    #: operators that publish into the DataStore; stages containing one form
    #: the commit-side segment the epoch pipeliner may overlap (DESIGN.md §4)
    commit_side: bool = False
    #: operators with a vectorized ``process_batch`` the VectorizeRule may
    #: select into a batch-mode pipeline block (ISSUE 7); the scalar iterator
    #: path stays as the fallback and correctness oracle
    batch_capable: bool = False

    def __init__(self, **params: Any) -> None:
        self.params: Dict[str, Any] = params
        self.mode: OpMode = OpMode.PARALLEL if self.cpu_heavy else OpMode.SERIAL
        # num_threads stays IN params: clone() and the process-backend
        # __reduce__ rebuild from params, so popping it here silently reset
        # cloned/shipped operators to the default pool width
        self.num_threads: int = int(params.get("num_threads", 4))
        self._inputs: List[IngestItem] = []
        self._outputs: Iterator[IngestItem] = iter(())
        self._pending: Deque[IngestItem] = deque()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._initialized = False
        self._finalized_ok = False  # runtime FT tracks finalize success (Sec. VI-C)
        # test hook: fail the next N process() calls (fault injection)
        self._fail_next: int = 0
        # milliseconds spent inside vectorized kernels (batch tier); the
        # runtime diffs this around a batch block to charge RunReport.kernel_ms
        self.kernel_ms_total: float = 0.0

    # ------------------------------------------------------------ iterator API
    def initialize(self) -> None:
        """Initialize the operator for the first time."""
        self._initialized = True
        self._finalized_ok = False

    def set_input(self, items: Sequence[IngestItem]) -> None:
        """Assign the set of input ingest data items."""
        if not self._initialized:
            self.initialize()
        self._inputs = list(items)
        self._outputs = self._make_output_iter()

    # paper naming
    setInput = set_input

    def has_next(self) -> bool:
        if self._pending:
            return True
        try:
            self._pending.append(next(self._outputs))
            return True
        except StopIteration:
            return False

    hasNext = has_next

    def next(self) -> IngestItem:
        if not self.has_next():
            raise StopIteration
        return self._pending.popleft()

    def finalize(self) -> None:
        """Cleanup; parallel-mode threads are joined here (paper Sec. VI-A)."""
        self._inputs = []
        self._pending = deque()
        self._outputs = iter(())
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        self._finalized_ok = True

    # --------------------------------------------------------------- execution
    def _make_output_iter(self) -> Iterator[IngestItem]:
        if self.mode is OpMode.PARALLEL and len(self._inputs) > 1:
            return self._parallel_iter()
        return self._serial_iter()

    def _serial_iter(self) -> Iterator[IngestItem]:
        for item in self._inputs:
            yield from self._process_guarded(item)

    def _ensure_pool(self) -> ThreadPoolExecutor:
        """Lazily-created worker pool, reused across ``set_input`` calls and
        joined in ``finalize()`` — one pool per run instead of one per batch
        (pool churn on every epoch x stage x node)."""
        if self._pool is None:
            self._pool = ThreadPoolExecutor(max_workers=self.num_threads)
        return self._pool

    def _parallel_iter(self) -> Iterator[IngestItem]:
        """Thread-pool processing of independent items; order preserved."""
        pool = self._ensure_pool()
        futures = [pool.submit(lambda it=item: list(self._process_guarded(it)))
                   for item in self._inputs]
        for fut in futures:
            yield from fut.result()

    def _process_guarded(self, item: IngestItem) -> Iterable[IngestItem]:
        if self._fail_next > 0:
            self._fail_next -= 1
            raise OperatorFailure(f"{self.name}: injected failure")
        return self.process(item)

    # ----------------------------------------------------------- to implement
    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        """Transform one labelled ingest data item into zero or more outputs."""
        raise NotImplementedError

    # ------------------------------------------------------- batch tier (ISSUE 7)
    def process_batch(self, items: Sequence[IngestItem]) -> List[IngestItem]:
        """Transform a whole batch at once.  ``batch_capable`` operators
        override this with a vectorized implementation (and may raise
        ``BatchFallback`` for inputs the vectorized path does not cover);
        the default is the scalar loop, so a dummy substituted into a
        batch-mode block still runs correctly."""
        out: List[IngestItem] = []
        for item in items:
            out.extend(self.process(item))
        return out

    def run_batch(self, items: Sequence[IngestItem]) -> List[IngestItem]:
        """Batch-mode twin of ``run``: one ``process_batch`` call instead of
        the per-item iterator drain.  Same lifecycle (initialize/finalize,
        ``_fail_next`` fault hook) so the runtime's retry-from-checkpoint and
        dummy-substitution machinery treat both paths identically."""
        self.initialize()
        if self._fail_next > 0:
            self._fail_next -= 1
            raise OperatorFailure(f"{self.name}: injected failure")
        out = list(self.process_batch(list(items)))
        self.finalize()
        return out

    # ------------------------------------------------------------------- misc
    def run(self, items: Sequence[IngestItem]) -> List[IngestItem]:
        """Convenience: drive the full iterator protocol over ``items``."""
        self.initialize()
        self.set_input(items)
        out: List[IngestItem] = []
        while self.has_next():
            out.append(self.next())
        self.finalize()
        return out

    def clone(self) -> "IngestOp":
        """Fresh instance with the same parameters (operators are re-instantiable
        from their params — the catalog stores params, not instances; Sec. VII)."""
        op = type(self)(**dict(self.params))
        op.mode = self.mode
        return op

    def __reduce__(self):
        """Operators pickle as (type, params, mode) — exactly the catalog
        contract — so shipping a plan to a worker process re-instantiates
        fresh operator state there (the process backend's launch_remote).
        Closure-valued params (a lambda predicate) fail here by design:
        ``assert_picklable_plan`` turns that into an actionable error."""
        return (_rebuild_op, (type(self), dict(self.params), self.mode))

    def signature(self) -> Dict[str, Any]:
        return {"type": type(self).__name__, "name": self.name,
                "params": {k: repr(v) for k, v in self.params.items()},
                "mode": self.mode.value}

    def __repr__(self) -> str:
        ps = ", ".join(f"{k}={v!r}" for k, v in self.params.items())
        return f"{type(self).__name__}({ps})"


def _rebuild_op(cls: type, params: Dict[str, Any], mode: OpMode) -> "IngestOp":
    op = cls(**params)
    op.mode = mode
    return op


def resolve_callable(spec: Any) -> Any:
    """Resolve a picklable callable spec.

    Accepts a callable (returned unchanged — fine for thread backends, only
    picklable if it is a module-level function) or an import spec string
    ``"package.module:attr"`` resolved at call time.  Spec strings are what
    make FilterOp / MapOp / ParserOp params cross process boundaries.
    """
    if isinstance(spec, str):
        mod, _, attr = spec.partition(":")
        if not attr:
            raise ValueError(
                f"callable spec {spec!r} must look like 'pkg.module:attr'")
        import importlib
        obj = importlib.import_module(mod)
        for part in attr.split("."):
            obj = getattr(obj, part)
        if not callable(obj):
            raise TypeError(f"callable spec {spec!r} resolved to non-callable {obj!r}")
        return obj
    return spec


class PassThroughOp(IngestOp):
    """The paper's *dummy pass-through operator* (Sec. VI-C): substituted for an
    operator that failed repeatedly; labels every item with -1 to mark the failure."""

    name = "dummy"

    def __init__(self, replaces: str = "op", **kw: Any) -> None:
        super().__init__(replaces=replaces, **kw)
        self.replaces = replaces

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        yield item.with_label(self.replaces, -1)


class MaterializeOp(IngestOp):
    """Materialization barrier inserted between operators (paper Sec. V).

    By default every operator boundary materializes; the pipelining rule removes
    barriers between same-granularity operators.  Each surviving barrier is also
    an in-flight checkpoint (Sec. VI-C1): the runtime snapshots items here.
    """

    name = "materialize"

    def __init__(self, **kw: Any) -> None:
        super().__init__(**kw)
        self.buffer: List[IngestItem] = []

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        self.buffer.append(item)
        yield item


def run_ops_batched(ops: Sequence[IngestOp], items: Sequence[IngestItem]
                    ) -> Tuple[List[IngestItem], Dict[str, Any]]:
    """Execute one batch-mode pipeline block (ISSUE 7).

    Shared by the thread backend (``RuntimeEngine._run_stage``) and the
    process backend's worker (``procexec._run_stage_ops``).  Each op runs
    ``run_batch``; a ``BatchFallback`` drops that op back to the scalar
    iterator path (counted — the block as a whole still succeeds).
    ``OperatorFailure`` propagates so both backends' retry-from-checkpoint
    machinery applies unchanged.

    Returns ``(out, stats)`` with ``vectorized_rows`` (rows entering the
    block), ``batch_fallbacks`` and ``kernel_ms`` (vectorized-kernel time the
    block's ops accumulated).
    """
    rows = sum(it.nrows() for it in items)
    kernel_before = sum(op.kernel_ms_total for op in ops)
    fallbacks = 0
    out: List[IngestItem] = list(items)
    for op in ops:
        try:
            out = op.run_batch(out)
        except BatchFallback:
            fallbacks += 1
            out = op.run(out)
    return out, {"vectorized_rows": rows, "batch_fallbacks": fallbacks,
                 "kernel_ms": sum(op.kernel_ms_total for op in ops)
                 - kernel_before}


# ----------------------------------------------------------------------------
# Operator registry: the language front-end resolves names (e.g. SERIALIZE AS
# "columnar") through this registry; users register custom operators the same
# way (paper Sec. IV-A: parser/filter/projection/replicator may be custom ops).
# ----------------------------------------------------------------------------
_REGISTRY: Dict[str, type] = {}


def register_op(key: str):
    def deco(cls: type) -> type:
        _REGISTRY[key] = cls
        return cls
    return deco


def resolve_op(__op_key: str, **params: Any) -> IngestOp:
    if __op_key not in _REGISTRY:
        raise KeyError(f"unknown ingestion operator {__op_key!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[__op_key](**params)


def registered_ops() -> List[str]:
    return sorted(_REGISTRY)
