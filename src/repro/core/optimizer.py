"""The rule-based ingestion optimizer (paper Sec. V).

Rules operate on *ingestion operator expressions* via ``check``/``apply`` and
are fired over a preorder traversal of each stage's chain (larger subtrees
first), iterating the ordered rule set to a fixpoint.

Built-in rules (paper Sec. V + Sec. VI-A):
  ReorderRule        — push data-reducing operators down, data-expanding up
  FilterFusionRule   — fuse adjacent filters (AND of predicates)
  PipelineRule       — merge materialization barriers between same-granularity
                       operators into pipelined blocks
  CheckpointRule     — force extra materialization every N operators (user-
                       controllable recovery-time knob, Sec. VI-C1)
  ParallelModeRule   — flip CPU-heavy operators to parallel mode
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .items import Granularity
from .operators import IngestOp, OpMode
from .ops_select import FilterOp, ProjectOp, ReplicateOp
from .plan import StagePlan, annotate_edges


@dataclass
class IngestOpExpr:
    """Root operator + descendant chain (recursively), per the paper's Sec. V."""

    op: IngestOp
    child: Optional["IngestOpExpr"] = None

    @classmethod
    def from_chain(cls, ops: Sequence[IngestOp]) -> Optional["IngestOpExpr"]:
        expr: Optional[IngestOpExpr] = None
        for op in ops:  # first op is the deepest descendant
            expr = cls(op, expr) if expr is None else cls(op, expr)
        # build so that root = last op, child chain = earlier ops
        expr = None
        for op in ops:
            expr = cls(op, expr)
        return expr

    def to_chain(self) -> List[IngestOp]:
        ops: List[IngestOp] = []
        node: Optional[IngestOpExpr] = self
        while node is not None:
            ops.append(node.op)
            node = node.child
        return list(reversed(ops))

    def preorder(self) -> List["IngestOpExpr"]:
        """Root-first traversal (largest subtree first, per the paper)."""
        out: List[IngestOpExpr] = []
        node: Optional[IngestOpExpr] = self
        while node is not None:
            out.append(node)
            node = node.child
        return out


class Rule:
    """check: IngestOpExpr -> bool ;  apply: IngestOpExpr -> IngestOpExpr'."""

    name = "rule"

    def check(self, expr: IngestOpExpr) -> bool:
        raise NotImplementedError

    def apply(self, expr: IngestOpExpr) -> IngestOpExpr:
        raise NotImplementedError


# ------------------------------------------------------------------- reordering
def _commutes(earlier: IngestOp, later: IngestOp) -> bool:
    """Is it legal to swap ``later`` in front of ``earlier``?

    Conservative legality: both CHUNK->CHUNK, and the op moving earlier must
    not read fields the other one creates/destroys.  A filter may move before
    a projection only if the projection keeps every field the filter reads.
    """
    chunky = (Granularity.CHUNK, None)
    if earlier.granularity_in not in chunky or earlier.granularity_out not in chunky:
        return False
    if later.granularity_in not in chunky or later.granularity_out not in chunky:
        return False
    if isinstance(earlier, ProjectOp) and isinstance(later, FilterOp):
        return set(later.fields) <= set(earlier.fields) and bool(later.fields)
    if isinstance(earlier, ReplicateOp):
        return True  # anything may move before a replicate (dedups work)
    if isinstance(later, ReplicateOp):
        return False  # never move replicate earlier
    if isinstance(earlier, FilterOp) and isinstance(later, FilterOp):
        return True  # filters commute
    return False


class ReorderRule(Rule):
    """Adjacent-pair swap: if the later op reduces volume more than the earlier
    one (expansion ratio), and the swap is legal, move it earlier.  Iterated to
    fixpoint this bubbles reducers down and expanders (replicate) up — the
    paper's replicate-as-late-as-possible instance falls out of the expansion
    ordering."""

    name = "reorder"

    def check(self, expr: IngestOpExpr) -> bool:
        if expr.child is None:
            return False
        earlier, later = expr.child.op, expr.op
        return _commutes(earlier, later) and later.expansion < earlier.expansion

    def apply(self, expr: IngestOpExpr) -> IngestOpExpr:
        child = expr.child
        assert child is not None
        return IngestOpExpr(child.op, IngestOpExpr(expr.op, child.child))


class FilterFusionRule(Rule):
    """filter(p2) after filter(p1)  ->  filter(p1 AND p2): one pass, one label."""

    name = "filter_fusion"

    def check(self, expr: IngestOpExpr) -> bool:
        return (expr.child is not None and isinstance(expr.op, FilterOp)
                and isinstance(expr.child.op, FilterOp))

    def apply(self, expr: IngestOpExpr) -> IngestOpExpr:
        f2, f1 = expr.op, expr.child.op
        p1, p2 = f1.predicate, f2.predicate
        fused = FilterOp(
            predicate=lambda cols, _p1=p1, _p2=p2: np.logical_and(
                np.asarray(_p1(cols), bool), np.asarray(_p2(cols), bool)),
            fields=tuple(set(f1.fields) | set(f2.fields)),
            selectivity=f1.expansion * f2.expansion,
        )
        return IngestOpExpr(fused, expr.child.child)


class ParallelModeRule(Rule):
    """Turn on parallel mode for CPU-heavy operators (paper Sec. VI-A).  Users
    add custom instances of this rule to control serial/parallel per operator."""

    name = "parallel_mode"

    def __init__(self, predicate: Optional[Callable[[IngestOp], bool]] = None,
                 mode: OpMode = OpMode.PARALLEL) -> None:
        self.predicate = predicate or (lambda op: op.cpu_heavy)
        self.mode = mode

    def check(self, expr: IngestOpExpr) -> bool:
        return self.predicate(expr.op) and expr.op.mode is not self.mode

    def apply(self, expr: IngestOpExpr) -> IngestOpExpr:
        expr.op.mode = self.mode
        return expr


# ---------------------------------------------------------------- pipelining
def compute_pipeline_blocks(ops: Sequence[IngestOp],
                            force_every: Optional[int] = None) -> List[List[int]]:
    """Merge consecutive operators into pipelined blocks; materialize only when
    item granularity changes (detected from the operators' declared types —
    the paper detects it from the data types).  ``force_every`` caps block
    length to trade throughput for recovery time (Sec. V / VI-C1)."""
    blocks: List[List[int]] = []
    cur: List[int] = []
    cur_gran: Optional[Granularity] = None
    for i, op in enumerate(ops):
        gin = op.granularity_in
        gout = op.granularity_out
        changes = gin is not None and gout is not None and gin != gout
        if cur and ((gin is not None and cur_gran is not None and gin != cur_gran)):
            blocks.append(cur)
            cur = []
        cur.append(i)
        if gout is not None:
            cur_gran = gout
        if changes or (force_every and len(cur) >= force_every):
            blocks.append(cur)
            cur = []
    if cur:
        blocks.append(cur)
    return blocks


@dataclass
class PipelineRule:
    """Not an expression rule: rewrites a StagePlan's materialization layout."""

    force_every: Optional[int] = None
    name: str = "pipeline"

    def rewrite(self, sp: StagePlan) -> StagePlan:
        sp.pipeline_blocks = compute_pipeline_blocks(sp.ops, self.force_every)
        return sp


@dataclass
class VectorizeRule:
    """Not an expression rule: selects batch-mode pipeline blocks (ISSUE 7).

    A pipeline block is rewritten to batch mode only when *every* operator in
    it is ``batch_capable`` — one non-capable op keeps the whole block on the
    scalar iterator path, so existing plans are untouched.  The runtime still
    falls back per-op at execution time on ``BatchFallback`` (and a dummy
    substituted into a batch block runs through the default scalar-loop
    ``process_batch``), so batch selection can never change results — the
    scalar path remains the correctness oracle.

    ``columnar`` (ISSUE 10) extends the rule across *stage edges*: an edge
    whose producer ends and whose consumer starts in a batch-mode block is
    annotated columnar-capable (``StagePlan.columnar_edges``), so the batch
    crosses it as a ColumnarBatch with no per-item pickling.  Disabling it
    (or the rule) keeps every edge on the scalar item-at-a-time path.
    """

    enabled: bool = True
    columnar: bool = True
    name: str = "vectorize"

    def rewrite(self, sp: StagePlan) -> StagePlan:
        blocks = sp.pipeline_blocks or [[i] for i in range(len(sp.ops))]
        sp.batch_blocks = [
            bool(self.enabled and blk
                 and all(getattr(sp.ops[i], "batch_capable", False)
                         for i in blk))
            for blk in blocks]
        return sp


def split_pipeline_segments(stage_plans: Sequence[StagePlan]) -> int:
    """Index of the first commit-side stage in the topologically-ordered DAG.

    Stages ``[0, split)`` form the *ingest segment* (parse / transform /
    shuffle — no DataStore writes); stages ``[split, n)`` form the *store
    segment* (upload + everything at or after it in topo order).  The
    pipelined streaming runtime overlaps epoch N+1's ingest segment with
    epoch N's store segment; this pipeline-block metadata is the single
    source of truth for what may overlap (DESIGN.md §4)."""
    for i, sp in enumerate(stage_plans):
        if sp.commit_side or sp.compute_commit_side():
            return i
    return len(stage_plans)


# ------------------------------------------------------------------- optimizer
class IngestionOptimizer:
    """Ordered rule set; preorder traversal; fire until fixpoint (paper Sec. V)."""

    MAX_PASSES = 32

    def __init__(self, rules: Optional[Sequence[Rule]] = None,
                 pipeline: Optional[PipelineRule] = None,
                 vectorize: Optional[VectorizeRule] = None) -> None:
        self.rules: List[Rule] = list(rules) if rules is not None else [
            FilterFusionRule(), ReorderRule(), ParallelModeRule()]
        self.pipeline = pipeline or PipelineRule()
        # batch-mode selection runs after pipelining (it is per-block);
        # pass VectorizeRule(enabled=False) to force all-scalar execution
        self.vectorize = vectorize or VectorizeRule()

    def add_rule(self, rule: Rule, front: bool = False) -> None:
        """Extensibility hook (paper: "users could provide additional rules")."""
        self.rules.insert(0, rule) if front else self.rules.append(rule)

    def optimize_chain(self, ops: Sequence[IngestOp]) -> List[IngestOp]:
        expr = IngestOpExpr.from_chain(ops)
        if expr is None:
            return []
        for _ in range(self.MAX_PASSES):
            fired = False
            for rule in self.rules:           # ordered rule set
                node = expr
                prev: Optional[IngestOpExpr] = None
                while node is not None:       # preorder: root (largest subtree) first
                    if rule.check(node):
                        new = rule.apply(node)
                        if prev is None:
                            expr = new
                        else:
                            prev.child = new
                        fired = True
                        node = new
                    prev, node = node, node.child
            if not fired:
                break
        return expr.to_chain()

    def optimize(self, stage_plans: Sequence[StagePlan]) -> List[StagePlan]:
        out: List[StagePlan] = []
        for sp in stage_plans:
            ops = self.optimize_chain(sp.ops)
            nsp = StagePlan(sp.name, ops, sp.upstream, sp.predicates)
            nsp.commit_side = nsp.compute_commit_side()
            # rule rewrites may reorder/fuse ops: recompute the shuffle
            # boundary metadata so workers partition by the surviving key
            nsp.shuffle_key = nsp.compute_shuffle_key()
            out.append(self.vectorize.rewrite(self.pipeline.rewrite(nsp)))
        # rewrites may change shuffle/commit metadata: recompile the
        # per-edge routing taxonomy (narrow / shuffle / cross-segment)
        out = annotate_edges(out)
        # columnar edge eligibility (ISSUE 10): producer's LAST block and the
        # consumer's FIRST block both batch-mode -> the batch crosses the
        # edge packed, no item materialization on either side
        columnar_on = self.vectorize.enabled and getattr(
            self.vectorize, "columnar", True)
        by_name = {sp.name: sp for sp in out}
        for sp in out:
            sp.columnar_edges = {}
            if not (columnar_on and sp.batch_blocks and sp.batch_blocks[-1]):
                continue
            for consumer in sp.edge_kinds:
                cs = by_name.get(consumer)
                sp.columnar_edges[consumer] = bool(
                    cs is not None and cs.batch_blocks
                    and cs.batch_blocks[0])
        return out

    def explain(self, before: Sequence[StagePlan], after: Sequence[StagePlan]) -> str:
        lines = []
        for b, a in zip(before, after):
            lines.append(f"stage {b.name}:")
            lines.append("  before: " + " -> ".join(type(o).__name__ for o in b.ops))
            lines.append("  after : " + " -> ".join(type(o).__name__ for o in a.ops))
            lines.append(f"  pipeline blocks: {a.pipeline_blocks}")
            if any(a.batch_blocks):
                lines.append("  batch blocks : " + ", ".join(
                    str(blk) for blk, on in zip(a.pipeline_blocks,
                                                a.batch_blocks) if on))
            if a.edge_kinds:
                # the compiled routing taxonomy (DESIGN.md §4): narrow edges
                # stay node-resident, shuffle edges partition across peers,
                # cross-segment edges pin their round across slices
                lines.append("  edges : " + ", ".join(
                    f"->{c} [{k}]" for c, k in a.edge_kinds.items()))
            cols = [c for c, on in a.columnar_edges.items() if on]
            if cols:
                # edges the batch crosses as a packed ColumnarBatch
                lines.append("  columnar edges : " + ", ".join(
                    f"->{c}" for c in cols))
        return "\n".join(lines)
