"""Heartbeat liveness + bounded-retry helpers (ISSUE 8).

Death detection used to rest on a single signal: the worker pipe's EOF.
A crashed worker closes its pipe and the receiver thread fails every
pending job — but a *wedged* worker (SIGSTOP, a runaway C extension, or,
on a future multi-host fabric, a silently dropped connection) keeps the
pipe open forever and the stream stalls with it.  AsterixDB's
fault-tolerant feeds (arXiv:1405.1705) track liveness explicitly for this
reason; this module adds the same second signal:

* :class:`LivenessMonitor` — a coordinator-side thread that pings every
  watched process worker over its existing control pipe each
  ``interval_s``.  The worker's receive loop answers ``("pong", seq)``
  immediately (stage jobs run on lanes, so a busy worker still answers);
  any traffic on the pipe — pongs, job results — refreshes the worker's
  heartbeat.  A worker silent for ``miss_threshold`` consecutive
  intervals is declared dead: the monitor SIGKILLs it (SIGKILL, not
  SIGTERM — a stopped process never delivers SIGTERM) and fails its
  in-flight futures, which feeds the runtime's ordinary NodeFailure
  recovery path (lineage-cone replay where capable).
* :func:`retry_call` — bounded retry with exponential backoff and
  deterministic jitter for spawn/connect paths, so one transient fork or
  shared-memory hiccup no longer aborts a whole run on first try.

The thread backend needs no monitor: its executors share the coordinator
process, so a wedge there stalls the coordinator itself and every death
already surfaces as a stage failure.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Type


def retry_call(fn: Callable[[], Any], *,
               attempts: int = 3,
               base_delay_s: float = 0.05,
               factor: float = 2.0,
               max_delay_s: float = 1.0,
               jitter: float = 0.25,
               retry_on: Tuple[Type[BaseException], ...] = (OSError,),
               seed: Optional[int] = None,
               sleep: Callable[[float], None] = time.sleep
               ) -> Tuple[Any, int]:
    """Call ``fn`` with bounded retry + exponential backoff and jitter.

    Returns ``(result, attempts_used)``; re-raises the last exception once
    ``attempts`` are exhausted.  Only exceptions in ``retry_on`` retry —
    anything else (a programming error) propagates immediately.  The
    jitter fraction desynchronizes concurrent retriers (every node
    executor spawning at once should not re-collide on the same
    millisecond); ``seed`` pins it for deterministic tests.
    """
    if attempts < 1:
        raise ValueError("retry_call needs attempts >= 1")
    rng = random.Random(seed)
    delay = base_delay_s
    used = 0
    while True:
        used += 1
        try:
            return fn(), used
        except retry_on:
            if used >= attempts:
                raise
            pause = delay * (1.0 + jitter * rng.random())
            sleep(pause)
            delay = min(delay * factor, max_delay_s)


class LivenessMonitor:
    """Coordinator-side heartbeat monitor over the workers' control pipes.

    ``watch(node, executor)`` registers any executor exposing the process
    backend's liveness surface — ``send_ping()``, ``heartbeat_age()``,
    ``fail_unresponsive()`` and the ``alive`` property; executors without
    it (the thread backend) are skipped: their deaths surface as stage
    failures already.  The monitor thread pings each watched worker every
    ``interval_s`` and declares one dead when its heartbeat age exceeds
    ``interval_s * miss_threshold`` — the pipe may well still be open
    (SIGSTOP leaves it so), which is precisely the gap this closes.

    Declared deaths are recorded in ``deaths`` as ``(node, waited_s)``
    where ``waited_s`` is the heartbeat age at declaration — the
    acceptance bound is ``waited_s <= 2 * interval_s * miss_threshold``.
    ``on_death(node, waited_s)`` fires after the worker has been failed.

    **Per-host quorum** (ISSUE 9): ``watch(node, executor, host=...)``
    groups workers by the host they run on.  When *every* watched worker
    of one host misses its window together, the likeliest cause is not N
    simultaneous process wedges but the link to that host — a network
    partition.  The host is then declared partitioned *as a unit*: all
    its workers are failed in one pass (recorded in ``partitions`` as
    ``(host, nodes, waited_s)``, plus the usual per-node ``deaths``
    entries), so recovery sees the whole host gone before the first
    replay starts instead of rediscovering it one serial death at a
    time.  A host with surviving heartbeats keeps per-node declaration:
    one silent worker there is a worker problem, not a link problem.
    ``host=None`` (the default, and every pre-ISSUE-9 caller) opts out.
    """

    def __init__(self, interval_s: float = 0.5, miss_threshold: int = 4,
                 on_death: Optional[Callable[[str, float], None]] = None,
                 on_partition: Optional[Callable[[str, List[str], float],
                                                 None]] = None
                 ) -> None:
        if interval_s <= 0:
            raise ValueError("heartbeat interval must be positive")
        if miss_threshold < 1:
            raise ValueError("miss threshold must be >= 1")
        self.interval_s = interval_s
        self.miss_threshold = miss_threshold
        self.on_death = on_death
        self.on_partition = on_partition
        self.deaths: List[Tuple[str, float]] = []
        #: (host, member nodes, heartbeat age) per unit declaration
        self.partitions: List[Tuple[str, List[str], float]] = []
        self._watched: Dict[str, Any] = {}
        self._hosts: Dict[str, Optional[str]] = {}
        self._declared: set = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------------- control
    def watch(self, node: str, executor: Any,
              host: Optional[str] = None) -> bool:
        """Register ``executor`` for monitoring; False (and ignored) when it
        exposes no heartbeat surface.  ``host`` opts the node into the
        per-host partition quorum."""
        if not callable(getattr(executor, "send_ping", None)):
            return False
        with self._lock:
            self._watched[node] = executor
            self._hosts[node] = host
        return True

    def start(self) -> None:
        if self._thread is not None:
            return
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="liveness-monitor")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=5)

    # ------------------------------------------------------------------- loop
    def _loop(self) -> None:
        limit = self.interval_s * self.miss_threshold
        while not self._stop.is_set():
            with self._lock:
                watched = dict(self._watched)
                hosts = dict(self._hosts)
            ages: Dict[str, float] = {}
            for node, ex in watched.items():
                if node in self._declared or not getattr(ex, "alive", False):
                    continue
                ages[node] = ex.heartbeat_age()
            # ---- host quorum first: a host whose every live worker missed
            # together dies as a unit, before any per-node bookkeeping
            by_host: Dict[str, List[str]] = {}
            for node in ages:
                h = hosts.get(node)
                if h is not None:
                    by_host.setdefault(h, []).append(node)
            unit_declared: set = set()
            for h, members in sorted(by_host.items()):
                if not all(ages[m] > limit for m in members):
                    continue
                members = sorted(members)
                waited = max(ages[m] for m in members)
                for m in members:
                    self._declare(m, watched[m], ages[m])
                unit_declared.update(members)
                self.partitions.append((h, members, waited))
                if self.on_partition is not None:
                    self.on_partition(h, members, waited)
            # ---- per-node path: hosts with surviving heartbeats, and every
            # node watched without host information
            for node, age in ages.items():
                if node in unit_declared:
                    continue
                if age > limit:
                    h = hosts.get(node)
                    if h is not None:
                        peers = [m for m in by_host.get(h, ())
                                 if m != node]
                        # beat-skew grace: last beats land a tick apart,
                        # so one member can cross the limit first.  Every
                        # peer within one interval of missing points at
                        # the link, not this worker — hold one tick and
                        # let the quorum declare the host as a unit.  A
                        # silent peer's age only grows, so this converges
                        # next tick either way.
                        if peers and all(ages[m] > limit - self.interval_s
                                         for m in peers):
                            watched[node].send_ping()
                            continue
                    self._declare(node, watched[node], age)
                else:
                    watched[node].send_ping()
            self._stop.wait(self.interval_s)

    def _declare(self, node: str, ex: Any, waited_s: float) -> None:
        self._declared.add(node)
        ex.fail_unresponsive()
        self.deaths.append((node, waited_s))
        if self.on_death is not None:
            self.on_death(node, waited_s)
