"""Streaming micro-batch ingestion runtime.

The batch ``RuntimeEngine`` takes a finite source list and runs every stage
behind a full barrier; this module makes the same optimized stage DAG consume
an *unbounded* source, in the shape of AsterixDB-style long-running feeds
(arXiv:1405.1705) with enrichment pipelines layered on top (arXiv:1902.08271):

* **Bounded ingest queues + backpressure** — a feeder thread routes source
  items round-robin into per-node ``queue.Queue(maxsize=...)``; when a node's
  queue is full the producer *blocks*, so queue memory is bounded no matter
  how fast data arrives.
* **Epochs (micro-batches)** — the stream is cut into epochs by item count
  and/or wall-clock tick; each epoch runs through the existing optimized
  ``StagePlan`` pipeline (operator chains, pipeline blocks, shuffle, retry /
  dummy-substitution fault machinery are all reused via
  ``RuntimeEngine._execute``).
* **Epoch-granular fault tolerance** — a node death mid-epoch aborts the
  staged epoch (its partially-written blocks are rolled back) and replays the
  whole epoch on the surviving nodes.  Committed epochs are never redone:
  ``DataStore.begin_epoch`` refuses an already-committed epoch id.
* **Exactly-once commits** — ``DataStore.commit_epoch`` publishes an epoch's
  blocks atomically (manifest temp-write + rename); ``DataAccess.since_epoch``
  lets queries consume exactly the committed epochs while ingestion continues.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, Iterator, List, Optional, Sequence,
                    Tuple, Union)

from .items import IngestItem
from .optimizer import IngestionOptimizer
from .plan import IngestPlan, StagePlan
from .runtime import FaultInjection, NodeFailure, RunReport, RuntimeEngine
from .store import DataStore


@dataclass
class StreamFaultInjection:
    """Deterministic streaming fault hooks (tests/benchmarks).

    ``op_failures`` uses the batch engine's (stage, op_index) -> count format
    and is shared across epochs; ``node_death_in_epoch`` kills a node while
    the given epoch index is mid-flight (after its first stage, before
    commit) — exercising abort + replay.
    """

    op_failures: Dict[Tuple[str, int], int] = field(default_factory=dict)
    node_death_in_epoch: Dict[str, int] = field(default_factory=dict)


@dataclass
class EpochReport:
    """What the engine observed for one committed epoch."""

    epoch: int
    items_in: int                 # source items consumed by the epoch
    n_blocks: int                 # blocks the commit published
    attempts: int                 # 1 = clean; >1 = replayed after node death
    commit_latency_s: float       # epoch cut -> manifest rename landed
    run: RunReport = field(default_factory=RunReport)


@dataclass
class StreamReport:
    """Aggregate of a ``run_stream`` call."""

    epochs: List[EpochReport] = field(default_factory=list)
    node_failures: List[str] = field(default_factory=list)
    replayed_epochs: List[int] = field(default_factory=list)
    total_items: int = 0
    wall_time_s: float = 0.0

    def committed_epoch_ids(self) -> List[int]:
        return [e.epoch for e in self.epochs]

    def commit_latencies(self) -> List[float]:
        return [e.commit_latency_s for e in self.epochs]

    def items_per_sec(self) -> float:
        return self.total_items / self.wall_time_s if self.wall_time_s else 0.0


class IngestQueues:
    """Per-node bounded ingest queues fed from an unbounded source.

    The feeder thread pulls from the source iterator and round-robins items
    across node queues with *blocking* puts — the backpressure seam: a slow
    pipeline stalls the producer instead of growing memory.  ``mark_dead``
    removes a node from the routing set; items already queued on a dead node
    are still drained (and re-routed to live nodes by the epoch cutter).
    """

    def __init__(self, source: Iterable[IngestItem], nodes: Sequence[str],
                 capacity: int = 64) -> None:
        self.nodes = list(nodes)
        self.capacity = capacity
        self.queues: Dict[str, "queue.Queue[IngestItem]"] = {
            n: queue.Queue(maxsize=capacity) for n in self.nodes}
        self._live = {n: True for n in self.nodes}
        self._source = iter(source)
        self._stop = threading.Event()
        self.exhausted = threading.Event()
        self.produced = 0   # items the feeder has pulled from the source
        self._thread = threading.Thread(target=self._feed, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------ feeder
    def _next_live(self, rr: Iterator[str]) -> Optional[str]:
        """Next live node in round-robin order; None when none remain (or the
        queues were stopped) — never spins on an all-dead cycle."""
        for _ in range(len(self.nodes)):
            n = next(rr)
            if self._live.get(n):
                return n
        return None

    def _feed(self) -> None:
        rr = itertools.cycle(self.nodes)
        for item in self._source:
            self.produced += 1
            target = self._next_live(rr)
            while target is not None and not self._stop.is_set():
                try:
                    self.queues[target].put(item, timeout=0.05)
                    break
                except queue.Full:
                    # blocked: backpressure — re-check liveness so items never
                    # pile onto a node that died while we waited
                    if not self._live.get(target):
                        target = self._next_live(rr)
            if target is None or self._stop.is_set():
                break
        self.exhausted.set()

    # ------------------------------------------------------------------- drain
    def cut_epoch(self, max_items: int, tick_s: Optional[float] = None
                  ) -> Dict[str, List[IngestItem]]:
        """Drain queues into one epoch: up to ``max_items`` total, or whatever
        arrived when ``tick_s`` elapses (needs >= 1 item — an empty tick waits
        for data or end-of-stream)."""
        batch: Dict[str, List[IngestItem]] = {n: [] for n in self.nodes}
        count = 0
        deadline = None
        while count < max_items:
            got = False
            for n in self.nodes:
                if count >= max_items:
                    break
                try:
                    batch[n].append(self.queues[n].get_nowait())
                    count += 1
                    got = True
                except queue.Empty:
                    continue
            if got:
                if deadline is None and tick_s is not None:
                    deadline = time.monotonic() + tick_s
                continue
            if self.exhausted.is_set() and all(q.empty() for q in self.queues.values()):
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
            time.sleep(0.001)
        return batch

    def mark_dead(self, node: str) -> None:
        self._live[node] = False

    def qsizes(self) -> Dict[str, int]:
        return {n: q.qsize() for n, q in self.queues.items()}

    def stop(self) -> None:
        self._stop.set()


class StreamingRuntimeEngine(RuntimeEngine):
    """Micro-batch streaming over the batch engine's optimized stage DAG.

    Epoch-cut knobs (``epoch_items`` / ``epoch_seconds`` / ``queue_capacity``)
    default from ``plan.stream_config`` — the declarative
    ``STREAM WITH EPOCHS(...)`` surface — and can be overridden per engine.
    """

    def __init__(self, store: DataStore, optimizer: Optional[IngestionOptimizer] = None,
                 max_retries: int = 3, epoch_items: int = 64,
                 epoch_seconds: Optional[float] = None,
                 queue_capacity: int = 64) -> None:
        super().__init__(store, optimizer, max_retries)
        self.epoch_items = epoch_items
        self.epoch_seconds = epoch_seconds
        self.queue_capacity = queue_capacity
        self.alive = {n: True for n in self.nodes}

    # ----------------------------------------------------------------- config
    def _config(self, plan: IngestPlan) -> Tuple[int, Optional[float], int]:
        cfg = getattr(plan, "stream_config", None) or {}
        return (int(cfg.get("items", self.epoch_items)),
                cfg.get("seconds", self.epoch_seconds),
                int(cfg.get("capacity", self.queue_capacity)))

    # -------------------------------------------------------------------- run
    def run_stream(self, plan: IngestPlan, source: Iterable[IngestItem],
                   faults: Optional[StreamFaultInjection] = None,
                   optimize: bool = True,
                   max_epochs: Optional[int] = None) -> StreamReport:
        """Consume ``source`` (any iterator, possibly unbounded) until it is
        exhausted or ``max_epochs`` epochs have committed."""
        t0 = time.time()
        faults = faults or StreamFaultInjection()
        sreport = StreamReport()

        # compile + optimize ONCE; every epoch reuses the same stage plans
        stage_plans = plan.compile()
        if optimize:
            stage_plans = self.optimizer.optimize(stage_plans)

        epoch_items, epoch_seconds, capacity = self._config(plan)
        queues = IngestQueues(source, self.nodes, capacity)
        eid = self.store.next_epoch_id()
        try:
            while max_epochs is None or len(sreport.epochs) < max_epochs:
                batch = queues.cut_epoch(epoch_items, epoch_seconds)
                items = [it for per_node in batch.values() for it in per_node]
                if not items:
                    break   # end of stream
                ereport = self._run_epoch(eid, batch, stage_plans, faults,
                                          sreport, queues)
                sreport.epochs.append(ereport)
                sreport.total_items += ereport.items_in
                eid += 1
        finally:
            queues.stop()
        sreport.wall_time_s = time.time() - t0
        return sreport

    # ------------------------------------------------------------------ epoch
    def _run_epoch(self, eid: int, batch: Dict[str, List[IngestItem]],
                   stage_plans: List[StagePlan], faults: StreamFaultInjection,
                   sreport: StreamReport, queues: IngestQueues) -> EpochReport:
        """Run one micro-batch through the stage DAG and commit it atomically.

        Node death mid-attempt -> abort the staged blocks, mark the node dead,
        replay the *entire epoch* on the survivors.  The commit is the only
        publish point, so a replayed epoch can neither lose items (the full
        input batch is retained until commit) nor double-commit
        (``begin_epoch`` refuses committed ids)."""
        epoch_index = len(sreport.epochs)
        all_items = [it for per_node in batch.values() for it in per_node]
        t_cut = time.time()
        attempts = 0
        while True:
            attempts += 1
            live = [n for n in self.nodes if self.alive[n]]
            if not live:
                raise RuntimeError("all nodes failed")
            # redistribute: queue affinity where the node is alive, round-robin
            # onto survivors otherwise (first attempt after a death, or replay)
            node_sources: Dict[str, List[IngestItem]] = {n: [] for n in self.nodes}
            spill: List[IngestItem] = []
            for n, its in batch.items():
                (node_sources[n] if self.alive[n] else spill).extend(its)
            for i, it in enumerate(spill):
                node_sources[live[i % len(live)]].append(it)

            # injected mid-epoch deaths for this epoch index -> die after the
            # first stage of the attempt (blocks already staged get aborted)
            ef = FaultInjection(op_failures=faults.op_failures)
            for n, at_epoch in faults.node_death_in_epoch.items():
                if at_epoch == epoch_index and self.alive.get(n):
                    ef.node_death_after_stage[n] = stage_plans[0].name

            self.store.begin_epoch(eid)
            ereport = RunReport()
            try:
                self._execute(stage_plans, node_sources, ef, ereport,
                              self.alive, on_node_death="raise")
            except NodeFailure as e:
                dead = str(e)
                self.store.abort_epoch(eid)
                queues.mark_dead(dead)
                sreport.node_failures.append(dead)
                if eid not in sreport.replayed_epochs:
                    sreport.replayed_epochs.append(eid)
                continue
            entry = self.store.commit_epoch(eid, n_items=len(all_items))
            return EpochReport(epoch=eid, items_in=len(all_items),
                               n_blocks=entry.n_blocks, attempts=attempts,
                               commit_latency_s=time.time() - t_cut,
                               run=ereport)


def stream_ingest(plan: IngestPlan, source: Iterable[IngestItem], store: DataStore,
                  *, optimize: bool = True,
                  faults: Optional[StreamFaultInjection] = None,
                  max_epochs: Optional[int] = None,
                  **engine_kw: Any) -> StreamReport:
    """One-call entry point: stream a source through an ingestion plan."""
    eng = StreamingRuntimeEngine(store, **engine_kw)
    return eng.run_stream(plan, source, faults=faults, optimize=optimize,
                          max_epochs=max_epochs)
