"""Streaming micro-batch ingestion runtime.

The batch ``RuntimeEngine`` takes a finite source list and runs every stage
behind a full barrier; this module makes the same optimized stage DAG consume
an *unbounded* source, in the shape of AsterixDB-style long-running feeds
(arXiv:1405.1705) with enrichment pipelines layered on top (arXiv:1902.08271):

* **Bounded ingest queues + backpressure** — a feeder thread routes source
  items round-robin into per-node ``queue.Queue(maxsize=...)``; when a node's
  queue is full the producer *blocks*, so queue memory is bounded no matter
  how fast data arrives.  An item the feeder could not place (``stop()`` fired
  mid-put, or every node died) is never silently dropped: it is parked in
  ``IngestQueues.unrouted``.
* **Epochs (micro-batches)** — the stream is cut into epochs by item count
  and/or wall-clock tick; each epoch runs through the existing optimized
  ``StagePlan`` pipeline (operator chains, pipeline blocks, shuffle, retry /
  dummy-substitution fault machinery are all reused via
  ``RuntimeEngine._execute`` on the persistent per-node executors).
* **Pipelined epochs** (DESIGN.md §4) — the optimizer's segment split
  (``split_pipeline_segments``) divides the DAG into an *ingest segment*
  (parse / transform / shuffle) and a *store segment* (upload + commit).
  Epoch N+1's ingest segment runs on the node executors' ``"ingest"`` lane
  while epoch N's store segment occupies the ``"store"`` lane inside a
  background committer; the DataStore commit sequencer publishes commits
  strictly in epoch order, so ``since_epoch`` readers never observe a gap.
  ``pipelined=False`` restores strictly sequential epochs.
* **Epoch-granular fault tolerance** — a node death mid-epoch aborts the
  staged epoch (its partially-written blocks are rolled back) and replays the
  whole epoch on the surviving nodes.  Committed epochs are never redone:
  ``DataStore.begin_epoch`` refuses an already-committed epoch id.
* **Exactly-once commits** — ``DataStore.commit_epoch`` publishes an epoch's
  blocks atomically (manifest temp-write + rename); ``DataAccess.since_epoch``
  lets queries consume exactly the committed epochs while ingestion continues.
* **Feed fan-out** — ``FeedDistributor`` + ``stream_ingest_multi`` fan one
  source into several plans (the language's ``FEED ... INTO plan1, plan2``),
  AsterixDB-style feed joints: enrichment pipelines share a single ingest.
* **Worker-pull sources** (ISSUE 6) — a ``SourceAdapter`` turns the source
  into shard *descriptors* (byte ranges / endpoints / seeded specs); the
  coordinator cuts epochs over descriptors and workers open/read/parse their
  shards directly into their local lanes, so zero item bytes cross the
  coordinator (``RunReport.source_coordinator_bytes == 0``).  A reader death
  re-issues the dead node's unfinished descriptors to survivors
  (``source_reissues``) before the usual invalidate-then-replay.  The pushed
  feeder path above remains as fallback and oracle for sources that cannot
  be described (feed joints, raw iterators).
"""
from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import (Any, Dict, Iterable, Iterator, List, Optional, Sequence,
                    Tuple, Union)

from .items import IngestItem
from .liveness import LivenessMonitor
from .optimizer import IngestionOptimizer, split_pipeline_segments
from .plan import IngestPlan, StagePlan, coerce_bool, cone_replay_capable
from .runtime import (FaultInjection, NodeFailure, RunReport, RuntimeEngine,
                      derive_spill_bytes)
from .sources import ShardDescriptor, SourceAdapter, build_source
from .store import DataStore


def _unit_rows(vals: Iterable[Any]) -> int:
    """Rows carried by a list of replay units — items report their actual
    row count, shard descriptors their estimate (at least one row each).
    This is the unit of ``RunReport.replayed_rows``: the cone-vs-whole-epoch
    comparison the death-matrix tests assert on (ISSUE 8)."""
    total = 0
    for v in vals:
        nr = getattr(v, "nrows", None)
        if callable(nr):
            total += int(nr())
        else:
            total += max(1, int(getattr(v, "est_items", 1)))
    return total


@dataclass
class EpochPolicy:
    """When to cut an epoch, and how big the ingest queues are.

    An epoch closes at the *first* threshold hit: ``items`` source items,
    ``bytes`` of queued payload (the first slice of adaptive epoch sizing —
    a burst of fat items no longer inflates the staged epoch), or ``seconds``
    of wall clock since the epoch's first item.  ``capacity`` bounds each
    node's ingest queue (the backpressure seam).  The declarative surface is
    ``STREAM WITH EPOCHS(items=…, seconds=…, bytes=…, capacity=…,
    adaptive=…)``.

    **Adaptive sizing** (ROADMAP "adaptive epoch sizing, part 2"): with
    ``adaptive=True`` the engine feeds every committed epoch's commit
    latency into :meth:`observe_commit`, which keeps an EWMA of the latency
    and rescales the ``items``/``bytes`` thresholds toward
    ``target_commit_s`` — commits lagging the target narrow the cut,
    fast commits widen it.  Each step is clamped to ``grow_limit`` per
    observation and the cut is bounded by ``min_items``/``max_items``, so a
    single outlier epoch cannot whiplash the stream.
    """

    items: int = 64
    seconds: Optional[float] = None
    bytes: Optional[int] = None
    capacity: int = 64
    adaptive: bool = False
    target_commit_s: float = 0.25
    alpha: float = 0.3          # EWMA smoothing factor
    grow_limit: float = 2.0     # max per-observation rescale (and 1/x shrink)
    min_items: int = 1
    max_items: int = 1 << 16
    _ewma: Optional[float] = field(default=None, init=False, repr=False,
                                   compare=False)

    @classmethod
    def from_stream_config(cls, cfg: Optional[Dict[str, Any]],
                           default: "EpochPolicy") -> "EpochPolicy":
        cfg = cfg or {}
        return cls(items=int(cfg.get("items", default.items)),
                   seconds=cfg.get("seconds", default.seconds),
                   bytes=(int(cfg["bytes"]) if cfg.get("bytes") is not None
                          else default.bytes),
                   capacity=int(cfg.get("capacity", default.capacity)),
                   adaptive=coerce_bool(cfg.get("adaptive", default.adaptive)),
                   target_commit_s=float(cfg.get("target_commit_s",
                                                 default.target_commit_s)))

    def observe_commit(self, latency_s: float) -> None:
        """Feed one committed epoch's commit latency into the controller.

        No-op unless ``adaptive``; otherwise updates the EWMA and rescales
        the items/bytes thresholds by ``clamp(target / ewma)``."""
        if not self.adaptive or latency_s <= 0:
            return
        a = self.alpha
        self._ewma = (latency_s if self._ewma is None
                      else a * latency_s + (1.0 - a) * self._ewma)
        ratio = self.target_commit_s / self._ewma
        ratio = min(self.grow_limit, max(1.0 / self.grow_limit, ratio))
        before = self.items
        self.items = max(self.min_items,
                         min(self.max_items, int(round(self.items * ratio))))
        if self.bytes is not None and before > 0:
            # bytes moves in lockstep with the *realized* items step, so it
            # inherits the min/max clamp: a saturated items cut stops the
            # bytes backstop from drifting unboundedly too
            self.bytes = max(1, int(round(self.bytes * self.items / before)))


@dataclass
class StreamFaultInjection:
    """Deterministic streaming fault hooks (tests/benchmarks).

    ``op_failures`` uses the batch engine's (stage, op_index) -> count format
    and is shared across epochs; ``node_death_in_epoch`` kills a node while
    the given epoch index is mid-flight (after its first stage, before
    commit) — exercising abort + replay.  ``node_death_at`` places the death
    precisely: ``(node, epoch_index) -> stage name`` dies right after that
    stage completes on the node, which is how the chaos harness (ISSUE 8)
    keys kill events to epoch·stage·node — a death after the ingest
    segment's *last* stage exercises the lineage-cone replay path.
    """

    op_failures: Dict[Tuple[str, int], int] = field(default_factory=dict)
    node_death_in_epoch: Dict[str, int] = field(default_factory=dict)
    node_death_at: Dict[Tuple[str, int], str] = field(default_factory=dict)


@dataclass
class EpochReport:
    """What the engine observed for one committed epoch."""

    epoch: int
    items_in: int                 # source items consumed by the epoch
    n_blocks: int                 # blocks the commit published
    attempts: int                 # 1 = clean; >1 = replayed after node death
    commit_latency_s: float       # epoch cut -> manifest rename landed
    run: RunReport = field(default_factory=RunReport)


@dataclass
class StreamReport:
    """Aggregate of a ``run_stream`` call."""

    epochs: List[EpochReport] = field(default_factory=list)
    node_failures: List[str] = field(default_factory=list)
    replayed_epochs: List[int] = field(default_factory=list)
    total_items: int = 0
    wall_time_s: float = 0.0
    spawn_retries: int = 0        # process-worker spawn attempts beyond the first
    liveness_deaths: List[Tuple[str, float]] = field(default_factory=list)
    # ^ (node, seconds-to-detection) for deaths the heartbeat monitor declared
    host_partitions: List[Tuple[str, List[str], float]] = field(
        default_factory=list)
    # ^ (host, member nodes, age) for hosts the quorum declared as one unit
    sweep_skipped_remote: int = 0  # shm sweeps skipped: worker not local

    def committed_epoch_ids(self) -> List[int]:
        return [e.epoch for e in self.epochs]

    def commit_latencies(self) -> List[float]:
        return [e.commit_latency_s for e in self.epochs]

    def items_per_sec(self) -> float:
        return self.total_items / self.wall_time_s if self.wall_time_s else 0.0

    # --------------------------- worker-pull source aggregates (ISSUE 6) ---
    def source_coordinator_bytes(self) -> int:
        """Item bytes that crossed the coordinator on the source hop —
        zero for descriptor-backed (worker-pull) sources."""
        return sum(e.run.source_coordinator_bytes for e in self.epochs)

    def source_descriptors(self) -> int:
        """Shard descriptors issued to workers across all committed epochs."""
        return sum(e.run.source_descriptors for e in self.epochs)

    def vectorized_rows(self) -> int:
        """Rows that went through the batch operator tier (ISSUE 7)."""
        return sum(e.run.vectorized_rows for e in self.epochs)

    def batch_fallbacks(self) -> int:
        """Batched blocks that fell back to the scalar iterator path."""
        return sum(e.run.batch_fallbacks for e in self.epochs)

    def kernel_ms(self) -> float:
        """Milliseconds spent inside erasure/encode kernels across epochs."""
        return sum(e.run.kernel_ms for e in self.epochs)

    def source_reissues(self) -> int:
        """Descriptors re-issued to survivors after a reader death."""
        return sum(e.run.source_reissues for e in self.epochs)

    # ------------------------------------- lineage-cone recovery (ISSUE 8) ---
    def cone_replays(self) -> int:
        """Deaths recovered by replaying only the dead node's lineage cone
        (zero when every recovery fell back to whole-epoch replay)."""
        return sum(e.run.cone_replays for e in self.epochs)

    def replayed_rows(self) -> int:
        """Rows recomputed by recovery — a cone replay contributes only the
        dead node's share, a whole-epoch replay the full epoch."""
        return sum(e.run.replayed_rows for e in self.epochs)

    # ----------------------------------------- degraded exchange (ISSUE 9) ---
    def degraded_exchange_rounds(self) -> int:
        """Exchange rounds that moved at least one partition cross-host in
        degraded mode (streamed spill files instead of shm segments)."""
        return sum(e.run.degraded_exchange_rounds for e in self.epochs)

    def degraded_peer_bytes(self) -> int:
        """Partition bytes that crossed host-to-host over the stream path."""
        return sum(e.run.degraded_peer_bytes for e in self.epochs)

    # ------------------------------------------- columnar plane (ISSUE 10) ---
    def columnar_rounds(self) -> int:
        """Exchange rounds that moved at least one partition as a
        ColumnarBatch column buffer (no per-item pickling on the edge)."""
        return sum(e.run.columnar_rounds for e in self.epochs)

    def columnar_bytes(self) -> int:
        """Partition bytes that crossed stage edges in columnar form."""
        return sum(e.run.columnar_bytes for e in self.epochs)

    def columnar_fallbacks(self) -> int:
        """Producers on columnar rounds whose output wouldn't pack and fell
        back to the scalar item path (counted, never wrong)."""
        return sum(e.run.columnar_fallbacks for e in self.epochs)


class IngestQueues:
    """Per-node bounded ingest queues fed from an unbounded source.

    The feeder thread pulls from the source iterator and round-robins items
    across node queues with *blocking* puts — the backpressure seam: a slow
    pipeline stalls the producer instead of growing memory.  ``mark_dead``
    removes a node from the routing set; items already queued on a dead node
    are still drained (and re-routed to live nodes by the epoch cutter).

    **Manual mode** (``IngestQueues.manual``, used by feed joints): no feeder
    thread is started — an external distributor pushes items with ``put`` and
    signals end-of-stream with ``close``.

    An item in the feeder's (or distributor's) hand when ``stop()`` fires, or
    when every node has died, is recorded in ``unrouted`` — never silently
    dropped: the stream's producer offset can be rewound by exactly
    ``len(unrouted)`` items on restart.
    """

    def __init__(self, source: Optional[Iterable[IngestItem]], nodes: Sequence[str],
                 capacity: int = 64) -> None:
        self.nodes = list(nodes)
        self.capacity = capacity
        self.queues: Dict[str, "queue.Queue[IngestItem]"] = {
            n: queue.Queue(maxsize=capacity) for n in self.nodes}
        self._live = {n: True for n in self.nodes}
        self._rr = itertools.cycle(self.nodes)
        self._stop = threading.Event()
        self.exhausted = threading.Event()
        self.produced = 0   # items pulled from the source / pushed by put()
        self.items_routed = 0       # successfully placed items …
        self.bytes_routed = 0       # … and their payload bytes (for the
        # spill-aware shuffle budget: avg_item_bytes() estimates how much
        # memory the queues themselves can pin at full capacity)
        self.unrouted: List[IngestItem] = []   # in-flight items never placed
        self._thread: Optional[threading.Thread] = None
        if source is not None:
            self._source = iter(source)
            self._thread = threading.Thread(target=self._feed, daemon=True)
            self._thread.start()

    @classmethod
    def manual(cls, nodes: Sequence[str], capacity: int = 64) -> "IngestQueues":
        """Queues without a feeder thread (fed by a FeedDistributor)."""
        return cls(None, nodes, capacity)

    # ------------------------------------------------------------------ feeder
    def _next_live(self) -> Optional[str]:
        """Next live node in round-robin order; None when none remain (or the
        queues were stopped) — never spins on an all-dead cycle."""
        for _ in range(len(self.nodes)):
            n = next(self._rr)
            if self._live.get(n):
                return n
        return None

    def _route(self, item: IngestItem) -> bool:
        """Blocking put with liveness re-checks.  False when the item could
        not be placed (stop() fired mid-put, or all nodes are dead)."""
        target = self._next_live()
        while target is not None and not self._stop.is_set():
            try:
                self.queues[target].put(item, timeout=0.05)
                self.items_routed += 1
                self.bytes_routed += item.nbytes()
                return True
            except queue.Full:
                # blocked: backpressure — re-check liveness so items never
                # pile onto a node that died while we waited
                if not self._live.get(target):
                    target = self._next_live()
        return False

    def _feed(self) -> None:
        for item in self._source:
            self.produced += 1
            if not self._route(item):
                # the in-flight item is parked, not lost (satellite of ISSUE 2)
                self.unrouted.append(item)
                break
        self.exhausted.set()

    # --------------------------------------------------------- manual producer
    def put(self, item: IngestItem) -> bool:
        """Feed-joint surface: route one item (blocking).  Returns False — and
        records the item in ``unrouted`` — when it could not be placed."""
        self.produced += 1
        if self._route(item):
            return True
        self.unrouted.append(item)
        return False

    def close(self) -> None:
        """Feed-joint end-of-stream (what source exhaustion is to the feeder)."""
        self.exhausted.set()

    # ------------------------------------------------------------------- drain
    def avg_item_bytes(self, default: int = 64 << 10) -> int:
        """Observed mean payload size of routed items (``default`` until the
        first item lands) — the ingest queues' share of a memory budget is
        ``capacity * len(nodes) * avg_item_bytes()``."""
        if not self.items_routed:
            return default
        return max(1, self.bytes_routed // self.items_routed)

    def cut_epoch(self, max_items: int, tick_s: Optional[float] = None,
                  max_bytes: Optional[int] = None
                  ) -> Dict[str, List[IngestItem]]:
        """Drain queues into one epoch: up to ``max_items`` total (and/or
        ``max_bytes`` of payload — the byte cut closes the epoch at the first
        item that reaches the threshold), or whatever arrived when ``tick_s``
        elapses.

        The tick deadline arms on **entry** (bugfix, ISSUE 6): it used to arm
        only after the first item landed, so an idle stream never honored the
        wall-clock cut and a slow trickle held the epoch open indefinitely.
        An idle tick now returns an *empty* batch at the deadline — callers
        distinguish it from end-of-stream via :meth:`at_eof`."""
        batch: Dict[str, List[IngestItem]] = {n: [] for n in self.nodes}
        count = 0
        nbytes = 0
        deadline = (time.monotonic() + tick_s) if tick_s is not None else None
        while count < max_items and (max_bytes is None or nbytes < max_bytes):
            got = False
            for n in self.nodes:
                if count >= max_items or (max_bytes is not None
                                          and nbytes >= max_bytes):
                    break
                try:
                    it = self.queues[n].get_nowait()
                    batch[n].append(it)
                    count += 1
                    nbytes += it.nbytes()
                    got = True
                except queue.Empty:
                    continue
            if got:
                continue
            if self.at_eof():
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
            # bounded wait, never past the tick deadline, waking early on
            # end-of-stream (the old code slept a blind 1 ms per pass)
            wait = 0.001
            if deadline is not None:
                wait = max(0.0005, min(wait, deadline - time.monotonic()))
            self.exhausted.wait(wait)
        return batch

    def at_eof(self) -> bool:
        """End of stream: the producer is done and every queue is drained
        (how callers tell an empty wall-clock tick from stream end)."""
        return (self.exhausted.is_set()
                and all(q.empty() for q in self.queues.values()))

    def mark_dead(self, node: str) -> None:
        self._live[node] = False

    def qsizes(self) -> Dict[str, int]:
        return {n: q.qsize() for n, q in self.queues.items()}

    def stop(self) -> None:
        self._stop.set()


class FeedDistributor:
    """AsterixDB-style feed joint (arXiv:1405.1705): one pull from the source,
    fanned out to several plans' ingest queues.

    Every joint receives every item (enrichment pipelines share the ingest);
    a slow pipeline exerts backpressure on the shared feed through its
    blocking ``put``.  A stopped or fully-dead pipeline fails its puts fast —
    the item is recorded unrouted on *that joint only* and the feed keeps
    serving the healthy pipelines.
    """

    def __init__(self, source: Iterable[IngestItem],
                 joints: Sequence[IngestQueues]) -> None:
        self.joints = list(joints)
        self.fanned_out = 0   # items pulled from the shared source
        self._source = iter(source)
        self._thread = threading.Thread(target=self._pump, daemon=True)
        self._thread.start()

    def _pump(self) -> None:
        active = list(self.joints)
        try:
            for item in self._source:
                self.fanned_out += 1
                for j in list(active):
                    if not j.put(item):
                        # the joint stopped (its pipeline finished or died):
                        # detach it so a long stream doesn't pile the whole
                        # remainder into its unrouted list
                        active.remove(j)
                if not active:
                    break
        finally:
            for j in self.joints:
                j.close()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)


# --------------------------------------------------------------------------
# Pipelined epoch committer
# --------------------------------------------------------------------------
@dataclass
class _EpochJob:
    """A cut epoch whose ingest segment has run, awaiting store + commit.

    ``node_set`` is the live set the ingest segment executed on: the
    segment's outputs live in *node-resident* exchange buckets pinned to
    those nodes (ISSUE 5), so the store segment may consume them in place
    only while every one of them is still alive — otherwise the committer
    replays the whole epoch from the retained ``batch``.

    With a worker-pull ``source`` (ISSUE 6), ``batch``/``node_sources`` hold
    :class:`~repro.core.sources.ShardDescriptor` assignments instead of
    items — the retained descriptors are the replay unit: re-reading them is
    deterministic, so a replayed epoch commits the same rows."""

    eid: int
    epoch_index: int
    batch: Dict[str, List[Any]]          # items, or shard descriptors
    node_sources: Dict[str, List[Any]]
    outputs: Dict[str, Dict[str, List[IngestItem]]]
    faults: FaultInjection           # this epoch's injection view
    ereport: RunReport
    attempts: int
    items_in: int
    t_cut: float
    node_set: List[str] = field(default_factory=list)
    source: Optional[SourceAdapter] = None   # set => descriptor-backed epoch


class _EpochCommitter:
    """Background store-segment worker for pipelined epochs.

    A single FIFO thread runs each staged epoch's commit-side stages on the
    node executors' ``"store"`` lane and publishes the commit; the bounded
    job queue is the pipeline depth (cut N+1 blocks while N+1-depth epochs
    are still staged).  Processing order + the DataStore commit sequencer
    guarantee commits land strictly in epoch order.
    """

    def __init__(self, engine: "StreamingRuntimeEngine",
                 stage_plans: List[StagePlan], split: int,
                 faults: StreamFaultInjection, sreport: StreamReport,
                 queues: Optional[IngestQueues], max_inflight: int = 2,
                 policy: Optional[EpochPolicy] = None) -> None:
        self.engine = engine
        self.stage_plans = stage_plans
        self.split = split
        self.faults = faults
        self.sreport = sreport
        self.queues = queues
        self.policy = policy
        self._jobs: "queue.Queue[Optional[_EpochJob]]" = queue.Queue(
            maxsize=max(1, max_inflight))
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="epoch-committer")
        self._thread.start()

    # ----------------------------------------------------------------- public
    def submit(self, job: _EpochJob) -> None:
        self.raise_if_failed()
        self._jobs.put(job)   # blocks: bounds the number of in-flight epochs

    def close(self) -> None:
        self._jobs.put(None)
        self._thread.join()

    def raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------- loop
    def _loop(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                return
            if self._error is not None:
                continue   # drain remaining jobs so submit() never deadlocks
            try:
                self._commit_job(job)
            except BaseException as e:
                self._error = e

    def _commit_job(self, job: _EpochJob) -> None:
        """Run the epoch's store segment and commit.

        The ingest segment's outputs live in node-resident exchange buckets
        (pinned rounds, ISSUE 5): the first attempt adopts them and runs
        only the store segment, in place, on the same node set.  If any
        ingest contributor has died since — its resident buckets died with
        it — or a later attempt is needed, the epoch's exchange state is
        invalidated and the *whole epoch* replays from the retained raw
        ``batch`` on the survivors (nothing committed yet, so the replay is
        exactly-once).  The executing node set is pinned per attempt — a
        death flipping ``alive`` from the ingest thread mid-attempt cannot
        silently drop a node's inputs.

        Before falling back, an ingest-contributor death on a cone-capable
        plan (ISSUE 8) first tries the narrower repair: strip only the dead
        node's exchange contribution and re-run the ingest segment for just
        its retained shards — survivors' resident buckets stay live and the
        store segment proceeds in place."""
        eng, store = self.engine, self.engine.store
        first = True
        while True:
            if not first:
                job.attempts += 1
            # a SIGTERM'd worker whose death never surfaced as a stage
            # failure (it finished its segment work, then died) is caught
            # here by its pipe EOF, before the store slice is submitted to it
            for n in eng._probe_executors():
                eng._record_death(n, job.eid, self.sreport, self.queues)
            if not any(eng.alive.values()):
                raise RuntimeError("all nodes failed")
            live = [n for n in eng.nodes if eng.alive.get(n)]
            in_place = first and not (set(job.node_set) - set(live))
            if (not in_place and first and eng.cone_recovery
                    and self.split > 0
                    and not getattr(eng.shuffle, "synchronous", False)
                    and cone_replay_capable(self.stage_plans, self.split)):
                dead = [n for n in job.node_set if n not in live]
                patch = eng._cone_patch(job.eid, dead, job.batch,
                                        self.stage_plans, self.split,
                                        job.faults, job.ereport, job.source)
                if patch is not None:
                    for n in dead:
                        job.batch[n] = []
                    for n, extra in patch.items():
                        job.batch.setdefault(n, []).extend(extra)
                    job.node_sources = job.batch
                    job.node_set = live
                    if job.eid not in self.sreport.replayed_epochs:
                        self.sreport.replayed_epochs.append(job.eid)
                    in_place = True
                else:
                    # the patch itself lost a node; its partial merge was
                    # torn down with the epoch's exchange state — recompute
                    # the live set and take the whole-epoch road
                    live = [n for n in eng.nodes if eng.alive.get(n)]
                    if not live:
                        raise RuntimeError("all nodes failed")
            first = False
            if not in_place:
                # resident ingest outputs are stale or lost: drop the
                # epoch's exchange rounds everywhere and recompute from the
                # retained batch
                eng.invalidate_exchange(job.eid)
                if job.source is not None:
                    # descriptor replay bookkeeping: the dead node's
                    # unfinished shards are handed to survivors
                    job.ereport.source_reissues += eng._count_lost(
                        job.batch, live)
                job.node_sources = eng._redistribute(job.batch, live)
                job.batch = job.node_sources
                job.outputs = {n: defaultdict(list) for n in eng.nodes}
                job.ereport.replayed_rows += _unit_rows(
                    it for v in job.node_sources.values() for it in v)
            store.begin_epoch(job.eid)
            base_items = job.ereport.source_items
            try:
                if not in_place and self.split > 0:
                    # recompute the ingest segment on the *ingest* lanes —
                    # the lane discipline of the original run: a stage's
                    # resident operator state (its output generator) is only
                    # ever driven by one lane, never concurrently from here
                    # and a newer epoch's ingest.  Its rounds re-pin and the
                    # store slice below adopts them, exactly like a clean run.
                    eng._execute(self.stage_plans, job.node_sources,
                                 job.faults, job.ereport, eng.alive,
                                 on_node_death="raise", lane="ingest",
                                 epoch=job.eid, outputs=job.outputs,
                                 start_stage=0, end_stage=self.split,
                                 node_set=live, source=job.source)
                eng._execute(self.stage_plans, job.node_sources, job.faults,
                             job.ereport, eng.alive, on_node_death="raise",
                             lane="store", epoch=job.eid, outputs=job.outputs,
                             start_stage=self.split, node_set=live,
                             source=job.source)
                if job.source is not None and self.split == 0:
                    # single-segment DAG: the shards were read just now, on
                    # the store lane — items_in is the worker-reported count
                    job.items_in = job.ereport.source_items - base_items
                self._publish(job)
                return
            except NodeFailure as e:
                store.abort_epoch(job.eid)
                eng._note_death(str(e), job.eid, self.sreport, self.queues)

    def _publish(self, job: _EpochJob) -> None:
        entry = self.engine.store.commit_epoch(job.eid, n_items=job.items_in)
        latency = time.time() - job.t_cut
        self.sreport.epochs.append(EpochReport(
            epoch=job.eid, items_in=job.items_in, n_blocks=entry.n_blocks,
            attempts=job.attempts, commit_latency_s=latency,
            run=job.ereport))
        self.sreport.total_items += job.items_in
        if self.policy is not None:
            # adaptive epoch sizing: the cut loop reads the rescaled
            # thresholds at its next epoch cut
            self.policy.observe_commit(latency)
        with self.engine._progress:
            self.engine._progress.notify_all()   # wake idle cut loops


class StreamingRuntimeEngine(RuntimeEngine):
    """Micro-batch streaming over the batch engine's optimized stage DAG.

    Epoch-cut knobs (``epoch_items`` / ``epoch_seconds`` / ``queue_capacity``)
    default from ``plan.stream_config`` — the declarative
    ``STREAM WITH EPOCHS(...)`` surface — and can be overridden per engine.

    ``pipelined=True`` (default) overlaps epoch N+1's ingest segment with
    epoch N's store/commit segment (DESIGN.md §4); ``max_inflight_epochs``
    bounds how many staged epochs the committer may hold.  Committed epoch
    ids are gap-free and in-order in either mode.
    """

    def __init__(self, store: DataStore, optimizer: Optional[IngestionOptimizer] = None,
                 max_retries: int = 3, epoch_items: int = 64,
                 epoch_seconds: Optional[float] = None,
                 epoch_bytes: Optional[int] = None,
                 queue_capacity: int = 64,
                 pipelined: bool = True,
                 max_inflight_epochs: int = 2,
                 shuffle_spill_bytes: Optional[int] = None,
                 shuffle_synchronous: bool = False,
                 backend: str = "thread",
                 memory_budget_bytes: Optional[int] = None,
                 epoch_adaptive: bool = False,
                 epoch_target_commit_s: Optional[float] = None,
                 cone_recovery: bool = True,
                 heartbeat_interval_s: Optional[float] = None,
                 heartbeat_miss: int = 4,
                 transport: str = "pipe",
                 node_hosts: Optional[Dict[str, str]] = None,
                 network_chaos: bool = False,
                 columnar: bool = True) -> None:
        super().__init__(store, optimizer, max_retries,
                         shuffle_spill_bytes=shuffle_spill_bytes,
                         shuffle_synchronous=shuffle_synchronous,
                         backend=backend,
                         memory_budget_bytes=memory_budget_bytes,
                         transport=transport, node_hosts=node_hosts,
                         network_chaos=network_chaos, columnar=columnar)
        self.epoch_items = epoch_items
        self.epoch_seconds = epoch_seconds
        self.epoch_bytes = epoch_bytes
        self.epoch_adaptive = epoch_adaptive
        self.epoch_target_commit_s = epoch_target_commit_s
        self.queue_capacity = queue_capacity
        self.pipelined = pipelined
        self.max_inflight_epochs = max_inflight_epochs
        self.alive = {n: True for n in self.nodes}
        # ----------------------------------------------- robustness (ISSUE 8)
        # cone_recovery=False forces every node death down the whole-epoch
        # replay road — the correctness oracle the death-matrix tests compare
        # cone-replayed stores against byte-for-byte
        self.cone_recovery = cone_recovery
        # heartbeat_interval_s arms the liveness monitor (process backend):
        # a worker that stops answering pings for heartbeat_miss intervals is
        # declared dead even though its pipe never closed (SIGSTOP / wedge)
        self.heartbeat_interval_s = heartbeat_interval_s
        self.heartbeat_miss = heartbeat_miss
        self.liveness: Optional[LivenessMonitor] = None
        # progress pulse: committers notify on publish/death so idle waiters
        # (the descriptor cut loop) sleep on a condition instead of spinning
        self._progress = threading.Condition()

    # ----------------------------------------------------------------- config
    def _config(self, plan: IngestPlan) -> EpochPolicy:
        default = EpochPolicy(items=self.epoch_items,
                              seconds=self.epoch_seconds,
                              bytes=self.epoch_bytes,
                              capacity=self.queue_capacity,
                              adaptive=self.epoch_adaptive)
        if self.epoch_target_commit_s is not None:
            default.target_commit_s = self.epoch_target_commit_s
        return EpochPolicy.from_stream_config(
            getattr(plan, "stream_config", None), default)

    # ------------------------------------------------- liveness (ISSUE 8)
    def _start_liveness(self) -> None:
        """Arm the heartbeat monitor over the process workers' control
        pipes.  No-op for the thread backend (an in-process executor cannot
        wedge independently of the coordinator) or when no interval is
        configured — pipe-EOF detection then remains the only death signal."""
        if self.heartbeat_interval_s is None or self.backend != "process":
            return
        mon = LivenessMonitor(interval_s=self.heartbeat_interval_s,
                              miss_threshold=self.heartbeat_miss)
        for n in self.nodes:
            # the host label opts the node into the per-host partition
            # quorum (ISSUE 9): a host whose workers all go silent together
            # is declared partitioned as one unit
            mon.watch(n, self.executor(n), host=self.node_hosts.get(n))
        mon.start()
        self.liveness = mon

    def _stop_liveness(self, sreport: StreamReport) -> None:
        mon, self.liveness = self.liveness, None
        if mon is not None:
            mon.stop()
            sreport.liveness_deaths.extend(mon.deaths)
            sreport.host_partitions.extend(mon.partitions)

    def _update_spill_budget(self, queues: IngestQueues) -> None:
        """Spill-aware shuffle sizing: re-derive ``spill_bytes`` from the
        shared memory budget minus what the ingest queues can pin at full
        capacity (observed mean item size) — re-evaluated at every epoch cut
        so the split adapts as the stream's item sizes drift."""
        if self.memory_budget_bytes is None or self._explicit_spill:
            return
        reserved = queues.capacity * len(self.nodes) * queues.avg_item_bytes()
        self.shuffle.spill_bytes = derive_spill_bytes(
            self.memory_budget_bytes, reserved)

    # -------------------------------------------------------------------- run
    def run_stream(self, plan: IngestPlan,
                   source: Union[Iterable[IngestItem], SourceAdapter,
                                 None] = None,
                   faults: Optional[StreamFaultInjection] = None,
                   optimize: bool = True,
                   max_epochs: Optional[int] = None,
                   queues: Optional[IngestQueues] = None) -> StreamReport:
        """Consume ``source`` until it is exhausted or ``max_epochs`` epochs
        have committed.  ``source`` is either a plain item iterator (legacy
        pushed path: a feeder thread routes items through coordinator-side
        queues) or a :class:`~repro.core.sources.SourceAdapter` (worker-pull
        path, ISSUE 6: epochs are cut over shard descriptors and workers read
        their shards directly).  Alternatively pass pre-built ``queues`` (a
        feed joint) instead of a source; with neither, a plan-level
        ``SOURCE ...`` spec compiles to an adapter."""
        adapter: Optional[SourceAdapter] = None
        if isinstance(source, SourceAdapter):
            adapter, source = source, None
        elif (source is None and queues is None
              and getattr(plan, "source_spec", None)):
            adapter = build_source(plan.source_spec)
        if sum(x is not None for x in (source, queues, adapter)) != 1:
            raise ValueError("run_stream needs exactly one of source/queues "
                             "(or a plan-level SOURCE spec)")
        t0 = time.time()
        faults = faults or StreamFaultInjection()
        sreport = StreamReport()
        if self.backend == "process":
            # fork the node workers before the feeder/committer threads exist
            self.prewarm_executors()
        self._start_liveness()

        # compile + optimize ONCE; every epoch reuses the same stage plans —
        # and the node executors keep their clone for the whole stream
        stage_plans = plan.compile()
        if optimize:
            stage_plans = self.optimizer.optimize(stage_plans)
        split = split_pipeline_segments(stage_plans)

        # store placement marks must agree with this engine's liveness view —
        # a fresh engine on a store a previous stream left marks on starts
        # from its own (all-live) map
        for n in self.nodes:
            (self.store.mark_node_live if self.alive[n]
             else self.store.mark_node_dead)(n)

        policy = self._config(plan)
        eid = self.store.next_epoch_id()
        if adapter is not None:
            # worker-pull path: no feeder thread, no coordinator queues —
            # the coordinator only plans *where* data is read
            try:
                self._run_pulled(stage_plans, split, adapter, faults, sreport,
                                 policy, max_epochs, eid)
            finally:
                self._stop_liveness(sreport)
                self.shuffle.drain()
                self.store.flush_manifest()
            sreport.spawn_retries = self._spawn_retry_total()
            sreport.sweep_skipped_remote = self._sweep_skip_total()
            sreport.wall_time_s = time.time() - t0
            return sreport
        if queues is None:
            queues = IngestQueues(source, self.nodes, policy.capacity)
        try:
            if self.pipelined:
                self._run_pipelined(stage_plans, split, queues, faults, sreport,
                                    policy, max_epochs, eid)
            else:
                epoch_index = 0
                while max_epochs is None or epoch_index < max_epochs:
                    self._update_spill_budget(queues)
                    batch = queues.cut_epoch(policy.items, policy.seconds,
                                             policy.bytes)
                    if not any(len(v) for v in batch.values()):
                        if queues.at_eof():
                            break   # end of stream
                        continue    # empty wall-clock tick: nothing to stage
                    ereport = self._run_epoch(eid, epoch_index, batch,
                                              stage_plans, faults, sreport, queues)
                    sreport.epochs.append(ereport)
                    sreport.total_items += ereport.items_in
                    policy.observe_commit(ereport.commit_latency_s)
                    eid += 1
                    epoch_index += 1
        finally:
            self._stop_liveness(sreport)
            queues.stop()
            self.shuffle.drain()
            self.store.flush_manifest()   # compact the epoch journal
        sreport.spawn_retries = self._spawn_retry_total()
        sreport.sweep_skipped_remote = self._sweep_skip_total()
        sreport.wall_time_s = time.time() - t0
        return sreport

    # -------------------------------------------------------------- pipelined
    def _run_pipelined(self, stage_plans: List[StagePlan], split: int,
                       queues: IngestQueues, faults: StreamFaultInjection,
                       sreport: StreamReport, policy: EpochPolicy,
                       max_epochs: Optional[int], eid: int) -> None:
        """Overlapped epochs: this thread cuts epoch N+1 and runs its ingest
        segment (lane "ingest") while the committer thread runs epoch N's
        store segment + commit (lane "store")."""
        committer = _EpochCommitter(self, stage_plans, split, faults, sreport,
                                    queues, max_inflight=self.max_inflight_epochs,
                                    policy=policy)
        epoch_index = 0
        try:
            while max_epochs is None or epoch_index < max_epochs:
                committer.raise_if_failed()
                self._update_spill_budget(queues)
                batch = queues.cut_epoch(policy.items, policy.seconds,
                                         policy.bytes)
                if not any(len(v) for v in batch.values()):
                    if queues.at_eof():
                        break   # end of stream
                    continue    # empty wall-clock tick: nothing to stage
                t_cut = time.time()
                job = self._ingest_segment(eid, epoch_index, batch, stage_plans,
                                           split, faults, sreport, queues, t_cut)
                committer.submit(job)
                eid += 1
                epoch_index += 1
        finally:
            committer.close()
        committer.raise_if_failed()

    # ------------------------------------------------------------ worker-pull
    @staticmethod
    def _count_lost(batch: Dict[str, List[Any]], live: Sequence[str]) -> int:
        """Descriptors assigned to nodes no longer in ``live`` — the shards a
        replay re-issues to survivors (``source_reissues``)."""
        live_set = set(live)
        return sum(len(v) for n, v in batch.items() if v and n not in live_set)

    def _cut_descriptors(self, pending: "deque[ShardDescriptor]",
                         adapter: SourceAdapter,
                         policy: EpochPolicy) -> List[ShardDescriptor]:
        """Epoch cut over shard descriptors.

        The coordinator never sees item bytes, so the cut budgets on the
        adapter's *estimates* (``est_items``/``est_bytes``, each descriptor
        counting at least one item); the authoritative per-epoch item count
        is worker-reported after the reads (``RunReport.source_items``).
        The ``seconds`` deadline arms on entry — an idle tick cuts whatever
        descriptors are pending, exactly like the fixed ``cut_epoch``."""
        deadline = (time.monotonic() + policy.seconds
                    if policy.seconds is not None else None)
        batch: List[ShardDescriptor] = []
        est_items = 0
        est_bytes = 0
        idle_wait = 0.005

        def full() -> bool:
            return (est_items >= policy.items
                    or (policy.bytes is not None
                        and est_bytes >= policy.bytes))

        while True:
            while pending and not full():
                d = pending.popleft()
                batch.append(d)
                est_items += max(1, int(getattr(d, "est_items", 1)))
                est_bytes += int(getattr(d, "est_bytes", 0))
            if full():
                break
            more = adapter.poll()
            if more:
                pending.extend(more)
                idle_wait = 0.005
                continue
            if adapter.exhausted():
                break
            if deadline is not None and time.monotonic() >= deadline:
                break
            # idle wait on the engine's progress condition instead of the old
            # 5 ms busy sleep (satellite of ISSUE 8): commit/death events wake
            # us immediately, and pure adapter polling backs off to 50 ms so
            # an idle stream doesn't spin a core.  The tick deadline caps the
            # wait so an armed wall-clock cut still fires on time.
            wait = idle_wait
            if deadline is not None:
                wait = max(0.0005, min(wait, deadline - time.monotonic()))
            with self._progress:
                self._progress.wait(wait)
            idle_wait = min(idle_wait * 2, 0.05)
        return batch

    def _run_pulled(self, stage_plans: List[StagePlan], split: int,
                    adapter: SourceAdapter, faults: StreamFaultInjection,
                    sreport: StreamReport, policy: EpochPolicy,
                    max_epochs: Optional[int], eid: int) -> None:
        """Descriptor-driven epochs (ISSUE 6): the coordinator distributes
        shard descriptors round-robin over the live nodes and the workers
        read them on their own lanes — zero source bytes cross here.  Reuses
        the pipelined committer / sequential epoch machinery unchanged; the
        retained descriptor batch is the replay unit after a reader death."""
        pending: "deque[ShardDescriptor]" = deque(adapter.describe())
        committer: Optional[_EpochCommitter] = None
        if self.pipelined:
            committer = _EpochCommitter(self, stage_plans, split, faults,
                                        sreport, None,
                                        max_inflight=self.max_inflight_epochs,
                                        policy=policy)
        epoch_index = 0
        try:
            while max_epochs is None or epoch_index < max_epochs:
                if committer is not None:
                    committer.raise_if_failed()
                descs = self._cut_descriptors(pending, adapter, policy)
                if not descs:
                    if adapter.exhausted() and not pending:
                        break   # end of stream
                    continue    # empty tick: the adapter may yet poll more
                live = [n for n in self.nodes if self.alive[n]]
                if not live:
                    raise RuntimeError("all nodes failed")
                batch: Dict[str, List[Any]] = {n: [] for n in self.nodes}
                for i, d in enumerate(descs):
                    batch[live[i % len(live)]].append(d)
                t_cut = time.time()
                if committer is not None:
                    job = self._ingest_segment(eid, epoch_index, batch,
                                               stage_plans, split, faults,
                                               sreport, None, t_cut,
                                               source=adapter)
                    committer.submit(job)
                else:
                    ereport = self._run_epoch(eid, epoch_index, batch,
                                              stage_plans, faults, sreport,
                                              None, source=adapter)
                    sreport.epochs.append(ereport)
                    sreport.total_items += ereport.items_in
                    policy.observe_commit(ereport.commit_latency_s)
                eid += 1
                epoch_index += 1
        finally:
            if committer is not None:
                committer.close()
        if committer is not None:
            committer.raise_if_failed()

    def _ingest_segment(self, eid: int, epoch_index: int,
                        batch: Dict[str, List[Any]],
                        stage_plans: List[StagePlan], split: int,
                        faults: StreamFaultInjection, sreport: StreamReport,
                        queues: Optional[IngestQueues], t_cut: float,
                        source: Optional[SourceAdapter] = None) -> _EpochJob:
        """Run the epoch's ingest segment (stages [0, split)), replaying on
        node death — nothing is staged yet, so recovery is pure recompute.

        With a worker-pull ``source`` the batch holds shard descriptors:
        the workers read them inside the segment's first stage, the
        committed item count is worker-reported (``source_items``), and a
        replay attempt re-issues the dead node's descriptors to survivors."""
        attempts = 0
        ereport = RunReport()
        if source is not None:
            ereport.source_descriptors = sum(len(v) for v in batch.values())
            items_in = 0   # worker-reported after the reads
        else:
            items_in = sum(len(v) for v in batch.values())
            # the legacy pushed path: every one of these items crossed the
            # coordinator's ingest queues — the hop the descriptor path deletes
            ereport.source_coordinator_bytes = sum(
                it.nbytes() for v in batch.values() for it in v)
        while True:
            attempts += 1
            live = [n for n in self.nodes if self.alive[n]]
            if not live:
                raise RuntimeError("all nodes failed")
            if source is not None:
                ereport.source_reissues += self._count_lost(batch, live)
            node_sources = self._redistribute(batch, live)
            batch = node_sources   # keep replay bookkeeping per-assignment
            if attempts > 1:
                # whole-segment retry: every retained unit recomputes
                ereport.replayed_rows += _unit_rows(
                    it for v in node_sources.values() for it in v)
            ef = FaultInjection(op_failures=faults.op_failures)
            for n, at_epoch in faults.node_death_in_epoch.items():
                if at_epoch == epoch_index and self.alive.get(n):
                    # die after the epoch's first stage — in the ingest
                    # segment if one exists, else at the store segment's head
                    ef.node_death_after_stage[n] = stage_plans[0].name
            for (n, at_epoch), stname in faults.node_death_at.items():
                if at_epoch == epoch_index and self.alive.get(n):
                    # chaos-harness placement: die right after `stname`
                    ef.node_death_after_stage[n] = stname
            outputs = {n: defaultdict(list) for n in self.nodes}
            if split == 0:
                return _EpochJob(eid, epoch_index, batch, node_sources, outputs,
                                 ef, ereport, attempts, items_in, t_cut,
                                 node_set=live, source=source)
            base_items = ereport.source_items
            try:
                # epoch binds the segment's exchange rounds (no store writes
                # happen before `split`, so the staging protocol is untouched)
                self._execute(stage_plans, node_sources, ef, ereport, self.alive,
                              on_node_death="raise", lane="ingest",
                              outputs=outputs, start_stage=0, end_stage=split,
                              node_set=live, epoch=eid, source=source)
            except NodeFailure as e:
                # lineage-cone site (ISSUE 8): a death surfacing at the
                # segment's LAST stage means every survivor completed the
                # whole ingest segment and dealt into the pinned rounds —
                # the minimal repair is to strip the victim and re-run only
                # its retained shards, leaving the survivors' work standing
                if (self.cone_recovery and split > 0
                        and getattr(e, "stage_index", None) == split - 1
                        # source epochs need the read stage (0) strictly
                        # before the death stage, so the victim's item count
                        # is known to have been worker-reported already
                        and (source is None or split >= 2)
                        and not getattr(self.shuffle, "synchronous", False)
                        and cone_replay_capable(stage_plans, split)):
                    before_patch = ereport.source_items
                    dead = [n for n in live if not self.alive.get(n)]
                    patch = self._cone_patch(eid, dead, batch, stage_plans,
                                             split, ef, ereport, source)
                    if patch is not None:
                        for n in dead:
                            self._record_death(n, eid, sreport, queues)
                            batch[n] = []
                        for n, extra in patch.items():
                            batch.setdefault(n, []).extend(extra)
                        if source is not None:
                            # the victim fully read its shards before dying
                            # (its last-stage completion is what raised) and
                            # the patch re-read them identically — the
                            # pre-patch counter already equals the epoch total
                            items_in = before_patch - base_items
                        survivors = [n for n in self.nodes if self.alive[n]]
                        return _EpochJob(eid, epoch_index, batch, batch,
                                         outputs, ef, ereport, attempts,
                                         items_in, t_cut, node_set=survivors,
                                         source=source)
                self._note_death(str(e), eid, sreport, queues)
                continue
            if source is not None:
                items_in = ereport.source_items - base_items
            return _EpochJob(eid, epoch_index, batch, node_sources, outputs,
                             ef, ereport, attempts, items_in, t_cut,
                             node_set=live, source=source)

    # ------------------------------------------------------------------ epoch
    # epoch batches rebalance with the engine-wide policy: RuntimeEngine
    # ._redistribute (node affinity for live nodes, round-robin spill)

    def _record_death(self, dead: str, eid: int, sreport: StreamReport,
                      queues: Optional[IngestQueues]) -> None:
        """Death bookkeeping alone — routing, failure list, replay list.
        The cone path uses this directly: it must NOT invalidate the whole
        epoch's exchange state, only the producer it strips itself."""
        if queues is not None:   # the worker-pull path has no ingest queues
            queues.mark_dead(dead)
        sreport.node_failures.append(dead)
        if eid not in sreport.replayed_epochs:
            sreport.replayed_epochs.append(eid)
        with self._progress:
            self._progress.notify_all()

    def _note_death(self, dead: str, eid: int, sreport: StreamReport,
                    queues: Optional[IngestQueues]) -> None:
        self._record_death(dead, eid, sreport, queues)
        # the epoch replays wholesale: its in-flight exchange partitions
        # (peer segments, spill files, worker-resident buckets) are invalid
        # — reclaim them everywhere before the replay opens fresh rounds
        self.invalidate_exchange(eid)

    def _probe_executors(self) -> List[str]:
        """Flip ``alive`` for nodes whose process worker already died (pipe
        EOF seen by its receive thread) without any stage future surfacing
        the failure — e.g. a SIGTERM landing after the node finished its
        ingest-segment work.  Thread executors expose no liveness and are
        skipped (their deaths always surface as stage failures)."""
        with self._exec_lock:
            execs = dict(self._executors)
        dead: List[str] = []
        for n, ex in execs.items():
            if self.alive.get(n) and not getattr(ex, "alive", True):
                self.alive[n] = False
                self.store.mark_node_dead(n)
                dead.append(n)
        return dead

    def _cone_patch(self, eid: int, dead_nodes: Sequence[str],
                    batch: Dict[str, List[Any]],
                    stage_plans: List[StagePlan], split: int,
                    ef: FaultInjection, ereport: RunReport,
                    source: Optional[SourceAdapter]
                    ) -> Optional[Dict[str, List[Any]]]:
        """Lineage-cone recovery (ISSUE 8): replay ONLY the dead nodes' cone.

        On a cone-capable plan (no shuffle in the ingest segment: every
        node's resident partitions derive solely from its own retained
        shards) the dead nodes' exchange contribution is stripped
        (``invalidate_producer``) and their shards re-run through the ingest
        segment on survivor targets.  The patch producers merge into the
        epoch's still-pinned rounds — deposits extend node-side buckets,
        manifests merge — so the store segment later adopts a complete
        round, with the survivors' work untouched.

        Returns the patch assignment (shards added per target) on success,
        or None when the patch itself lost a node — the caller falls back
        to whole-epoch replay, whose ``invalidate_exchange`` also cleans up
        the half-merged patch."""
        live = [n for n in self.nodes if self.alive[n]]
        if not live:
            return None
        shards = {n: list(batch.get(n) or []) for n in dead_nodes}
        total_units = sum(len(v) for v in shards.values())
        for n in dead_nodes:
            self.invalidate_producer(eid, n)
        if total_units == 0:
            ereport.cone_replays += 1
            return {}   # the dead node held no inputs: stripping sufficed
        if source is not None:
            ereport.source_reissues += total_units
        patch = {n: v for n, v in self._redistribute(shards, live).items()
                 if v}
        outputs = {n: defaultdict(list) for n in self.nodes}
        try:
            self._execute(stage_plans, patch, ef, ereport, self.alive,
                          on_node_death="raise", lane="ingest",
                          outputs=outputs, start_stage=0, end_stage=split,
                          node_set=list(patch), epoch=eid, source=source)
        except NodeFailure:
            return None
        ereport.cone_replays += 1
        ereport.replayed_rows += _unit_rows(
            it for v in shards.values() for it in v)
        return patch

    def _run_epoch(self, eid: int, epoch_index: int,
                   batch: Dict[str, List[Any]],
                   stage_plans: List[StagePlan], faults: StreamFaultInjection,
                   sreport: StreamReport, queues: Optional[IngestQueues],
                   source: Optional[SourceAdapter] = None) -> EpochReport:
        """Sequential mode: run one micro-batch through the full stage DAG and
        commit it atomically.

        Node death mid-attempt -> abort the staged blocks, mark the node dead,
        replay the *entire epoch* on the survivors.  The commit is the only
        publish point, so a replayed epoch can neither lose items (the full
        input batch — items or shard descriptors — is retained until commit)
        nor double-commit (``begin_epoch`` refuses committed ids)."""
        items_in = sum(len(v) for v in batch.values())
        n_descs = items_in if source is not None else 0
        pushed_bytes = (0 if source is not None else sum(
            it.nbytes() for v in batch.values() for it in v))
        t_cut = time.time()
        attempts = 0
        reissues = 0
        while True:
            attempts += 1
            live = [n for n in self.nodes if self.alive[n]]
            if not live:
                raise RuntimeError("all nodes failed")
            if source is not None:
                reissues += self._count_lost(batch, live)
            node_sources = self._redistribute(batch, live)
            batch = node_sources   # keep replay bookkeeping per-assignment

            # injected mid-epoch deaths for this epoch index -> die after the
            # first stage of the attempt (blocks already staged get aborted)
            ef = FaultInjection(op_failures=faults.op_failures)
            for n, at_epoch in faults.node_death_in_epoch.items():
                if at_epoch == epoch_index and self.alive.get(n):
                    ef.node_death_after_stage[n] = stage_plans[0].name
            for (n, at_epoch), stname in faults.node_death_at.items():
                if at_epoch == epoch_index and self.alive.get(n):
                    ef.node_death_after_stage[n] = stname

            self.store.begin_epoch(eid)
            ereport = RunReport()
            if attempts > 1:
                # sequential mode always replays wholesale: the full DAG ran
                # under one _execute, so a death loses the epoch's exchange
                ereport.replayed_rows = _unit_rows(
                    it for v in node_sources.values() for it in v)
            if source is not None:
                ereport.source_descriptors = n_descs
                ereport.source_reissues = reissues
            else:
                ereport.source_coordinator_bytes = pushed_bytes
            try:
                self._execute(stage_plans, node_sources, ef, ereport,
                              self.alive, on_node_death="raise", epoch=eid,
                              node_set=live, source=source)
            except NodeFailure as e:
                self.store.abort_epoch(eid)
                self._note_death(str(e), eid, sreport, queues)
                continue
            if source is not None:
                items_in = ereport.source_items
            entry = self.store.commit_epoch(eid, n_items=items_in)
            return EpochReport(epoch=eid, items_in=items_in,
                               n_blocks=entry.n_blocks, attempts=attempts,
                               commit_latency_s=time.time() - t_cut,
                               run=ereport)


def stream_ingest(plan: IngestPlan,
                  source: Union[Iterable[IngestItem], SourceAdapter, None],
                  store: DataStore,
                  *, optimize: bool = True,
                  faults: Optional[StreamFaultInjection] = None,
                  max_epochs: Optional[int] = None,
                  **engine_kw: Any) -> StreamReport:
    """One-call entry point: stream a source through an ingestion plan."""
    eng = StreamingRuntimeEngine(store, **engine_kw)
    try:
        return eng.run_stream(plan, source, faults=faults, optimize=optimize,
                              max_epochs=max_epochs)
    finally:
        eng.close()   # one-shot engine: release node executors + shuffle writer


def stream_ingest_multi(plans: Union[Sequence[IngestPlan], Any],
                        source: Iterable[IngestItem],
                        stores: Union[DataStore, Sequence[DataStore]],
                        *, optimize: bool = True,
                        faults: Optional[Union[StreamFaultInjection,
                                               Dict[str, StreamFaultInjection]]] = None,
                        max_epochs: Optional[int] = None,
                        **engine_kw: Any) -> Dict[str, StreamReport]:
    """Fan one source into several plans (``FEED ... INTO plan1, plan2``).

    ``plans`` is a sequence of IngestPlans, or any object with a ``.plans``
    attribute (the language front-end's FeedSpec).  Each plan runs in its own
    StreamingRuntimeEngine over its own DataStore from ``stores`` — one store
    per plan: concurrent engines must not share an epoch-id space.  A single
    ``StreamFaultInjection`` applies to every pipeline; a dict maps plan name
    -> injection.  Returns plan name -> StreamReport.
    """
    plan_list: List[IngestPlan] = list(getattr(plans, "plans", plans))
    store_list = list(stores) if isinstance(stores, (list, tuple)) else [stores]
    if len(store_list) != len(plan_list):
        raise ValueError(f"{len(plan_list)} plans need {len(plan_list)} stores, "
                         f"got {len(store_list)}")
    roots = {s.root for s in store_list}
    if len(roots) != len(store_list):
        raise ValueError("each fanned-out plan needs its own DataStore "
                         "(engines must not share an epoch-id space)")

    names = [p.name for p in plan_list]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate plan names {names}: rename plans so "
                         f"faults/results can be addressed deterministically")

    engines: List[StreamingRuntimeEngine] = []
    joints: List[IngestQueues] = []
    for plan, st in zip(plan_list, store_list):
        eng = StreamingRuntimeEngine(st, **engine_kw)
        engines.append(eng)
        joints.append(IngestQueues.manual(eng.nodes, eng._config(plan).capacity))
    distributor = FeedDistributor(source, joints)

    results: Dict[str, StreamReport] = {}
    errors: List[Tuple[str, BaseException]] = []

    def run_one(name: str, eng: StreamingRuntimeEngine, plan: IngestPlan,
                joint: IngestQueues) -> None:
        f = faults.get(name) if isinstance(faults, dict) else faults
        try:
            results[name] = eng.run_stream(plan, queues=joint, faults=f,
                                           optimize=optimize, max_epochs=max_epochs)
        except BaseException as e:
            errors.append((name, e))
            joint.stop()   # unblock the distributor for this joint

    threads = [threading.Thread(target=run_one, args=(nm, e, p, j), daemon=True)
               for nm, e, p, j in zip(names, engines, plan_list, joints)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    distributor.join()
    for eng in engines:
        eng.close()
    if errors:
        raise errors[0][1]
    return results
