"""The flexible decoder: one parameterized definition covering all 10 archs.

Structure (cfg.pattern × cfg.pattern_repeats, then cfg.remainder):

  tokens ──embed──▶ [ scan over repeats: pattern blocks ] ─▶ [remainder] ─▶ norm ─▶ unembed

Block kinds: attn / swa / local (GQA self-attention, optionally windowed),
cross (cross-attention to stubbed encoder embeddings), ssd (Mamba-2),
rec (RG-LRU).  Each block is pre-norm residual: x + mixer(norm(x)), then
x + mlp(norm(x)) where the MLP may be dense or MoE ("moe" mlp_kind).

Three entry points (pure functions of (cfg, params, batch)):
  forward(...)            — full-sequence training forward -> hidden states
  prefill(...)            — forward + populate decode caches, last-pos logits
  decode_step(...)        — one-token serve step against caches

Caches are ParamDef trees too (see ``cache_defs``) so the AOT dry-run can
shard them exactly like parameters.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from .config import ModelConfig
from .layers import embed, embedding_defs, mlp, mlp_defs, rmsnorm, rmsnorm_defs, unembed
from .moe import moe_defs, moe_ffn
from .params import ParamDef, ParamTree, stack_tree
from .rglru import rglru_defs, rglru_mixer
from .ssd import ssd_defs, ssd_dims, ssd_mixer

ATTN_KINDS = ("attn", "swa", "local", "cross")


# ======================================================================= defs
def attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": ParamDef((D, H, hd), ("embed", "heads", None), dt),
        "wk": ParamDef((D, KV, hd), ("embed", "kv", None), dt),
        "wv": ParamDef((D, KV, hd), ("embed", "kv", None), dt),
        "wo": ParamDef((H, hd, D), ("heads", None, "embed"), dt, "scaled"),
    }


def block_defs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d: Dict[str, Any] = {"pre_norm": rmsnorm_defs(cfg.d_model)}
    if kind in ATTN_KINDS:
        d["attn"] = attn_defs(cfg)
        d["mlp_norm"] = rmsnorm_defs(cfg.d_model)
        d["mlp"] = moe_defs(cfg) if cfg.mlp_kind == "moe" else mlp_defs(cfg)
    elif kind == "ssd":
        d["mixer"] = ssd_defs(cfg)
        if cfg.mlp_kind != "none":
            d["mlp_norm"] = rmsnorm_defs(cfg.d_model)
            d["mlp"] = mlp_defs(cfg)
    elif kind == "rec":
        d["mixer"] = rglru_defs(cfg)
        d["mlp_norm"] = rmsnorm_defs(cfg.d_model)
        d["mlp"] = mlp_defs(cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return d


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    """The full ParamDef tree.  Pattern blocks get a leading scan dim."""
    defs: Dict[str, Any] = {"embed": embedding_defs(cfg)}
    defs["pattern"] = ([stack_tree(block_defs(cfg, k), cfg.pattern_repeats)
                        for k in cfg.pattern] if cfg.pattern_repeats > 0 else [])
    defs["remainder"] = [block_defs(cfg, k) for k in cfg.remainder]
    defs["final_norm"] = rmsnorm_defs(cfg.d_model)
    return defs


# ----------------------------------------------------------------- cache defs
def _attn_cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    window = cfg.window if kind in ("swa", "local") else None
    return min(window, max_len) if window else max_len


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Decode-state ParamDef tree mirroring the block structure."""
    dt = jnp.dtype(cfg.dtype)
    KV, hd = cfg.n_kv_heads, cfg.head_dim

    def one(kind: str) -> Dict[str, Any]:
        if kind == "cross":
            Ne = cfg.cross_attn_kv_len
            return {"k": ParamDef((batch, Ne, KV, hd), ("cache_batch", "cache_len", "kv", None), dt, "zeros"),
                    "v": ParamDef((batch, Ne, KV, hd), ("cache_batch", "cache_len", "kv", None), dt, "zeros")}
        if kind in ATTN_KINDS:
            C = _attn_cache_len(cfg, kind, max_len)
            return {"k": ParamDef((batch, C, KV, hd), ("cache_batch", "cache_len", "kv", None), dt, "zeros"),
                    "v": ParamDef((batch, C, KV, hd), ("cache_batch", "cache_len", "kv", None), dt, "zeros")}
        if kind == "ssd":
            d_in, nh, P, G, N = ssd_dims(cfg)
            s = cfg.ssm
            conv_ch = d_in + 2 * G * N
            return {"conv": ParamDef((batch, s.conv_width - 1, conv_ch),
                                     ("cache_batch", None, "heads"), dt, "zeros"),
                    "ssm": ParamDef((batch, nh, P, N),
                                    ("cache_batch", "heads", None, None), jnp.float32, "zeros")}
        if kind == "rec":
            r = cfg.rglru
            W = (r.lru_width or cfg.d_model) if r else cfg.d_model
            K = r.conv_width if r else 4
            return {"conv": ParamDef((batch, K - 1, W), ("cache_batch", None, "ffn"), dt, "zeros"),
                    "h": ParamDef((batch, W), ("cache_batch", "ffn"), jnp.float32, "zeros")}
        raise ValueError(kind)

    out: Dict[str, Any] = {}
    out["pattern"] = ([stack_tree(one(k), cfg.pattern_repeats)
                       for k in cfg.pattern] if cfg.pattern_repeats > 0 else [])
    out["remainder"] = [one(k) for k in cfg.remainder]
    return out


# ==================================================================== blocks
def _pin_w(constrain, name: str, w: jax.Array) -> jax.Array:
    return constrain(name, w) if constrain is not None else w


def _self_attention(cfg: ModelConfig, kind: str, p: Dict[str, jax.Array],
                    x: jax.Array, seg: jax.Array, pos: jax.Array,
                    constrain=None) -> jax.Array:
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, _pin_w(constrain, "w_q", p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", x, _pin_w(constrain, "w_kv", p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", x, _pin_w(constrain, "w_kv", p["wv"]))
    q = attn.rope(q, pos, cfg.rope_theta)
    k = attn.rope(k, pos, cfg.rope_theta)
    window = cfg.window if kind in ("swa", "local") else None
    if window is not None and S % window == 0 and S // window >= 2:
        o = attn.attention_local(q, k, v, pos, pos, seg, seg, window=window)
    elif cfg.attn_impl == "chunked" and S > cfg.attn_chunk:
        o = attn.attention_chunked(q, k, v, pos, pos, seg, seg,
                                   chunk=cfg.attn_chunk, window=window,
                                   unroll=cfg.unroll_scans,
                                   logits_dtype=jnp.dtype(cfg.attn_logits_dtype))
    else:
        o = attn.attention_naive(q, k, v, pos, pos, seg, seg, window=window)
    return jnp.einsum("bshk,hkd->bsd", o, _pin_w(constrain, "w_o", p["wo"]))


def _cross_attention(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
                     seg: jax.Array, enc: jax.Array, constrain=None) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, _pin_w(constrain, "w_q", p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", enc.astype(x.dtype),
                   _pin_w(constrain, "w_kv", p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", enc.astype(x.dtype),
                   _pin_w(constrain, "w_kv", p["wv"]))
    o = attn.attention_cross(q, k, v, seg)
    return jnp.einsum("bshk,hkd->bsd", o, _pin_w(constrain, "w_o", p["wo"]))


def _apply_mlp(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
               constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (mlp_out, moe_lb_loss)."""
    if cfg.mlp_kind == "moe":
        out, aux = moe_ffn(p, x, cfg, constrain=constrain)
        return out, aux["lb_loss"]
    if constrain is not None and cfg.mlp_kind in ("swiglu", "geglu", "gelu"):
        p = dict(p)
        for key in ("wi_gate", "wi_up", "wi"):
            if key in p:
                p[key] = constrain("w_in", p[key])
        p["wo"] = constrain("w_out", p["wo"])
    return mlp(p, x, cfg.mlp_kind), jnp.zeros((), jnp.float32)


def apply_block(cfg: ModelConfig, kind: str, p: Dict[str, Any], x: jax.Array,
                *, seg: jax.Array, pos: jax.Array,
                enc: Optional[jax.Array] = None,
                constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Training/prefill-forward block.  Returns (x, moe_aux_loss)."""
    h = rmsnorm(p["pre_norm"], x, cfg.norm_eps)
    if kind in ("attn", "swa", "local"):
        x = x + _self_attention(cfg, kind, p["attn"], h, seg, pos)
    elif kind == "cross":
        assert enc is not None, "cross block needs encoder embeddings"
        x = x + _cross_attention(cfg, p["attn"], h, seg, enc)
    elif kind == "ssd":
        out, _ = ssd_mixer(p["mixer"], h, cfg, seg=seg)
        x = x + out
    elif kind == "rec":
        out, _ = rglru_mixer(p["mixer"], h, cfg, seg=seg)
        x = x + out
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in p:
        h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        out, aux = _apply_mlp(cfg, p["mlp"], h, constrain=constrain)
        x = x + out
    return x, aux


def _remat_policy(cfg: ModelConfig):
    cp = jax.checkpoint_policies
    return {
        "nothing": cp.nothing_saveable,
        "dots": cp.dots_saveable,
        "save_layer_inputs": cp.nothing_saveable,
        "dots_no_batch": cp.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]


# =================================================================== forward
def forward(cfg: ModelConfig, params: Dict[str, Any], batch: Dict[str, jax.Array],
            constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  batch: tokens/segments/positions (B,S)
    [+ encoder_embeds (B,Ne,D)].  Returns (hidden (B,S,D), moe_aux_loss).

    ``constrain("hidden", x)`` re-pins the residual stream after every block:
    without it, GSPMD sometimes migrates the FSDP params' "data" sharding onto
    the *embed* dim of activation gradients (full-batch all-reduces in the
    backward — verified on gemma-7b)."""
    seg = batch["segments"]
    pos = batch["positions"]
    enc = batch.get("encoder_embeds")
    pin = (lambda h: constrain("hidden", h)) if constrain else (lambda h: h)
    x = pin(embed(params["embed"], batch["tokens"], cfg))

    def body(carry, layer_params):
        h, aux = carry
        for i, kind in enumerate(cfg.pattern):
            h, a = apply_block(cfg, kind, layer_params[i], h,
                               seg=seg, pos=pos, enc=enc, constrain=constrain)
            h = pin(h)
            aux = aux + a
        return (h, aux), None

    aux = jnp.zeros((), jnp.float32)
    if cfg.pattern_repeats > 0:
        body_r = jax.checkpoint(body, policy=_remat_policy(cfg),
                                prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body_r, (x, aux), params["pattern"])
    for i, kind in enumerate(cfg.remainder):
        # per-layer remat for unrolled blocks (same policy as the scan body,
        # so production and dry-run-cost graphs do the same recompute work)
        blk = jax.checkpoint(
            lambda p, h, k=kind: apply_block(cfg, k, p, h, seg=seg, pos=pos,
                                             enc=enc, constrain=constrain),
            policy=_remat_policy(cfg), prevent_cse=False)
        x, a = blk(params["remainder"][i], x)
        x = pin(x)
        aux = aux + a
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def logits_fn(cfg: ModelConfig, params: Dict[str, Any],
              hidden: jax.Array) -> jax.Array:
    return unembed(params["embed"], hidden, cfg)


# ==================================================================== decode
def _decode_attn(cfg: ModelConfig, kind: str, p: Dict[str, Any],
                 x: jax.Array, cache: Dict[str, jax.Array], pos: jax.Array,
                 constrain=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token self-attention against a (ring) KV cache.  x (B,1,D).

    ``pos`` is a scalar (uniform batch — the dry-run/production fast path,
    dynamic-update-slice cache write) or a (B,) vector (continuous batching:
    per-slot positions, scatter cache write)."""
    B = x.shape[0]
    C = cache["k"].shape[1]
    window = cfg.window if kind in ("swa", "local") else None
    q = jnp.einsum("bsd,dhk->bshk", x, _pin_w(constrain, "w_q", p["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", x, _pin_w(constrain, "w_kv", p["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", x, _pin_w(constrain, "w_kv", p["wv"]))
    per_row = pos.ndim == 1
    posb = (pos[:, None] if per_row
            else jnp.broadcast_to(pos[None, None], (B, 1))).astype(jnp.int32)
    q = attn.rope(q, posb, cfg.rope_theta)
    k = attn.rope(k, posb, cfg.rope_theta)
    slot = (pos % C) if window is not None else jnp.minimum(pos, C - 1)
    if per_row:
        rows = jnp.arange(B)
        k_cache = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    n_valid = jnp.minimum(pos + 1, C)
    cache_len = jnp.broadcast_to(n_valid, (B,))
    o = attn.attention_decode(q, k_cache, v_cache, cache_len, softcap=0.0)
    return (jnp.einsum("bshk,hkd->bsd", o, _pin_w(constrain, "w_o", p["wo"])),
            {"k": k_cache, "v": v_cache})


def _decode_cross(cfg: ModelConfig, p: Dict[str, Any], x: jax.Array,
                  cache: Dict[str, jax.Array]) -> jax.Array:
    """Cross-attention during decode: cache holds projected encoder kv."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    seg = jnp.ones(x.shape[:2], jnp.int32)
    o = attn.attention_cross(q, cache["k"].astype(x.dtype),
                             cache["v"].astype(x.dtype), seg)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def decode_block(cfg: ModelConfig, kind: str, p: Dict[str, Any], x: jax.Array,
                 cache: Dict[str, Any], pos: jax.Array, constrain=None
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
    h = rmsnorm(p["pre_norm"], x, cfg.norm_eps)
    if kind in ("attn", "swa", "local"):
        out, cache = _decode_attn(cfg, kind, p["attn"], h, cache, pos,
                                  constrain=constrain)
        x = x + out
    elif kind == "cross":
        x = x + _decode_cross(cfg, p["attn"], h, cache)
    elif kind == "ssd":
        out, cache = ssd_mixer(p["mixer"], h, cfg, decode_state=cache)
        x = x + out
    elif kind == "rec":
        out, cache = rglru_mixer(p["mixer"], h, cfg, decode_state=cache)
        x = x + out
    if "mlp" in p:
        h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        out, _ = _apply_mlp(cfg, p["mlp"], h, constrain=constrain)
        x = x + out
    return x, cache


def decode_step(cfg: ModelConfig, params: Dict[str, Any],
                cache: Dict[str, Any], tokens: jax.Array, pos: jax.Array,
                constrain=None) -> Tuple[jax.Array, Dict[str, Any]]:
    """One serve step: tokens (B,1) at position ``pos`` (scalar int32, or a
    (B,) vector of per-slot positions for continuous batching).
    Returns (logits (B,1,V), new cache)."""
    pos = jnp.asarray(pos, jnp.int32)
    pin = (lambda h: constrain("hidden", h)) if constrain else (lambda h: h)
    x = pin(embed(params["embed"], tokens, cfg))

    def body(h, xs):
        layer_params, layer_cache = xs
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            h, nc = decode_block(cfg, kind, layer_params[i], h,
                                 layer_cache[i], pos, constrain=constrain)
            h = pin(h)
            new_caches.append(nc)
        return h, new_caches

    new_cache: Dict[str, Any] = {"pattern": [], "remainder": []}
    if cfg.pattern_repeats > 0:
        x, new_cache["pattern"] = jax.lax.scan(
            body, x, (params["pattern"], cache["pattern"]))
    for i, kind in enumerate(cfg.remainder):
        x, nc = decode_block(cfg, kind, params["remainder"][i], x,
                             cache["remainder"][i], pos, constrain=constrain)
        x = pin(x)
        new_cache["remainder"].append(nc)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    if constrain is not None:
        logits = constrain("logits", logits)
    return logits, new_cache


# =================================================================== prefill
def prefill(cfg: ModelConfig, params: Dict[str, Any],
            batch: Dict[str, jax.Array], max_len: int, constrain=None
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Forward + cache population.  Returns (last-position logits, cache).

    Cache layout matches ``cache_defs(cfg, B, max_len)``: full-attention
    caches hold positions [0, S); windowed caches hold the last ``window``
    keys in ring order (slot = pos % window).
    """
    seg, pos = batch["segments"], batch["positions"]
    enc = batch.get("encoder_embeds")
    B, S = batch["tokens"].shape
    pin = (lambda h: constrain("hidden", h)) if constrain else (lambda h: h)
    x = pin(embed(params["embed"], batch["tokens"], cfg))

    def fill_attn(kind: str, p: Dict[str, Any], h: jax.Array) -> Dict[str, jax.Array]:
        if kind == "cross":
            k = jnp.einsum("bsd,dhk->bshk", enc.astype(h.dtype),
                           _pin_w(constrain, "w_kv", p["attn"]["wk"]))
            v = jnp.einsum("bsd,dhk->bshk", enc.astype(h.dtype),
                           _pin_w(constrain, "w_kv", p["attn"]["wv"]))
            return {"k": k, "v": v}
        C = _attn_cache_len(cfg, kind, max_len)
        k = jnp.einsum("bsd,dhk->bshk", h, _pin_w(constrain, "w_kv", p["attn"]["wk"]))
        k = attn.rope(k, pos, cfg.rope_theta)
        v = jnp.einsum("bsd,dhk->bshk", h, _pin_w(constrain, "w_kv", p["attn"]["wv"]))
        if C >= S:
            pad = jnp.zeros((B, C - S) + k.shape[2:], k.dtype)
            return {"k": jnp.concatenate([k, pad], 1),
                    "v": jnp.concatenate([v, pad], 1)}
        # ring: keep last C keys, placed at slot = pos % C
        kl, vl = k[:, S - C:], v[:, S - C:]
        shift = S % C
        idx = (jnp.arange(C) - shift) % C
        return {"k": kl[:, idx], "v": vl[:, idx]}

    def run_block(kind: str, p: Dict[str, Any], h: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
        hn = rmsnorm(p["pre_norm"], h, cfg.norm_eps)
        if kind in ATTN_KINDS:
            c = fill_attn(kind, p, hn)
            if kind == "cross":
                h = h + _cross_attention(cfg, p["attn"], hn, seg, enc,
                                         constrain=constrain)
            else:
                h = h + _self_attention(cfg, kind, p["attn"], hn, seg, pos,
                                        constrain=constrain)
        elif kind == "ssd":
            out, c = ssd_mixer(p["mixer"], hn, cfg, seg=seg)
            h = h + out
        elif kind == "rec":
            out, c = rglru_mixer(p["mixer"], hn, cfg, seg=seg)
            h = h + out
        if "mlp" in p:
            hn = rmsnorm(p["mlp_norm"], h, cfg.norm_eps)
            out, _ = _apply_mlp(cfg, p["mlp"], hn, constrain=constrain)
            h = h + out
        return h, c

    def body(h, layer_params):
        caches = []
        for i, kind in enumerate(cfg.pattern):
            h, c = run_block(kind, layer_params[i], h)
            h = pin(h)
            caches.append(c)
        return h, caches

    cache: Dict[str, Any] = {"pattern": [], "remainder": []}
    if cfg.pattern_repeats > 0:
        body_r = jax.checkpoint(body, policy=_remat_policy(cfg), prevent_cse=False)
        x, cache["pattern"] = jax.lax.scan(body_r, x, params["pattern"])
    for i, kind in enumerate(cfg.remainder):
        x, c = run_block(kind, params["remainder"][i], x)
        x = pin(x)
        cache["remainder"].append(c)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1:], cfg)
    return logits, cache
