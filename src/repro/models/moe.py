"""Mixture-of-Experts feed-forward with capacity-based einsum dispatch.

TPU-native MoE (GShard/Switch style): tokens are routed with a top-k softmax
router, then dispatched to experts through dense one-hot einsums so the whole
layer is static-shaped (MXU-friendly, shardable with pjit).  The expert dim is
sharded over the "model" mesh axis (expert parallelism) when
``num_experts % model_shards == 0``; otherwise experts are replicated and the
expert hidden dim is tensor-parallel instead (mixtral-8x22b on a 16-way model
axis).

Dispatch cost control: routing is done within fixed-size *groups* of tokens
(``group_size``), so the dispatch/combine einsums cost
``O(k · capacity_factor · group · tokens · d_model)`` instead of
``O(tokens² · …)`` — the standard GShard trick.

Capacity-based dispatch drops overflow tokens (counted in aux stats) which
keeps compiled FLOPs proportional to *active* parameters — exactly what the
roofline's ``6·N_active·D`` model expects.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .params import ParamDef


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    m = cfg.moe or MoEConfig()
    D, F, E = cfg.d_model, m.d_ff_expert, m.num_experts
    dt = jnp.dtype(cfg.param_dtype)
    defs: Dict[str, ParamDef] = {
        "router": ParamDef((D, E), ("embed", None), jnp.float32),
        "wi_gate": ParamDef((E, D, F), ("experts", "embed", "ffn"), dt),
        "wi_up": ParamDef((E, D, F), ("experts", "embed", "ffn"), dt),
        "wo": ParamDef((E, F, D), ("experts", "ffn", "embed"), dt, "scaled"),
    }
    if m.num_shared_experts:
        S = m.num_shared_experts * F
        defs["shared_wi_gate"] = ParamDef((D, S), ("embed", "ffn"), dt)
        defs["shared_wi_up"] = ParamDef((D, S), ("embed", "ffn"), dt)
        defs["shared_wo"] = ParamDef((S, D), ("ffn", "embed"), dt, "scaled")
    return defs


def _capacity(group: int, m: MoEConfig) -> int:
    cap = int(group * m.top_k * m.capacity_factor / m.num_experts)
    return max(4, ((cap + 3) // 4) * 4)  # 4-aligned, never zero


def moe_ffn(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
            group_size: int = 2048, constrain=None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) -> (B, S, D), aux stats (load-balance loss, drop fraction).

    Grouped dispatch: (n_groups, G, D) tokens -> (n_groups, E, C, D) expert
    slices -> expert MLP -> combined back.  All einsums are static-shaped.
    """
    m = cfg.moe or MoEConfig()
    B, S, D = x.shape
    T = B * S
    G = min(group_size, T)
    if T % G:
        G = T  # fallback: single group (tiny smoke configs)
    n = T // G
    C = _capacity(G, m)
    xg = x.reshape(n, G, D)
    if constrain is not None:
        # GShard layout: groups sharded over data AND model so dispatch/
        # combine lower as all-to-alls instead of dense partial-sum
        # all-reduces (the combine AR moves the full (n,G,D) stream twice;
        # the a2a moves each expert slot once)
        xg = constrain("moe_tokens", xg)

    # ---- router (fp32 for numerics)
    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (n, G, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)               # (n, G, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- capacity assignment: position of each (token, k) within its expert.
    # Counting is exact int32 (bf16 cumsum breaks past 256); the one-hot
    # masks are bf16 — they only ever hold 0/1, and f32 masks doubled the
    # router-side HBM/collective bytes (kimi: 1.6 GB f32 all-gathers).
    onehot_i = jax.nn.one_hot(gate_idx, m.num_experts, dtype=jnp.int32)  # (n,G,k,E)
    flat = onehot_i.reshape(n, G * m.top_k, m.num_experts)
    pos = jnp.cumsum(flat, axis=1) * flat - 1                  # slot per assignment
    pos = pos.reshape(n, G, m.top_k, m.num_experts)
    in_cap = (pos >= 0) & (pos < C)
    slot_oh = jax.nn.one_hot(pos, C, dtype=x.dtype)
    slot_oh = slot_oh * in_cap[..., None].astype(x.dtype)      # (n,G,k,E,C)

    # combine weights: (n, G, E, C); dispatch mask is its support
    onehot = onehot_i.astype(x.dtype)
    combine = jnp.einsum("ngk,ngkec->ngec", gate_vals.astype(x.dtype),
                         slot_oh * onehot[..., None])
    dispatch = (combine > 0.0).astype(x.dtype)

    # ---- dispatch -> expert MLP -> combine
    pin = constrain if constrain is not None else (lambda name, v: v)
    wi_gate = pin("w_moe", p["wi_gate"])   # gathered-over-data, EP over model
    wi_up = pin("w_moe", p["wi_up"])
    wo = pin("w_moe_out", p["wo"])
    expert_in = pin("moe_ecd", jnp.einsum("ngec,ngd->necd", dispatch, xg))
    act = jax.nn.silu if cfg.mlp_kind != "geglu" else jax.nn.gelu
    h = act(jnp.einsum("necd,edf->necf", expert_in, wi_gate))
    h = h * jnp.einsum("necd,edf->necf", expert_in, wi_up)
    expert_out = pin("moe_ecd", jnp.einsum("necf,efd->necd", h, wo))  # (n,E,C,D)
    out = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), expert_out)

    if m.num_shared_experts:
        g = jax.nn.silu(xg @ p["shared_wi_gate"])
        out = out + (g * (xg @ p["shared_wi_up"])) @ p["shared_wo"]

    # ---- aux: load-balance loss (Switch) + dropped fraction
    me = probs.mean(axis=1)                                    # (n, E)
    ce = onehot_i.sum(axis=2).mean(axis=1).astype(jnp.float32)  # (n, E) routed
    lb_loss = m.num_experts * jnp.mean(jnp.sum(me * ce, axis=-1))
    dropped = 1.0 - jnp.sum(in_cap & (onehot_i > 0)) / (n * G * m.top_k)
    return out.reshape(B, S, D), {"lb_loss": lb_loss, "drop_frac": dropped}
