"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence:  r_t = sigmoid(W_a x_t + b_a)        (recurrence gate)
             i_t = sigmoid(W_x x_t + b_x)        (input gate)
             log a_t = -c * softplus(Lambda) * r_t
             h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill runs the diagonal recurrence with an associative scan
(O(S log S) depth, O(S) work); decode is the O(1) per-token update — the
recurrent state is (B, W) per layer regardless of context, which is why
recurrentgemma runs the ``long_500k`` cell.

The full residual block is: conv1d + RG-LRU on one branch, gated by
GeLU(linear) on the other (the "recurrent block" of the paper).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, RGLRUConfig
from .params import ParamDef
from .ssd import causal_conv1d


def rglru_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    r = cfg.rglru or RGLRUConfig()
    D = cfg.d_model
    W = r.lru_width or D
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "in_x": ParamDef((D, W), ("embed", "ffn"), dt),
        "in_gate": ParamDef((D, W), ("embed", "ffn"), dt),
        "conv_w": ParamDef((r.conv_width, W), (None, "ffn"), dt),
        "conv_b": ParamDef((W,), ("ffn",), dt, "zeros"),
        "gate_a": ParamDef((W, W), ("ffn", None), dt),
        "gate_x": ParamDef((W, W), ("ffn", None), dt),
        "gate_a_b": ParamDef((W,), (None,), jnp.float32, "zeros"),
        "gate_x_b": ParamDef((W,), (None,), jnp.float32, "zeros"),
        "a_param": ParamDef((W,), (None,), jnp.float32, "a_param"),
        "out": ParamDef((W, D), ("ffn", "embed"), dt, "scaled"),
    }


def _rglru_scan(log_a: jax.Array, gx: jax.Array,
                init: Optional[jax.Array], seg: Optional[jax.Array]
                ) -> Tuple[jax.Array, jax.Array]:
    """Associative scan of h_t = a_t h_{t-1} + gx_t over time.

    log_a, gx: (B, S, W) fp32.  Returns (h (B,S,W), final_state (B,W)).
    """
    if seg is not None:
        B, S = seg.shape
        boundary = jnp.concatenate(
            [jnp.zeros((B, 1), bool), seg[:, 1:] != seg[:, :-1]], axis=1)
        log_a = jnp.where(boundary[..., None], -1e9, log_a)
    if init is not None:
        # fold the initial state in as a virtual step 0 contribution
        gx = gx.at[:, 0].add(jnp.exp(log_a[:, 0]) * init.astype(gx.dtype))

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 + a2, jnp.exp(a2) * x1 + x2

    a_cum, h = jax.lax.associative_scan(combine, (log_a, gx), axis=1)
    return h, h[:, -1]


def rglru_mixer(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig, *,
                seg: Optional[jax.Array] = None,
                decode_state: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full recurrent block.  x (B,S,D) -> (B,S,D).

    decode_state: {"conv": (B,K-1,W), "h": (B,W)} for S==1 decode.
    """
    r = cfg.rglru or RGLRUConfig()
    B, S, D = x.shape
    W = r.lru_width or D

    branch = x @ p["in_x"]                                     # (B,S,W)
    gate = jax.nn.gelu((x @ p["in_gate"]).astype(jnp.float32), approximate=True)

    conv_state = decode_state["conv"] if decode_state is not None else None
    u, new_conv = causal_conv1d(branch, p["conv_w"], p["conv_b"], conv_state)

    uf = u.astype(jnp.float32)
    rt = jax.nn.sigmoid(uf @ p["gate_a"].astype(jnp.float32) + p["gate_a_b"])
    it = jax.nn.sigmoid(uf @ p["gate_x"].astype(jnp.float32) + p["gate_x_b"])
    c = r.c_exponent
    log_a = -c * jax.nn.softplus(p["a_param"])[None, None, :] * rt  # (B,S,W)
    gx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (it * uf)

    if decode_state is not None:
        h1 = (jnp.exp(log_a[:, 0]) * decode_state["h"].astype(jnp.float32)
              + gx[:, 0])
        h = h1[:, None]
        new_state: Optional[Dict[str, jax.Array]] = {"conv": new_conv, "h": h1}
    else:
        init = None
        h, final = _rglru_scan(log_a, gx, init, seg)
        new_state = {"conv": new_conv, "h": final}

    y = (h * gate).astype(x.dtype)
    return y @ p["out"], new_state
