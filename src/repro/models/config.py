"""Model configuration for the flexible decoder family.

One parameterized definition covers all 10 assigned architectures: block
*patterns* (scanned super-blocks + unrolled remainder) express heterogeneous
stacks (RG-LRU/attn interleave, cross-attn every Nth layer); mixer and MLP
kinds select attention / SSD / RG-LRU and dense / MoE feed-forwards.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

# block kinds: what the mixer is
#   attn   — causal self attention (GQA; window=None -> full)
#   swa    — sliding-window attention (window tokens)
#   local  — local attention (alias of swa; recurrentgemma naming)
#   ssd    — Mamba-2 state-space duality mixer (no separate MLP unless d_ff>0)
#   rec    — RG-LRU recurrent block
#   cross  — cross-attention to encoder/vision embeddings (+ self mlp)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 2048
    capacity_factor: float = 1.25
    # how many experts live on each model shard (num_experts % shard == 0 to
    # use expert parallelism; otherwise experts are replicated and d_ff is TP)
    expert_parallel: bool = True
    num_shared_experts: int = 0     # kimi-k2 has 1 shared expert
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    num_heads: int = 0            # derived: d_inner / head_dim if 0
    num_groups: int = 1           # G (B/C projections shared per group)
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256         # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0            # 0 -> d_model
    conv_width: int = 4
    c_exponent: float = 8.0       # a = a_param^(c * r)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 4
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 2048
    vocab_size: int = 32000

    # stack structure: pattern is scanned `pattern_repeats` times, remainder
    # layers are unrolled after the scan.  pattern of ("attn",) with
    # repeats=num_layers is the homogeneous case.
    pattern: Tuple[str, ...] = ("attn",)
    remainder: Tuple[str, ...] = ()

    mlp_kind: str = "swiglu"      # swiglu | geglu | gelu | moe | none
    window: Optional[int] = None  # SWA/local attention window
    cross_attn_kv_len: int = 0    # vlm: number of vision tokens (stub frontend)

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tied_embeddings: bool = False
    embed_scale: bool = False      # gemma-style sqrt(d_model) scaling
    logit_softcap: float = 0.0

    dtype: str = "bfloat16"        # activations/params compute dtype
    param_dtype: str = "bfloat16"
    attn_impl: str = "chunked"     # chunked | naive
    attn_chunk: int = 1024         # KV chunk for chunked attention
    # dtype of materialized attention logits/probs tiles.  fp32 (default) is
    # the training-safe choice; bf16 halves the dominant S×chunk HBM traffic
    # on serve paths (stats m/l stay fp32 — only the tiles are rounded).
    attn_logits_dtype: str = "float32"
    # dry-run cost path: unroll every lax.scan so XLA cost analysis (which
    # counts while-loop bodies once) sees the full per-step work
    unroll_scans: bool = False

    # distribution
    optimizer: str = "adamw"       # adamw | adafactor (1T-scale)
    remat_policy: str = "save_layer_inputs"   # nothing | save_layer_inputs | dots
    sharding_overrides: Dict[str, Any] = field(default_factory=dict, hash=False)

    # serving
    max_cache_len: int = 32768

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.n_heads))
        # pattern bookkeeping
        total_pat = len(self.pattern)
        if total_pat and (self.num_layers - len(self.remainder)) % total_pat:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} minus remainder "
                f"{len(self.remainder)} not divisible by pattern {self.pattern}")

    @property
    def pattern_repeats(self) -> int:
        if not self.pattern:
            return 0
        return (self.num_layers - len(self.remainder)) // len(self.pattern)

    def unrolled(self) -> "ModelConfig":
        """Equivalent config with every layer unrolled (pattern -> remainder).
        Used by the dry-run cost path: XLA cost analysis counts while-loop
        bodies once, so per-step FLOPs are only correct on unrolled graphs."""
        layers = tuple(self.pattern) * self.pattern_repeats + tuple(self.remainder)
        return self.replace(pattern=(), remainder=layers)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- size audit
    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS = 6·N·D in §Roofline)."""
        D, V = self.d_model, self.vocab_size
        total = V * D  # embedding
        if not self.tied_embeddings:
            total += V * D
        kinds = list(self.pattern) * self.pattern_repeats + list(self.remainder)
        for kind in kinds:
            total += self._block_params(kind)
        total += D  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (= param_count for dense; MoE counts top_k
        + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        D = self.d_model
        m = self.moe
        full_expert = 3 * D * m.d_ff_expert
        inactive = (m.num_experts - m.top_k) * full_expert
        kinds = list(self.pattern) * self.pattern_repeats + list(self.remainder)
        n_moe_layers = sum(1 for k in kinds if k in ("attn", "swa", "local", "cross"))
        return self.param_count() - n_moe_layers * inactive

    def _block_params(self, kind: str) -> int:
        D, F = self.d_model, self.d_ff
        H, KV, hd = self.n_heads, self.n_kv_heads, self.head_dim
        norms = 2 * D
        if kind in ("attn", "swa", "local"):
            attn = D * H * hd + 2 * D * KV * hd + H * hd * D
            return attn + self._mlp_params() + norms
        if kind == "cross":
            attn = D * H * hd + 2 * D * KV * hd + H * hd * D
            return attn + self._mlp_params() + norms + D  # extra kv norm/gate
        if kind == "ssd":
            s = self.ssm or SSMConfig()
            d_in = s.expand * D
            nh = s.num_heads or d_in // s.head_dim
            # in_proj covers [z, x, B, C, dt]: 2*d_in + 2*G*N + nh
            zxbcdt = 2 * d_in + 2 * s.num_groups * s.state_dim + nh
            return D * zxbcdt + d_in * D + s.conv_width * (
                d_in + 2 * s.num_groups * s.state_dim) + 3 * nh + D
        if kind == "rec":
            r = self.rglru or RGLRUConfig()
            W = r.lru_width or D
            rec = 2 * D * W + W * D + r.conv_width * W + 2 * W * W + 2 * W
            return rec + self._mlp_params() + norms
        raise ValueError(f"unknown block kind {kind!r}")

    def _mlp_params(self) -> int:
        D, F = self.d_model, self.d_ff
        if self.mlp_kind in ("swiglu", "geglu"):
            return 3 * D * F
        if self.mlp_kind == "gelu":
            return 2 * D * F
        if self.mlp_kind == "moe":
            m = self.moe or MoEConfig()
            full = 3 * self.d_model * m.d_ff_expert
            return m.num_experts * full + m.num_shared_experts * full + self.d_model * m.num_experts
        if self.mlp_kind == "none":
            return 0
        raise ValueError(f"unknown mlp kind {self.mlp_kind!r}")
