"""Attention mixers: GQA full/chunked/windowed, cross-attention, decode.

All functions are pure jnp (the dry-run/roofline path); the Pallas
flash-attention kernel in kernels/flash_attention is an opt-in drop-in for
real-TPU serving (DESIGN.md §6).

Conventions:
  q: (B, Sq, H, Dh)   k/v: (B, Sk, KV, Dh)   H = KV * q_per_kv
  q_pos/k_pos: global positions within the packed block (causality),
  q_seg/k_seg: segment ids (packing isolation; 0 = padding).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embeddings; x: (B, S, H, D), positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _mask(q_pos, k_pos, q_seg, k_seg, window: Optional[int], causal: bool):
    """(B, Sq, Sk) boolean mask."""
    m = (q_seg[:, :, None] == k_seg[:, None, :]) & (k_seg[:, None, :] > 0)
    if causal:
        m &= q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        m &= q_pos[:, :, None] - k_pos[:, None, :] < window
    return m


def _sdpa(q, k, v, mask, softcap: float = 0.0) -> jax.Array:
    """Grouped scaled dot-product attention; mask: (B, Sq, Sk)."""
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, Sq, KV, H // KV, Dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits *= Dh ** -0.5
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, Dh)


def attention_naive(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                    window: Optional[int] = None, causal: bool = True,
                    softcap: float = 0.0) -> jax.Array:
    return _sdpa(q, k, v, _mask(q_pos, k_pos, q_seg, k_seg, window, causal), softcap)


def attention_chunked(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                      chunk: int = 1024, window: Optional[int] = None,
                      causal: bool = True, softcap: float = 0.0,
                      unroll: bool = False,
                      logits_dtype=jnp.float32) -> jax.Array:
    """Flash-style online-softmax over KV chunks (memory O(Sq·chunk) instead of
    O(Sq·Sk)); pure jnp so HLO cost analysis sees the real FLOPs.

    ``logits_dtype`` controls the materialized tile dtype: bf16 halves the
    dominant HBM traffic on serve paths (softmax stats stay fp32)."""
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if Sk % chunk:
        chunk = Sk  # fallback: single chunk
    n_chunks = Sk // chunk
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dh)
    scale = Dh ** -0.5
    neg = jnp.asarray(-3e4 if logits_dtype == jnp.bfloat16 else NEG_INF,
                      logits_dtype)

    # index-scan + dynamic_slice instead of pre-transposed scan xs: the
    # (nc, B, chunk, ...) transpose materializes full-S copies of K/V every
    # layer (measured 0.9 TB per 5 llama layers — the dominant memory term);
    # slicing in the body reads only the live chunk.
    def body(carry, idx):
        acc, m_prev, l_prev = carry
        k_i = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=1)
        kp_i = jax.lax.dynamic_slice_in_dim(k_pos, idx * chunk, chunk, axis=1)
        ks_i = jax.lax.dynamic_slice_in_dim(k_seg, idx * chunk, chunk, axis=1)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_i,
                            preferred_element_type=logits_dtype)
        logits = logits * jnp.asarray(scale, logits_dtype)
        if softcap > 0.0:
            logits = jnp.tanh(logits / softcap) * softcap
        msk = _mask(q_pos, kp_i, q_seg, ks_i, window, causal)
        logits = jnp.where(msk[:, None, None, :, :], logits, neg)
        m_cur = jnp.maximum(m_prev, logits.max(axis=-1).astype(jnp.float32))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(logits.astype(jnp.float32) - m_cur[..., None]).astype(logits_dtype)
        l_cur = l_prev * alpha + p.astype(jnp.float32).sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_i.dtype), v_i).astype(jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_cur, l_cur), None

    acc0 = jnp.zeros((B, KV, G, Sq, Dh), jnp.float32)
    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  jnp.arange(n_chunks, dtype=jnp.int32),
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dh).astype(q.dtype)


def attention_local(q, k, v, q_pos, k_pos, q_seg, k_seg, *, window: int,
                    softcap: float = 0.0) -> jax.Array:
    """Exact sliding-window attention in O(S·window): queries in block i attend
    keys in blocks i-1 and i only (block size = window).  Sub-quadratic — the
    long-context path for SWA/local archs (DESIGN.md §4)."""
    B, Sq, H, Dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if Sq != Sk or Sq % window or Sq // window < 2:
        return attention_chunked(q, k, v, q_pos, k_pos, q_seg, k_seg,
                                 chunk=min(Sq, 4096), window=window, softcap=softcap)
    nb = Sq // window
    G = H // KV

    def blocked(x, d):
        return x.reshape(B, nb, window, *x.shape[2:]) if d else x.reshape(B, nb, window)

    qb = blocked(q, True).reshape(B, nb, window, KV, G, Dh)
    kb, vb = blocked(k, True), blocked(v, True)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)           # (B, nb, 2w, KV, Dh)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    qp, ks, qs = blocked(q_pos, False), blocked(k_seg, False), blocked(q_seg, False)
    kp = blocked(k_pos, False)
    kp2 = jnp.concatenate([jnp.concatenate(
        [jnp.full_like(kp[:, :1], -10**9), kp[:, :-1]], axis=1), kp], axis=2)
    ks2 = jnp.concatenate([jnp.zeros_like(ks[:, :1]).at[:].set(0).astype(ks.dtype)
                           if False else jnp.concatenate(
        [jnp.zeros_like(ks[:, :1]), ks[:, :-1]], axis=1), ks], axis=2)

    logits = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2).astype(jnp.float32)
    logits *= Dh ** -0.5
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    msk = ((qs[:, :, :, None] == ks2[:, :, None, :]) & (ks2[:, :, None, :] > 0)
           & (qp[:, :, :, None] >= kp2[:, :, None, :])
           & (qp[:, :, :, None] - kp2[:, :, None, :] < window))
    logits = jnp.where(msk[:, :, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (first tokens of padding segments) -> zeros
    probs = jnp.where(msk[:, :, None, None, :, :], probs, 0.0).astype(v.dtype)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", probs, v2)
    return out.reshape(B, Sq, H, Dh)


def attention_decode(q, k_cache, v_cache, cache_len, *, window: Optional[int] = None,
                     softcap: float = 0.0) -> jax.Array:
    """One-token decode: q (B, 1, H, Dh) against cache (B, Smax, KV, Dh).
    ``cache_len`` (B,) gives the number of valid cache entries per row."""
    B, _, H, Dh = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Dh)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    logits *= Dh ** -0.5
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    pos = jnp.arange(Smax)[None, :]
    valid = pos < cache_len[:, None]
    if window is not None:
        valid &= pos >= (cache_len[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    return out.reshape(B, 1, H, Dh)


def attention_cross(q, k, v, q_seg, *, softcap: float = 0.0) -> jax.Array:
    """Cross attention to encoder embeddings: no causal mask; padding queries
    masked by segment 0."""
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    mask = jnp.broadcast_to((q_seg > 0)[:, :, None], (B, Sq, Sk))
    return _sdpa(q, k, v, mask, softcap)
