"""Mamba-2 SSD (state-space duality) mixer — chunked linear-attention form.

Training/prefill uses the blocked SSD algorithm (paper arXiv:2405.21060):
within chunks of length L the recurrence is computed as masked attention
(quadratic in L, MXU-friendly); across chunks the (H, P, N) states are carried
by a linear scan.  Decode is the O(1)-per-token recurrent update — this is
what makes ``long_500k`` runnable for mamba2 (state size is independent of
context length).

Shapes follow the Mamba-2 reference:
  u:  (B, S, D_in)  split from in_proj   x: (B, S, H, P)
  B/C:(B, S, G, N)  dt: (B, S, H)        state: (B, H, P, N)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, SSMConfig
from .params import ParamDef


def ssd_dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    s = cfg.ssm or SSMConfig()
    d_in = s.expand * cfg.d_model
    nh = s.num_heads or d_in // s.head_dim
    return d_in, nh, s.head_dim, s.num_groups, s.state_dim


def ssd_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    s = cfg.ssm or SSMConfig()
    D = cfg.d_model
    d_in, nh, P, G, N = ssd_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    conv_ch = d_in + 2 * G * N            # conv over [x, B, C]
    return {
        # in_proj -> [z (gate), xBC (conv'd), dt]
        "in_proj": ParamDef((D, 2 * d_in + 2 * G * N + nh), ("embed", "heads"), dt),
        "conv_w": ParamDef((s.conv_width, conv_ch), (None, "heads"), dt),
        "conv_b": ParamDef((conv_ch,), ("heads",), dt, "zeros"),
        "dt_bias": ParamDef((nh,), ("heads",), jnp.float32, "zeros"),
        "a_log": ParamDef((nh,), ("heads",), jnp.float32, "ones"),
        "d_skip": ParamDef((nh,), ("heads",), jnp.float32, "ones"),
        "norm_scale": ParamDef((d_in,), ("heads",), jnp.float32, "zeros"),
        "out_proj": ParamDef((d_in, D), ("heads", "embed"), dt, "scaled"),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum(a[..., j+1:i+1]) for j <= i.

    a: (..., L) log-decays; returns (..., L, L) lower-triangular log decay
    matrix with -inf above the diagonal.
    """
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, Bm: jax.Array,
                Cm: jax.Array, *, chunk: int,
                init_state: Optional[jax.Array] = None,
                seg: Optional[jax.Array] = None,
                unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Blocked SSD scan.

    x (B,S,H,P), dt (B,S,H) post-softplus, a (H,) negative decay rates,
    Bm/Cm (B,S,G,N).  Returns y (B,S,H,P) and final state (B,H,P,N).
    ``seg`` (B,S) segment ids reset the state at packing boundaries by zeroing
    the decay across a boundary.
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = min(chunk, S)
    if S % L:
        L = S
    nc = S // L
    rep = H // G

    cdt = x.dtype                                     # compute dtype (bf16)
    dA = dt * a[None, None, :]                       # (B,S,H) fp32 log decay
    if seg is not None:
        # zero carry-over across segment boundaries: make decay -inf there
        boundary = jnp.concatenate(
            [jnp.zeros((B, 1), bool), seg[:, 1:] != seg[:, :-1]], axis=1)
        dA = jnp.where(boundary[..., None], -1e9, dA)
    # fold dt into x (ZOH input); keep intra-chunk math in compute dtype
    xb = (x * dt[..., None].astype(cdt)).reshape(B, nc, L, H, P)
    dAb = dA.reshape(B, nc, L, H)
    Bb = Bm.reshape(B, nc, L, G, N)
    Cb = Cm.reshape(B, nc, L, G, N)
    Bh = jnp.repeat(Bb, rep, axis=3)                  # (B,nc,L,H,N)
    Ch = jnp.repeat(Cb, rep, axis=3)

    # ---- intra-chunk (diagonal blocks): masked attention form
    Ldec = jnp.exp(_segsum(dAb.transpose(0, 1, 3, 2)))        # (B,nc,H,L,L) fp32
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)          # (B,nc,H,L,L)
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp",
                        scores, Ldec.astype(cdt), xb)

    # ---- chunk states: contribution of each chunk to its end-state
    # (fp32 accumulation: the inter-chunk recurrence compounds over S/L steps)
    cum = jnp.cumsum(dAb, axis=2)                               # (B,nc,L,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,nc,L,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn",
                        Bh, decay_to_end.astype(cdt), xb,
                        preferred_element_type=jnp.float32)     # (B,nc,H,P,N) f32

    # ---- inter-chunk recurrence over nc (linear scan, tiny trip count)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (B,nc,H) fp32

    def scan_fn(carry, inp):
        st, dec = inp                                           # f32,(B,H)f32
        new = carry * dec[..., None, None] + st
        return new, carry                                       # emit state *entering* chunk

    init = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, entering = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=nc if unroll else 1)
    entering = entering.transpose(1, 0, 2, 3, 4)                # (B,nc,H,P,N)

    # ---- inter-chunk output: state entering the chunk read by C with decay
    decay_from_start = jnp.exp(cum)                             # (B,nc,L,H)
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp",
                       Ch, decay_from_start.astype(cdt), entering.astype(cdt))

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y.astype(cdt), final


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
                    Bm: jax.Array, Cm: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrent update.  state (B,H,P,N); x (B,H,P); dt (B,H);
    Bm/Cm (B,G,N).  Returns (y (B,H,P), new_state)."""
    H, G = x.shape[1], Bm.shape[1]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                            # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dA = jnp.exp(dt * a[None, :])                               # (B,H)
    upd = jnp.einsum("bhp,bhn->bhpn", x * dt[..., None], Bh)
    new_state = state * dA[..., None, None].astype(state.dtype) + upd.astype(state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(state.dtype))
    return y.astype(x.dtype), new_state


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time.  x (B,S,C), w (K,C), b (C,).
    ``state`` (B,K-1,C) carries the last K-1 inputs for decode; returns
    (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[0]
    pad = (jnp.zeros((B, K - 1, C), x.dtype) if state is None
           else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)                      # (B,S+K-1,C)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, S:, :] if K > 1 else jnp.zeros((B, 0, C), x.dtype)
    return jax.nn.silu(y + b[None, None, :]), new_state


def ssd_mixer(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig, *,
              seg: Optional[jax.Array] = None,
              decode_state: Optional[Dict[str, jax.Array]] = None
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba-2 block body (post-norm residual excluded).

    Train/prefill: x (B,S,D) -> (B,S,D); decode (S==1): O(1) update against
    ``decode_state`` {"conv": (B,K-1,conv_ch), "ssm": (B,H,P,N)}.
    """
    s = cfg.ssm or SSMConfig()
    d_in, nh, P, G, N = ssd_dims(cfg)
    B, S, D = x.shape

    zxbcdt = x @ p["in_proj"]
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])            # (B,S,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                 # (nh,)

    conv_state = decode_state["conv"] if decode_state is not None else None
    xBC, new_conv = causal_conv1d(xBC, p["conv_w"], p["conv_b"], conv_state)
    xs, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    xh = xs.reshape(B, S, nh, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)

    if decode_state is not None:
        y1, new_ssm = ssd_decode_step(
            decode_state["ssm"], xh[:, 0], dt[:, 0], a, Bm[:, 0], Cm[:, 0])
        y = y1[:, None]
        new_state: Optional[Dict[str, jax.Array]] = {"conv": new_conv, "ssm": new_ssm}
    else:
        y, final = ssd_chunked(xh, dt, a, Bm, Cm, chunk=s.chunk_size, seg=seg,
                               unroll=cfg.unroll_scans)
        new_state = {"conv": new_conv, "ssm": final}

    y = y + xh * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (Mamba-2)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + cfg.norm_eps)
    y = (yf * (1.0 + p["norm_scale"][None, None, :])).astype(x.dtype)
    return y @ p["out_proj"], new_state
