"""Parameter definition trees: one source of truth for shapes, init, and
logical sharding axes.

A ``ParamDef`` records (shape, dtype, logical axes, initializer).  From a tree
of defs we derive (a) initialized arrays, (b) ``jax.ShapeDtypeStruct``s for the
AOT dry-run, and (c) ``PartitionSpec``s by mapping logical axis names through
per-arch sharding rules (MaxText-style; DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis per dim (None = replicated)
    dtype: Any = jnp.bfloat16
    init: str = "normal"                 # normal | zeros | ones | scaled | a_param
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Union[ParamDef, Dict[str, Any], List[Any], Tuple[Any, ...]]


def _is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn: Callable[[ParamDef], Any], tree: ParamTree) -> Any:
    return jax.tree.map(fn, tree, is_leaf=_is_def)


def init_param(key: jax.Array, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "a_param":
        # RG-LRU decay parameterization: softplus-inv of decays in (0.9, 0.999)
        u = jax.random.uniform(key, d.shape, jnp.float32, 0.9, 0.999)
        return jnp.log(jnp.expm1(-jnp.log(u) * 8.0)).astype(d.dtype)
    fan_in = d.shape[0] if len(d.shape) >= 2 else max(1, d.shape[-1])
    if d.init == "scaled":
        std = d.init_scale / np.sqrt(fan_in)
    else:
        std = 0.02 * d.init_scale
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def init_params(key: jax.Array, defs: ParamTree) -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    arrays = [init_param(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(defs: ParamTree) -> Any:
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def logical_to_spec(axes: Sequence[Optional[str]],
                    rules: Dict[str, Any],
                    shape: Optional[Sequence[int]] = None,
                    axis_sizes: Optional[Dict[str, int]] = None) -> P:
    """Map logical axis names -> mesh axes via rules; drop collisions and
    (when ``shape``/``axis_sizes`` are given) non-divisible shardings.

    A rule value may be a mesh-axis name, a tuple of mesh axes, or None.
    If two dims would map to the same mesh axis, the later dim wins nothing
    (kept unsharded) — XLA requires each mesh axis used at most once.
    """
    used: set = set()
    out: List[Any] = []
    for i, ax in enumerate(axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            out.append(None)
            continue
        entries = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        free = tuple(e for e in entries if e not in used)
        if shape is not None and axis_sizes is not None and free:
            # keep the longest divisible prefix of the mesh-axis tuple
            dim = shape[i]
            kept = []
            prod = 1
            for e in free:
                prod *= axis_sizes.get(e, 1)
                if dim % prod == 0:
                    kept.append(e)
                else:
                    break
            free = tuple(kept)
        if not free:
            out.append(None)
            continue
        used.update(free)
        out.append(free if len(free) > 1 else free[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_specs(defs: ParamTree, rules: Dict[str, Any],
                axis_sizes: Optional[Dict[str, int]] = None) -> Any:
    return tree_map_defs(
        lambda d: logical_to_spec(d.axes, rules, d.shape, axis_sizes), defs)


def param_count(defs: ParamTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return int(sum(np.prod(d.shape) for d in leaves))


def stack_defs(d: ParamDef, n: int, axis_name: Optional[str] = "layers") -> ParamDef:
    """Add a leading scan (layer-stack) dimension to a def."""
    return ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.dtype, d.init,
                    d.init_scale)


def stack_tree(tree: ParamTree, n: int) -> ParamTree:
    return tree_map_defs(lambda d: stack_defs(d, n, None), tree)
