"""Shared layers: RMSNorm, dense MLPs (SwiGLU/GeGLU/GELU), embeddings.

Pure-functional: every layer is ``fn(params_subtree, x, cfg) -> y`` with
parameter *definitions* provided by matching ``*_defs`` functions so model.py
can build the full ParamDef tree (shapes + logical sharding axes) in one place.

Logical axis names (mapped to mesh axes by per-arch sharding rules):
  "embed"   — d_model dim          (FSDP: sharded over the data axis)
  "ffn"     — feed-forward hidden  (TP: sharded over the model axis)
  "heads"   — attention head dim   (TP)
  "kv"      — kv head dim          (TP)
  "vocab"   — vocabulary dim       (TP)
  "experts" — MoE expert dim       (EP)
  "layers"  — scanned layer stack  (never sharded)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamDef


# ------------------------------------------------------------------ norms
def rmsnorm_defs(d: int) -> Dict[str, ParamDef]:
    return {"scale": ParamDef((d,), ("embed",), jnp.float32, "zeros")}


def rmsnorm(p: Dict[str, jax.Array], x: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with (1 + scale) parameterization (gemma/llama convention)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    return (xf * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# ------------------------------------------------------------------- MLPs
def mlp_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamDef((D, F), ("embed", "ffn"), dt),
            "wi_up": ParamDef((D, F), ("embed", "ffn"), dt),
            "wo": ParamDef((F, D), ("ffn", "embed"), dt, "scaled"),
        }
    if cfg.mlp_kind == "gelu":
        return {
            "wi": ParamDef((D, F), ("embed", "ffn"), dt),
            "wo": ParamDef((F, D), ("ffn", "embed"), dt, "scaled"),
        }
    raise ValueError(f"mlp_defs: unsupported {cfg.mlp_kind!r}")


def mlp(p: Dict[str, jax.Array], x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else lambda v: jax.nn.gelu(v, approximate=True)
        g = act(x @ p["wi_gate"])
        return (g * (x @ p["wi_up"])) @ p["wo"]
    if kind == "gelu":
        return jax.nn.gelu(x @ p["wi"], approximate=True) @ p["wo"]
    raise ValueError(f"mlp: unsupported {kind!r}")


# ------------------------------------------------------------- embeddings
def embedding_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    dt = jnp.dtype(cfg.param_dtype)
    out = {"embedding": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), dt)}
    if not cfg.tied_embeddings:
        out["unembed"] = ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt)
    return out


def embed(p: Dict[str, jax.Array], tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0).astype(cfg.activation_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Final projection to logits (fp32) with optional soft-capping."""
    w = p["embedding"].T if cfg.tied_embeddings else p["unembed"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if cfg.logit_softcap > 0.0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits
