"""Data-cleaning ingestion operators."""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, Optional

import numpy as np

from ..core.items import Columns, Granularity, IngestItem, num_rows, take_rows
from ..core.operators import IngestOp, register_op


@register_op("fd_check")
class FDCheckOp(IngestOp):
    """Functional dependency ``lhs -> rhs``: tuples sharing lhs must share rhs.

    Within each item, groups rows by lhs; any group with >1 distinct rhs is a
    violation — all its rows are routed to a violations item (label
    ``violation=1``); clean rows keep ``violation=0``.  The paper's global FD
    (Sec. IX-A1) partitions on lhs with a shuffle first so groups are global;
    pass ``shuffle_by=<partition label>`` to request the runtime barrier.
    """

    name = "fd_check"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK

    def __init__(self, lhs: str, rhs: str, drop_violations: bool = False,
                 shuffle_by: Optional[str] = None, **kw: Any) -> None:
        super().__init__(lhs=lhs, rhs=rhs, drop_violations=drop_violations, **kw)
        if shuffle_by is not None:
            self.params["shuffle_by"] = shuffle_by
        self.lhs, self.rhs, self.drop_violations = lhs, rhs, drop_violations

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        lhs, rhs = cols[self.lhs], cols[self.rhs]
        # vectorized: a group violates iff its rhs min != rhs max under lhs key
        uniq, inv = np.unique(lhs, return_inverse=True)
        order = np.argsort(inv, kind="stable")
        sorted_rhs = rhs[order]
        starts = np.searchsorted(inv[order], np.arange(len(uniq)))
        ends = np.append(starts[1:], len(inv))
        bad_groups = np.zeros(len(uniq), dtype=bool)
        for g in range(len(uniq)):  # rhs may be non-numeric: per-group unique
            seg = sorted_rhs[starts[g] : ends[g]]
            if len(seg) > 1 and len(np.unique(seg)) > 1:
                bad_groups[g] = True
        viol_mask = bad_groups[inv]
        clean = take_rows(cols, np.nonzero(~viol_mask)[0])
        viol = take_rows(cols, np.nonzero(viol_mask)[0])
        yield IngestItem(clean, item.granularity, item.labels, dict(item.meta)) \
            .with_label(self.name, 0)
        if not self.drop_violations:
            yield IngestItem(viol, item.granularity, item.labels, dict(item.meta)) \
                .with_label(self.name, 1)


@register_op("dc_check")
class DCCheckOp(IngestOp):
    """Denial constraint: rows where ``violation_predicate`` holds are
    violations (paper example: quantity < 3 AND discount > 9%).  Stores both
    the violating tuples and the original data (label-routed)."""

    name = "dc_check"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK

    def __init__(self, violation_predicate: Callable[[Columns], np.ndarray],
                 repair: Optional[Callable[[Columns], Columns]] = None,
                 **kw: Any) -> None:
        super().__init__(violation_predicate=violation_predicate, repair=repair, **kw)
        self.violation_predicate = violation_predicate
        self.repair = repair

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        bad = np.asarray(self.violation_predicate(cols), dtype=bool)
        viol = take_rows(cols, np.nonzero(bad)[0])
        if self.repair is not None and num_rows(viol):
            repaired = self.repair(viol)
            base = {k: v.copy() for k, v in cols.items()}
            bidx = np.nonzero(bad)[0]
            for k in base:
                base[k][bidx] = repaired[k]
            yield IngestItem(base, item.granularity, item.labels,
                             dict(item.meta)).with_label(self.name, 0)
        else:
            yield item.with_label(self.name, 0)
        yield IngestItem(viol, item.granularity, item.labels,
                         dict(item.meta)).with_label(self.name, 1)


@register_op("dict_repair")
class DictRepairOp(IngestOp):
    """Single-pass dictionary repair (paper: country 'mexico' -> 'MX').

    Values of ``field`` not in ``valid`` are replaced via ``mapping`` when
    possible; rows that cannot be repaired are routed to label 1.  Only the
    corrected values are stored (label 0)."""

    name = "dict_repair"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK

    def __init__(self, field: str, mapping: Dict[Any, Any], **kw: Any) -> None:
        super().__init__(field=field, mapping=mapping, **kw)
        self.field, self.mapping = field, mapping
        self.valid = set(mapping.values())

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = {k: v.copy() for k, v in item.data.items()}
        vals = cols[self.field]
        invalid = np.array([v not in self.valid for v in vals])
        unrepairable = np.zeros(len(vals), dtype=bool)
        for i in np.nonzero(invalid)[0]:
            fix = self.mapping.get(vals[i])
            if fix is None:
                unrepairable[i] = True
            else:
                vals[i] = fix
        ok = take_rows(cols, np.nonzero(~unrepairable)[0])
        bad = take_rows(item.data, np.nonzero(unrepairable)[0])
        yield IngestItem(ok, item.granularity, item.labels,
                         dict(item.meta)).with_label(self.name, 0)
        if unrepairable.any():
            yield IngestItem(bad, item.granularity, item.labels,
                             dict(item.meta)).with_label(self.name, 1)
