"""Ingest-time data cleaning operators (paper Sec. II-A, IX-A1).

* FDCheckOp       — functional-dependency violation detection (lhs -> rhs);
                    requires grouping on lhs (pair with a shuffle for the
                    global FD of the paper's experiment).
* DCCheckOp       — denial-constraint detection (vectorized predicate over
                    rows; violating rows routed to a violations file).
* DictRepairOp    — single-pass dictionary repair of invalid codes.
"""
from .ops import DCCheckOp, DictRepairOp, FDCheckOp

__all__ = ["DCCheckOp", "DictRepairOp", "FDCheckOp"]
