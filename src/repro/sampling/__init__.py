"""Ingest-time sampling operators (paper Sec. II-B, IX-A2).

Five techniques evaluated in the paper: Bernoulli, simple random, systematic
random, local stratified, global stratified.  Each emits the base data
unchanged (label ``sample=0``) plus sample items (label ``sample=1``) so the
plan can route them to different physical files — the paper's "in addition to
collecting all tuples into a base file anyways".
"""
from .ops import (BernoulliSampleOp, ReservoirSampleOp, StratifiedSampleOp,
                  SystematicSampleOp, UniformSampleOp)

__all__ = ["BernoulliSampleOp", "ReservoirSampleOp", "StratifiedSampleOp",
           "SystematicSampleOp", "UniformSampleOp"]
