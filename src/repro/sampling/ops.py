"""Sampling ingestion operators."""
from __future__ import annotations

from typing import Any, Iterable, List, Optional, Sequence

import numpy as np

from ..core.items import Columns, Granularity, IngestItem, concat_columns, num_rows, take_rows
from ..core.operators import IngestOp, register_op


class _SamplerBase(IngestOp):
    """Common shape: pass the base item through with sample=0; emit samples
    with sample=1.  ``emit_base=False`` keeps only the samples (pure sample
    extraction for e.g. skew estimation in co-partitioning)."""

    name = "sample"
    granularity_in = Granularity.CHUNK
    granularity_out = Granularity.CHUNK

    def __init__(self, emit_base: bool = True, seed: int = 0, **kw: Any) -> None:
        super().__init__(emit_base=emit_base, seed=seed, **kw)
        self.emit_base = emit_base
        self._rng = np.random.default_rng(seed)

    def _emit(self, item: IngestItem, sample_cols: Columns) -> Iterable[IngestItem]:
        if self.emit_base:
            yield item.with_label(self.name, 0)
        yield IngestItem(sample_cols, item.granularity, item.labels,
                         dict(item.meta)).with_label(self.name, 1)


@register_op("bernoulli_sample")
class BernoulliSampleOp(_SamplerBase):
    """Independent coin flip per row with probability p (paper: probabilistic
    replication of tuples into a separate physical file)."""

    def __init__(self, p: float = 0.01, **kw: Any) -> None:
        super().__init__(**kw)
        self.params["p"] = p
        self.p = p

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        mask = self._rng.random(num_rows(cols)) < self.p
        yield from self._emit(item, take_rows(cols, np.nonzero(mask)[0]))


@register_op("uniform_sample")
class UniformSampleOp(_SamplerBase):
    """Simple random sample: exactly ``k`` rows without replacement per chunk."""

    def __init__(self, k: int = 256, **kw: Any) -> None:
        super().__init__(**kw)
        self.params["k"] = k
        self.k = k

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        n = num_rows(cols)
        idx = self._rng.choice(n, size=min(self.k, n), replace=False)
        yield from self._emit(item, take_rows(cols, np.sort(idx)))


@register_op("systematic_sample")
class SystematicSampleOp(_SamplerBase):
    """Every ``step``-th row from a random start (systematic random sampling)."""

    def __init__(self, step: int = 100, **kw: Any) -> None:
        super().__init__(**kw)
        self.params["step"] = step
        self.step = step

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        n = num_rows(cols)
        start = int(self._rng.integers(self.step)) if n >= self.step else 0
        yield from self._emit(item, take_rows(cols, np.arange(start, n, self.step)))


@register_op("reservoir_sample")
class ReservoirSampleOp(_SamplerBase):
    """Reservoir sampling across all input items; the reservoir is emitted once
    at drain time (paper: "finally emitting the reservoir as samples in the
    end").  Uses the standard single-pass Vitter algorithm vectorized per chunk."""

    def __init__(self, capacity: int = 1024, **kw: Any) -> None:
        super().__init__(**kw)
        self.params["capacity"] = capacity
        self.capacity = capacity
        self._reservoir: Optional[Columns] = None
        self._seen = 0
        self._template: Optional[IngestItem] = None

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        n = num_rows(cols)
        self._template = item
        if self._reservoir is None:
            take = min(n, self.capacity)
            self._reservoir = take_rows(cols, np.arange(take))
            rest = take_rows(cols, np.arange(take, n))
            self._seen = take
            cols = rest
            n = num_rows(cols)
        if n:
            # each incoming row i (global index seen+i) replaces a random slot
            # with prob capacity/(seen+i+1)
            gidx = self._seen + np.arange(n) + 1
            accept = self._rng.random(n) < (self.capacity / gidx)
            slots = self._rng.integers(0, self.capacity, size=n)
            for i in np.nonzero(accept)[0]:
                for k in self._reservoir:
                    self._reservoir[k][slots[i]] = cols[k][i]
            self._seen += n
        if self.emit_base:
            yield item.with_label(self.name, 0)

    def set_input(self, items: Sequence[IngestItem]) -> None:
        super().set_input(items)
        base = self._outputs

        def drained():
            yield from base
            if self._reservoir is not None and self._template is not None:
                yield IngestItem(self._reservoir, Granularity.CHUNK,
                                 self._template.labels, {}).with_label(self.name, 1)

        self._outputs = drained()


@register_op("stratified_sample")
class StratifiedSampleOp(_SamplerBase):
    """Stratified sampling on ``key``: pick ``fraction`` of each stratum
    (proportional allocation) with at least ``min_per_stratum`` rows, so rare
    strata are over-represented relative to their size (paper Sec. II-B).

    Local mode samples each node's strata directly.  Global mode is expressed
    in the *plan*: partition(key, scheme=field) with shuffle, then this op per
    group — the runtime's shuffle barrier makes the strata global.
    """

    def __init__(self, key: str = "", fraction: float = 0.01,
                 min_per_stratum: int = 8, shuffle_by: Optional[str] = None,
                 **kw: Any) -> None:
        super().__init__(**kw)
        self.params.update(key=key, fraction=fraction,
                           min_per_stratum=min_per_stratum)
        if shuffle_by is not None:
            self.params["shuffle_by"] = shuffle_by
        self.key, self.fraction, self.min_per_stratum = key, fraction, min_per_stratum

    def process(self, item: IngestItem) -> Iterable[IngestItem]:
        cols = item.data
        vals = cols[self.key]
        picks: List[np.ndarray] = []
        for v in np.unique(vals):
            idx = np.nonzero(vals == v)[0]
            k = max(self.min_per_stratum, int(len(idx) * self.fraction))
            k = min(k, len(idx))
            picks.append(np.sort(self._rng.choice(idx, size=k, replace=False)))
        sel = np.concatenate(picks) if picks else np.array([], dtype=np.int64)
        yield from self._emit(item, take_rows(cols, sel))
