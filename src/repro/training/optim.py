"""Optimizers in pure JAX: AdamW and Adafactor.

State trees mirror the parameter tree, so pjit shards optimizer state with
the same PartitionSpecs as the parameters (via ``opt_state_specs``).

Adafactor (factored second moments) is what makes kimi-k2 (1 T params)
trainable on a 256-chip pod: AdamW fp32 state would need ~8 TB; Adafactor's
row/col factors are ~(rows+cols)/(rows·cols) of that.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.params import ParamDef, logical_to_spec, tree_map_defs


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128
    warmup_steps: int = 100


def _lr(c: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, c.warmup_steps))
    return c.lr * warm


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


# ------------------------------------------------------------------- AdamW
def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(c: OptConfig, grads: Any, state: Dict[str, Any], params: Any
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
    step = state["step"] + 1
    lr = _lr(c, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - c.b1 ** t
    bc2 = 1.0 - c.b2 ** t

    def upd(g, mu, nu, p):
        mu = c.b1 * mu + (1 - c.b1) * g
        nu = c.b2 * nu + (1 - c.b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + c.eps)
        u = u + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------- Adafactor
def _factored(shape: Tuple[int, ...], min_dim: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(params: Any, min_dim: int = 128) -> Dict[str, Any]:
    def per_leaf(p):
        if _factored(p.shape, min_dim):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(per_leaf, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(c: OptConfig, grads: Any, state: Dict[str, Any], params: Any
                     ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
    step = state["step"] + 1
    lr = _lr(c, step)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-c.decay_rate)

    def upd(g, v, p):
        g2 = g * g + 1e-30
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = vr.mean(axis=-1, keepdims=True)
            pre = (vr / jnp.maximum(denom, 1e-30))[..., None] * vc[..., None, :]
            u = g * jax.lax.rsqrt(jnp.maximum(pre, 1e-30))
            nv = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(vv, 1e-30))
            nv = {"v": vv}
        # update clipping (Adafactor's RMS-1 rule)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

    is_state = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    out = jax.tree.map(upd, grads, state["v"], params, is_leaf=lambda x: False or is_state(x))
    # out leaves are (new_p, new_v) tuples at param positions
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=is_pair)
    new_v = jax.tree.map(lambda o: o[1], out, is_leaf=is_pair)
    return new_p, {"v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------- factories
def make_optimizer(name: str, **kw: Any):
    """Returns (init_fn, update_fn, opt_cfg)."""
    c = OptConfig(name=name, **kw)
    if name == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(c, g, s, p), c
    if name == "adafactor":
        return (lambda p: adafactor_init(p, c.min_dim_factored),
                lambda g, s, p: adafactor_update(c, g, s, p), c)
    raise ValueError(f"unknown optimizer {name!r}")


def opt_state_defs(name: str, param_defs: Any, min_dim: int = 128) -> Any:
    """ParamDef tree for the optimizer state (for AOT dry-run + sharding)."""
    if name == "adamw":
        f32 = lambda d: ParamDef(d.shape, d.axes, jnp.float32, "zeros")
        return {"mu": tree_map_defs(f32, param_defs),
                "nu": tree_map_defs(f32, param_defs),
                "step": ParamDef((), (), jnp.int32, "zeros")}
    if name == "adafactor":
        def per_def(d: ParamDef):
            if _factored(d.shape, min_dim):
                return {"vr": ParamDef(d.shape[:-1], d.axes[:-1], jnp.float32, "zeros"),
                        "vc": ParamDef(d.shape[:-2] + d.shape[-1:],
                                       d.axes[:-2] + d.axes[-1:], jnp.float32, "zeros")}
            return {"v": ParamDef(d.shape, d.axes, jnp.float32, "zeros")}
        return {"v": tree_map_defs(per_def, param_defs),
                "step": ParamDef((), (), jnp.int32, "zeros")}
    raise ValueError(name)
