"""Sharded, mesh-elastic checkpointing with an async writer.

Layout:  <dir>/step_<N>/
           manifest.json        — tree structure, shapes, dtypes, logical axes
           <leafpath>.npy       — one file per parameter leaf (full array or
                                  this process's shard range)

Elasticity: leaves are stored with their *logical* axes, not mesh-relative
shards, so a checkpoint written on a (16,16) mesh restores onto (2,16,16) or
a single CPU device — restore places each leaf with the sharding the *new*
mesh derives from the same logical axes (DESIGN.md §5).  This is what lets a
job lose a pod and restart on fewer chips.

The async writer snapshots device arrays to host (blocking only for the
device->host copy), then persists on a background thread — the train loop
continues into the next step while the previous checkpoint lands on disk.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten_with_paths(tree: Any, is_leaf=None) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append((SEP.join(keys), leaf))
    return out


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    """Save/restore + retention + async writes."""

    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> str:
        self.wait()  # one in-flight write at a time
        host_leaves = [(k, np.asarray(jax.device_get(v)))
                       for k, v in _flatten_with_paths(tree)]
        target = os.path.join(self.dir, f"step_{step:09d}")

        def write():
            try:
                tmp = target + ".tmp"
                os.makedirs(tmp, exist_ok=True)
                manifest = {"step": step, "leaves": {}}
                for key, arr in host_leaves:
                    fname = key.replace(SEP, "__") + ".npy"
                    np.save(os.path.join(tmp, fname), arr)
                    manifest["leaves"][key] = {
                        "file": fname, "shape": list(arr.shape),
                        "dtype": str(arr.dtype)}
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(target):
                    shutil.rmtree(target)
                os.rename(tmp, target)  # atomic publish
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_write and not blocking:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            if self._error:
                raise self._error
        return target

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                place: Optional[Callable[[str, np.ndarray], Any]] = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``place(key, host_array)`` lets the caller put
        each leaf onto devices with mesh-specific sharding (elastic restore);
        default returns host numpy arrays."""
        self.wait()
        src = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(src, "manifest.json")) as f:
            manifest = json.load(f)
        keys = [k for k, _ in _flatten_with_paths(like)]
        missing = [k for k in keys if k not in manifest["leaves"]]
        if missing:
            raise KeyError(f"checkpoint step {step} missing leaves: {missing[:5]}")
        leaves = []
        for k in keys:
            meta = manifest["leaves"][k]
            arr = np.load(os.path.join(src, meta["file"]))
            leaves.append(place(k, arr) if place else arr)
        return jax.tree_util.tree_unflatten(_treedef_of(like), leaves)


def place_on_mesh(mesh, specs_tree: Any) -> Callable[[str, np.ndarray], Any]:
    """Build a ``place`` callback that shards each leaf per its PartitionSpec
    on ``mesh`` — the elastic-restore path."""
    from jax.sharding import NamedSharding, PartitionSpec
    spec_by_key = dict(_flatten_with_paths(
        specs_tree, is_leaf=lambda x: isinstance(x, PartitionSpec)))

    def place(key: str, arr: np.ndarray):
        spec = spec_by_key.get(key)
        if spec is None:
            return jax.device_put(arr)
        return jax.device_put(arr, NamedSharding(mesh, spec))

    return place
