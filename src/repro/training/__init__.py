from .optim import adafactor_init, adafactor_update, adamw_init, adamw_update, make_optimizer
from .steps import loss_fn, make_serve_step, make_train_step
from .compression import ef_compress, ef_decompress, ef_init
