"""Train / serve step builders: loss, grads, optimizer update, metrics.

``make_train_step(cfg)`` returns a pure function
    (params, opt_state, batch, rng) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with in/out shardings from the launch layer.

The cross-entropy is computed in sequence chunks (``loss_chunk``) so the
(B, S, V) logits tensor never materializes at once — with V up to 256 k this
is the difference between fitting and OOM on a 16 GB chip.  FLOPs are
unchanged (same matmuls, scanned), so the roofline's compute term is honest.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.layers import unembed
from ..models.model import decode_step, forward, prefill
from .optim import make_optimizer


def _chunked_xent(cfg: ModelConfig, params: Dict[str, Any], hidden: jax.Array,
                  labels: jax.Array, valid: jax.Array, chunk: int,
                  constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Sum NLL + count over valid positions, scanning over sequence chunks."""
    B, S, D = hidden.shape
    if S % chunk:
        chunk = S
    n = S // chunk
    hc = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    vc = valid.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, l, v = xs
        logits = unembed(params["embed"], h, cfg)              # (B, chunk, V) fp32
        if constrain is not None:
            # pin (batch -> data, vocab -> model): without this, a tied
            # embedding's FSDP-sharded contracting dim makes GSPMD replicate
            # the batch through the loss/backward (verified on gemma-7b)
            logits = constrain("logits", logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        nll = jnp.where(v, lse - picked, 0.0)
        nloss, ncount = carry
        return (nloss + nll.sum(), ncount + v.sum()), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, vc.astype(jnp.float32)), unroll=n if cfg.unroll_scans else 1)
    return loss_sum, count


def loss_fn(cfg: ModelConfig, params: Dict[str, Any], batch: Dict[str, jax.Array],
            *, loss_chunk: int = 1024, moe_aux_weight: float = 0.01,
            constrain=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean next-token NLL over valid (segment>0) positions + MoE aux loss."""
    hidden, moe_aux = forward(cfg, params, batch, constrain=constrain)
    labels = batch["labels"]
    valid = (batch["segments"] > 0) & (labels >= 0)
    loss_sum, count = _chunked_xent(cfg, params, hidden, labels,
                                    valid, loss_chunk, constrain)
    xent = loss_sum / jnp.maximum(count, 1.0)
    total = xent + moe_aux_weight * moe_aux
    return total, {"loss": total, "xent": xent, "moe_aux": moe_aux,
                   "tokens": count}


def make_train_step(cfg: ModelConfig, *, loss_chunk: int = 1024,
                    grad_accum: int = 1, optimizer_kw: Optional[Dict[str, Any]] = None,
                    constrain=None, grad_shardings=None) -> Callable:
    """Build the jit-able train step (with optional gradient accumulation:
    the global batch is split into ``grad_accum`` microbatches scanned
    sequentially — the standard activation-memory lever).

    ``constrain(name, x)`` optionally pins activation shardings (supplied by
    the launch layer, which knows the mesh)."""
    _, opt_update, _ = make_optimizer(cfg.optimizer, **(optimizer_kw or {}))

    def single_loss(params, batch):
        return loss_fn(cfg, params, batch, loss_chunk=loss_chunk,
                       constrain=constrain)

    def _pin_grads(grads):
        # Pin gradient shardings to the parameter shardings so GSPMD lowers
        # the data-axis gradient reduction as reduce-scatter fused into the
        # FSDP layout instead of a full all-reduce (the standard FSDP fix;
        # saves ~half the gradient collective traffic).
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                single_loss, has_aux=True)(params, batch)
            grads = _pin_grads(grads)
        else:
            B = batch["tokens"].shape[0]
            mb = B // grad_accum
            micro = jax.tree.map(
                lambda x: x.reshape(grad_accum, mb, *x.shape[1:])
                if x.ndim >= 1 and x.shape[0] == B else x, batch)

            def accum(carry, mbatch):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(single_loss, has_aux=True)(
                    params, mbatch)
                g = _pin_grads(g)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(accum, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            metrics = jax.tree.map(lambda m: m[-1], ms)
            metrics["loss"] = loss
        new_params, new_opt, opt_metrics = opt_update(grads, opt_state, params)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, constrain=None) -> Callable:
    """One-token decode step: (params, cache, tokens (B,1), pos) ->
    (next_token (B,1), logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = decode_step(cfg, params, cache, tokens, pos,
                                        constrain=constrain)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: int, constrain=None) -> Callable:
    def prefill_step(params, batch):
        return prefill(cfg, params, batch, max_len, constrain=constrain)

    return prefill_step
