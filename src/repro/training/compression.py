"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

On a (pod, data, model) mesh the gradient reduction crosses the DCN once per
step; at 1 T-parameter scale that link is the bottleneck.  Standard remedy
(1-bit Adam / EF-SGD family): quantize the cross-pod contribution to int8
with a per-tensor scale, accumulate the quantization error locally, and add
it back into the next step's gradient — unbiased in the long run, 4x less
DCN traffic than fp32 (2x vs bf16).

Usage (see make_compressed_train_step):
    ef   = ef_init(params)
    g_q, ef = ef_compress(grads, ef)       # before the cross-pod reduce
    grads   = ef_decompress(g_q)           # after it

The quantize/dequantize pair runs inside the jitted step; under pjit the
all-reduce of the int8 tensor is what crosses the DCN.  Error-feedback state
is sharded like the gradients (it IS a gradient-shaped tree).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def ef_init(params: Any) -> Any:
    """Zero error-feedback residual, shaped/sharded like the gradients."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def ef_compress(grads: Any, ef_state: Any) -> Tuple[Any, Any]:
    """Quantize (grad + carried error); new error = input - dequantized."""
    def per_leaf(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        deq = q.astype(jnp.float32) * scale
        return (q, scale), x - deq

    flat = jax.tree.map(per_leaf, grads, ef_state)
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2 and isinstance(t[0], tuple)
    qs = jax.tree.map(lambda t: t[0], flat, is_leaf=is_pair)
    new_ef = jax.tree.map(lambda t: t[1], flat, is_leaf=is_pair)
    return qs, new_ef


def ef_decompress(qs: Any) -> Any:
    """(q, scale) tree -> fp32 gradient tree."""
    is_q = lambda t: (isinstance(t, tuple) and len(t) == 2
                      and getattr(t[0], "dtype", None) == jnp.int8)
    return jax.tree.map(lambda t: t[0].astype(jnp.float32) * t[1], qs,
                        is_leaf=is_q)


def compression_ratio(params: Any) -> float:
    """Bytes on the cross-pod link: int8+scale vs fp32."""
    n = sum(p.size for p in jax.tree.leaves(params))
    k = len(jax.tree.leaves(params))
    return (n * 1 + k * 4) / (n * 4)
