"""Systematic Reed-Solomon (k data + m parity) over GF(2^8).

Encoding:  parity = C @ data        (C: m×k Cauchy matrix, data: k×L bytes)
Recovery:  any k surviving rows of [I; C] are invertible — solve for the
           missing data rows, then recompute missing parity rows.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .gf256 import GF256


class ReedSolomon:
    def __init__(self, k: int, m: int, use_pallas: bool = False) -> None:
        if k < 1 or m < 1:
            raise ValueError("need k >= 1 data and m >= 1 parity blocks")
        self.k, self.m = k, m
        self.C = GF256.cauchy_matrix(m, k)  # (m, k)
        self.use_pallas = use_pallas
        self.last_kernel_s = 0.0   # encode time of the last batch call
        self._pallas_matmul = None
        if use_pallas:
            from ..kernels import ops as gf_ops  # lazy: jax import
            self._pallas_matmul = gf_ops.gf256_matmul

    # ------------------------------------------------------------------ encode
    def _matmul(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        if self._pallas_matmul is not None:
            return np.asarray(self._pallas_matmul(A, B))
        return GF256.matmul(A, B)

    def encode(self, data: np.ndarray) -> np.ndarray:
        """data: (k, L) uint8 -> parity (m, L) uint8."""
        data = np.asarray(data, dtype=np.uint8)
        if data.shape[0] != self.k:
            raise ValueError(f"expected {self.k} data rows, got {data.shape[0]}")
        return self._matmul(self.C, data)

    def encode_payloads(self, payloads: Sequence[bytes]) -> Tuple[np.ndarray, int]:
        """Encode variable-length payloads: zero-pad to the max length (and to a
        multiple of 128 for kernel tile alignment); missing trailing blocks of a
        partial stripe are virtual zero blocks.  Returns (parity (m, L), L)."""
        L = max((len(p) for p in payloads), default=1)
        L = max(1, -(-L // 128) * 128)
        data = np.zeros((self.k, L), dtype=np.uint8)
        for i, p in enumerate(payloads):
            data[i, : len(p)] = np.frombuffer(p, dtype=np.uint8)
        return self.encode(data), L

    @staticmethod
    def stripe_pad(payloads: Sequence) -> int:
        """The padded stripe length ``encode_payloads`` would use — per-stripe
        max payload length rounded up to a multiple of 128."""
        L = max((len(p) for p in payloads), default=1)
        return max(1, -(-L // 128) * 128)

    def encode_payload_batch(
            self, stripes: Sequence[Sequence[np.ndarray]]
            ) -> List[Tuple[np.ndarray, int]]:
        """Batch twin of ``encode_payloads``: encode S stripes in one pass.

        ``stripes`` holds uint8 payload views (one inner list per stripe, up
        to ``k`` rows each; short stripes encode virtual zero blocks).  The S
        stripes share one stacked parity accumulator ``(m, sum L_s)`` — the
        numpy path XOR-accumulates constant-product table gathers straight
        from the payload buffers (no staged ``(k, S*L)`` matrix), the Pallas
        path stages the stacked matrix once and runs ``gf256_matmul`` over
        all stripes in a single kernel launch.  Per-stripe results are
        byte-identical to ``encode_payloads`` (same per-stripe padding), so
        the scalar path stays the correctness oracle.

        Returns ``[(parity (m, L_s) view, L_s), ...]``; the views alias the
        shared accumulator.  Encode time lands in ``self.last_kernel_s``.
        """
        Ls = [self.stripe_pad(ps) for ps in stripes]
        offs = [0]
        for L in Ls:
            offs.append(offs[-1] + L)
        total = offs[-1]
        t0 = time.perf_counter()
        if self._pallas_matmul is not None:
            data = np.zeros((self.k, total), dtype=np.uint8)
            for si, ps in enumerate(stripes):
                o = offs[si]
                for j, p in enumerate(ps):
                    data[j, o:o + len(p)] = p
            from ..core.items import as_device_array  # lazy: jax import
            parity = np.asarray(
                self._pallas_matmul(self.C, as_device_array(data)))
        else:
            parity = np.zeros((self.m, total), dtype=np.uint8)
            for si, ps in enumerate(stripes):
                o = offs[si]
                for j, p in enumerate(ps):
                    for i in range(self.m):
                        GF256.xor_mul_into(parity[i, o:], int(self.C[i, j]), p)
        self.last_kernel_s = time.perf_counter() - t0
        return [(parity[:, offs[s]:offs[s] + Ls[s]], Ls[s])
                for s in range(len(stripes))]

    # ------------------------------------------------------------------ decode
    def reconstruct(self, shards: Dict[int, np.ndarray]) -> np.ndarray:
        """Rebuild the full (k, L) data matrix from any >= k surviving shards.

        ``shards`` maps stripe position -> row bytes; positions 0..k-1 are data
        rows, k..k+m-1 are parity rows.
        """
        if len(shards) < self.k:
            raise ValueError(f"need at least {self.k} shards, have {len(shards)}")
        L = len(next(iter(shards.values())))
        G = np.concatenate([np.eye(self.k, dtype=np.uint8), self.C], axis=0)  # (k+m, k)
        pos = sorted(shards)[: self.k]
        A = G[pos]                                  # (k, k) rows we actually have
        Y = np.stack([np.frombuffer(np.asarray(shards[p], dtype=np.uint8).tobytes(),
                                    dtype=np.uint8) for p in pos])  # (k, L)
        A_inv = GF256.mat_inv(A)
        return self._matmul(A_inv, Y)               # (k, L) original data rows

    def recover_block(self, missing_pos: int, shards: Dict[int, np.ndarray]) -> np.ndarray:
        """Recover one missing stripe row (data or parity) from survivors."""
        data = self.reconstruct(shards)
        if missing_pos < self.k:
            return data[missing_pos]
        return self.encode(data)[missing_pos - self.k]
