"""Reed-Solomon erasure coding over GF(2^8) (paper Sec. II-D, VI-C2).

Parity generation is a matrix multiply over GF(2^8): ``parity = C @ data``
where C is an m×k Cauchy coding matrix.  The numpy path vectorizes the GF
multiply with log/exp tables; the Pallas path (kernels/gf256_matmul) tiles the
same computation into VMEM for TPU (DESIGN.md §6).
"""
from .gf256 import GF256
from .reed_solomon import ReedSolomon

__all__ = ["GF256", "ReedSolomon"]
