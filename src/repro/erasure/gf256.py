"""GF(2^8) arithmetic with the AES polynomial 0x11B, vectorized via log/exp tables."""
from __future__ import annotations

from typing import Dict

import numpy as np

_POLY = 0x11B  # x^8 + x^4 + x^3 + x + 1
_GEN = 3       # generator of the multiplicative group under 0x11B


def _build_tables():
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        # multiply x by the generator (3): x*3 = x*2 ^ x
        x2 = x << 1
        if x2 & 0x100:
            x2 ^= _POLY
        x = x2 ^ x
    exp[255:510] = exp[:255]  # wraparound so exp[a+b] needs no mod
    return exp, log


EXP_TABLE, LOG_TABLE = _build_tables()

# Constant-multiplier product tables for the batch encode tier (ISSUE 7).
# Keyed by the coefficient byte; a Cauchy code matrix has only m*k distinct
# coefficients, so the working set is a handful of cache-resident tables.
_ROW_TABLES: Dict[int, np.ndarray] = {}    # c -> (256,)   uint8: c * b
_PAIR_TABLES: Dict[int, np.ndarray] = {}   # c -> (65536,) uint16: c * (b0, b1)


class GF256:
    """Vectorized GF(2^8) field ops on uint8 numpy arrays."""

    exp = EXP_TABLE
    log = LOG_TABLE

    @staticmethod
    def add(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return np.bitwise_xor(a, b)

    sub = add  # characteristic 2

    @staticmethod
    def mul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        a = np.asarray(a, dtype=np.uint8)
        b = np.asarray(b, dtype=np.uint8)
        out = EXP_TABLE[LOG_TABLE[a] + LOG_TABLE[b]]
        # anything multiplied by 0 is 0 (log[0] is a bogus 0 entry)
        zero = (a == 0) | (b == 0)
        return np.where(zero, np.uint8(0), out).astype(np.uint8)

    @staticmethod
    def inv(a: np.ndarray) -> np.ndarray:
        a = np.asarray(a, dtype=np.uint8)
        if np.any(a == 0):
            raise ZeroDivisionError("GF(256) inverse of 0")
        return EXP_TABLE[255 - LOG_TABLE[a]].astype(np.uint8)

    @staticmethod
    def div(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return GF256.mul(a, GF256.inv(b))

    # ------------------------------------------------- constant-product tables
    @staticmethod
    def row_table(c: int) -> np.ndarray:
        """(256,) uint8 table of ``c * b`` for every byte ``b``."""
        t = _ROW_TABLES.get(c)
        if t is None:
            t = GF256.mul(np.uint8(c), np.arange(256, dtype=np.uint8))
            t.setflags(write=False)
            _ROW_TABLES[c] = t
        return t

    @staticmethod
    def pair_table(c: int) -> np.ndarray:
        """(65536,) uint16 table multiplying *both* bytes of a little-endian
        byte pair by the constant ``c``: one gather per two payload bytes.

        This is the batch encode tier's CPU idiom (ISSUE 7): a row of N bytes
        viewed as uint16 needs N/2 gathers from a 128 KB L2-resident table,
        instead of the log/exp path's several int32 passes per element —
        ~5x on erasure-coded stripes (see bench_storage's kernel-tier section).
        """
        t = _PAIR_TABLES.get(c)
        if t is None:
            row = GF256.row_table(c).astype(np.uint16)
            idx = np.arange(65536)
            t = row[idx & 0xFF] | (row[idx >> 8] << 8)
            t.setflags(write=False)
            _PAIR_TABLES[c] = t
        return t

    @staticmethod
    def xor_mul_into(acc: np.ndarray, c: int, payload: np.ndarray) -> None:
        """``acc[:len(payload)] ^= c * payload`` (GF(256), elementwise).

        ``acc`` is a uint8 vector at least as long as ``payload``; the product
        runs through the pair tables (two bytes per gather), with the odd tail
        byte finished through the 256-entry row table.
        """
        n = len(payload)
        if n == 0 or c == 0:
            return
        even = n & ~1
        if even:
            a16 = acc[:even].view(np.uint16)
            try:
                p16 = payload[:even].view(np.uint16)
            except ValueError:  # unaligned view (odd-offset slice of a buffer)
                p16 = np.ascontiguousarray(payload[:even]).view(np.uint16)
            np.bitwise_xor(a16, GF256.pair_table(c).take(p16), out=a16)
        if n & 1:
            acc[n - 1] ^= GF256.row_table(c)[payload[n - 1]]

    # ------------------------------------------------------------- lin-algebra
    @staticmethod
    def matmul(A: np.ndarray, B: np.ndarray) -> np.ndarray:
        """GF(256) matrix product: XOR-accumulated table-lookup products.

        A: (m, k) uint8, B: (k, n) uint8 -> (m, n) uint8.
        Vectorized over n; loops over k (k is small: stripe width).
        """
        A = np.asarray(A, dtype=np.uint8)
        B = np.asarray(B, dtype=np.uint8)
        m, k = A.shape
        out = np.zeros((m, B.shape[1]), dtype=np.uint8)
        for j in range(k):
            out ^= GF256.mul(A[:, j : j + 1], B[j : j + 1, :])
        return out

    @staticmethod
    def mat_inv(A: np.ndarray) -> np.ndarray:
        """Gauss-Jordan inverse of a square GF(256) matrix."""
        A = np.asarray(A, dtype=np.uint8).copy()
        n = A.shape[0]
        I = np.eye(n, dtype=np.uint8)
        aug = np.concatenate([A, I], axis=1)
        for col in range(n):
            piv = col + int(np.argmax(aug[col:, col] != 0))
            if aug[piv, col] == 0:
                raise np.linalg.LinAlgError("singular GF(256) matrix")
            if piv != col:
                aug[[col, piv]] = aug[[piv, col]]
            aug[col] = GF256.mul(aug[col], GF256.inv(aug[col, col]))
            for r in range(n):
                if r != col and aug[r, col] != 0:
                    aug[r] = GF256.add(aug[r], GF256.mul(aug[r, col], aug[col]))
        return aug[:, n:]

    @staticmethod
    def cauchy_matrix(m: int, k: int) -> np.ndarray:
        """Cauchy coding matrix: C[i, j] = 1 / (x_i + y_j) with distinct x, y.

        Every square submatrix of a Cauchy matrix is invertible, which is what
        makes it a valid MDS erasure code generator.
        """
        if m + k > 256:
            raise ValueError("m + k must be <= 256 for GF(256) Cauchy codes")
        x = np.arange(k, k + m, dtype=np.uint8)   # rows
        y = np.arange(0, k, dtype=np.uint8)       # cols
        denom = np.bitwise_xor(x[:, None], y[None, :])
        return GF256.inv(denom)
