"""End-to-end driver: ingest a corpus with INGESTBASE, then train a smollm-
family model on it for a few hundred steps (CPU-scaled config).

    PYTHONPATH=src python examples/train_smollm.py [--steps 200]

This is the thin wrapper over the production entry point
(repro.launch.train); the same flow runs the full smollm-135m on a 16x16 pod
by swapping --smoke/--mesh.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    work = tempfile.mkdtemp(prefix="ingestbase_train_")
    sys.argv = [
        "train", "--arch", "smollm-135m", "--smoke",
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq-len", str(args.seq_len),
        "--data-dir", os.path.join(work, "corpus"),
        "--ckpt-dir", os.path.join(work, "ckpt"),
        "--ckpt-every", "50", "--log-every", "20",
    ]
    from repro.launch.train import main as train_main
    raise SystemExit(train_main())


if __name__ == "__main__":
    main()
