"""Streaming log ingestion, end to end: an unbounded log feed is cut into
micro-batch epochs, committed atomically, and queried while ingestion runs —
with a node killed mid-stream to show epoch-granular replay (no loss, no
duplicate commits).

    PYTHONPATH=src python examples/streaming_logs.py

The plan is written in the textual language; ``STREAM WITH EPOCHS(...)``
declares the epoch-cut policy, and the same optimized stage pipeline the batch
engine runs is reused per epoch.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (DataAccess, DataStore, StreamFaultInjection,
                        StreamingRuntimeEngine, parse_ingestion_script)
from repro.core.items import IngestItem
from repro.data.generators import gen_log_records

SCRIPT = """
s1 = SELECT * FROM input USING parser;
s2 = FORMAT s1 CHUNK BY 4096 SERIALIZE AS columnar;
s3 = STORE s2 LOCATE USING roundrobin UPLOAD TO target;
CREATE STAGE main USING s1,s2,s3;
STREAM WITH EPOCHS(items=4, capacity=16);
"""


def log_feed(n_shards=24, rows_per_shard=2_000):
    """The 'fast arriving data': one shard of log lines per pull."""
    for i in range(n_shards):
        yield IngestItem(gen_log_records(rows_per_shard, seed=i))


def main():
    root = tempfile.mkdtemp(prefix="ingestbase_stream_")
    ds = DataStore(root, nodes=[f"n{i}" for i in range(4)])
    plan = parse_ingestion_script(SCRIPT, env={"target": ds})

    n_shards, rows = 24, 2_000
    engine = StreamingRuntimeEngine(ds)
    faults = StreamFaultInjection(node_death_in_epoch={"n1": 2})  # die mid-stream
    try:
        report = engine.run_stream(plan, log_feed(n_shards, rows), faults=faults)
    finally:
        engine.close()   # release the persistent node executors

    print(f"epochs committed: {report.committed_epoch_ids()}")
    print(f"node failures: {report.node_failures} "
          f"(epoch(s) {report.replayed_epochs} replayed on survivors)")
    lat = sorted(report.commit_latencies())
    print(f"sustained: {report.items_per_sec() * rows:,.0f} rows/s; "
          f"epoch commit p50={lat[len(lat) // 2] * 1e3:.1f}ms "
          f"max={lat[-1] * 1e3:.1f}ms")

    # epoch-aware access: fresh data is queryable the moment its epoch commits
    acc = DataAccess(ds)
    total = len(acc.since_epoch(-1).read_all(projection=["ts"])["ts"])
    assert total == n_shards * rows, (total, n_shards * rows)
    print(f"rows readable after death+replay: {total:,} (zero loss)")

    last = acc.latest_epoch()
    fresh = acc.filter_epoch(last).read_all(projection=["severity"])
    print(f"freshest epoch {last}: {len(fresh['severity']):,} rows, "
          f"{int((fresh['severity'] >= 2).sum())} errors")


if __name__ == "__main__":
    main()
