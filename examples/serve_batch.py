"""Batched serving example: prefill a prompt batch, decode continuations.

    PYTHONPATH=src python examples/serve_batch.py [--arch recurrentgemma-2b]

Uses the production serve path (prefill -> one-token decode steps with KV /
recurrent-state caches); smoke configs keep it CPU-sized.  Works for every
assigned architecture family (attention, SWA ring cache, SSD state, RG-LRU).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: N requests through B slots")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    if args.continuous:
        import time
        import jax
        import numpy as np
        from repro.configs import get_smoke
        from repro.models.model import model_defs
        from repro.models.params import init_params
        from repro.serving import ContinuousBatcher, Request

        cfg = get_smoke(args.arch)
        params = init_params(jax.random.PRNGKey(0), model_defs(cfg))
        batcher = ContinuousBatcher(cfg, params, num_slots=args.batch,
                                    max_len=args.prompt_len + args.decode_steps + 8)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            batcher.submit(Request(
                rid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    rng.integers(8, args.prompt_len + 1)
                                    ).astype(np.int32),
                max_new_tokens=args.decode_steps))
        t0 = time.perf_counter()
        done = batcher.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        print(f"[continuous] {len(done)} requests through {args.batch} slots "
              f"in {batcher.steps} decode iterations; "
              f"{toks} tokens in {dt:.1f}s ({toks/dt:.1f} tok/s)")
        for r in done[:3]:
            print(f"  req {r.rid}: slot {r.slot}, "
                  f"ttft {1e3*(r.t_first_token-r.t_enqueue):.0f} ms, "
                  f"tokens {r.generated[:8]}")
        return 0

    sys.argv = ["serve", "--arch", args.arch, "--smoke",
                "--batch", str(args.batch),
                "--prompt-len", str(args.prompt_len),
                "--decode-steps", str(args.decode_steps)]
    from repro.launch.serve import main as serve_main
    raise SystemExit(serve_main())


if __name__ == "__main__":
    main()
