"""Quickstart: declare an ingestion plan, run it, query the result.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's core loop in ~40 lines of user code:
  1. declare WHAT/HOW/WHERE with SELECT / FORMAT / STORE statements,
  2. let the optimizer reorder + pipeline the plan,
  3. run it distributed (4 simulated nodes) and fault-tolerant,
  4. read back through ingestion-aware access with pushdown.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (DataAccess, DataStore, IngestPlan, create_stage,
                        format_, ingest, select)
from repro.core import store as store_stmt
from repro.data.generators import as_file_items, gen_lineitem


def main():
    root = tempfile.mkdtemp(prefix="ingestbase_quickstart_")
    ds = DataStore(root, nodes=["n0", "n1", "n2", "n3"])

    # ---- 1. declare the ingestion plan -----------------------------------
    plan = IngestPlan("quickstart")
    s1 = select(plan, where=("quantity", ">", 5), replicate=2)
    s2 = format_(plan, s1,
                 partition={"scheme": "hash", "key": "suppkey",
                            "num_partitions": 4},
                 chunk={"target_rows": 4096},
                 serialize="columnar")
    s3 = store_stmt(plan, s2, locate="roundrobin", upload=ds)
    create_stage(plan, using=[s1, s2, s3], name="main")
    print(plan.describe())

    # ---- 2-3. optimize + run distributed ---------------------------------
    items = as_file_items(gen_lineitem(100_000), shards=8)
    report = ingest(plan, items, ds)
    print(f"\ningested: {report.stage_items}, "
          f"{len(ds.blocks())} physical blocks, "
          f"{ds.total_bytes() / 1e6:.1f} MB, wall {report.wall_time_s:.2f}s")
    print("lineage-named file example:", ds.blocks()[0].block_id)

    # ---- 4. ingestion-aware access ---------------------------------------
    acc = DataAccess(ds).filter_replica("serialize", "columnar").distinct_replicas()
    cols = acc.read_all(projection=["suppkey", "extendedprice"],
                        selection=("extendedprice", ">", 100_000))
    print(f"\nprojected+filtered read: {len(cols['suppkey'])} rows, "
          f"revenue sum {cols['extendedprice'].sum():.0f}")

    # per-partition splits (what a query processor's tasks would consume)
    splits = acc.split_by_key("partition")
    print("splits:", [(s.key, len(s.blocks)) for s in splits])


if __name__ == "__main__":
    main()
