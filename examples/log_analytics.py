"""The paper's Sec. IV-C log-analytics scenario, end to end — written in the
SQL-ish textual ingestion language, with post-ingestion fault tolerance.

    PYTHONPATH=src python examples/log_analytics.py

Three replicas with different physical designs:
  replica 1: time-sorted rows            (point/range lookups on timestamp)
  replica 2: columnar                    (projection scans)
  replica 3: hash-partitioned columnar   (machine-keyed joins/aggregations)
then kills a block and lets the FT daemon repair it via a differently-
serialized replica (transformation-based recovery).
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (Catalog, DataAccess, DataStore, FaultToleranceDaemon,
                        TransformationRecovery, ingest, parse_ingestion_script)
from repro.data.generators import as_file_items, gen_log_records

SCRIPT = """
s1 = SELECT * FROM input USING parser REPLICATE BY 2;
s2 = SELECT * FROM s1 REPLICATE BY 2;
s3 = FORMAT s2 CHUNK BY 2048;
s4 = FORMAT s3 ORDER BY ts SERIALIZE AS sorted(key=ts);
s5 = FORMAT s3 SERIALIZE AS columnar;
s6 = FORMAT s1 PARTITION BY hash(key=machine, num_partitions=4) CHUNK BY 2048 SERIALIZE AS columnar;
s7 = STORE s4,s5 LOCATE USING disjoint;
s8 = STORE s6 LOCATE USING random;
s9 = STORE s7,s8 UPLOAD TO target;
CREATE STAGE a USING s1;
CHAIN STAGE b TO a USING s2,s3 WHERE l_replicate_s1=1;
CHAIN STAGE c TO a USING s6,s8 WHERE l_replicate_s1=2;
CHAIN STAGE d TO b USING s4 WHERE l_replicate_s2=1;
CHAIN STAGE e TO b USING s5 WHERE l_replicate_s2=2;
CHAIN STAGE f TO d,e USING s7;
CHAIN STAGE g TO c,f USING s9;
"""


def main():
    root = tempfile.mkdtemp(prefix="ingestbase_logs_")
    ds = DataStore(root, nodes=[f"n{i}" for i in range(4)])

    plan = parse_ingestion_script(
        SCRIPT, env={"target": ds, "partition_key": "machine",
                     "order_key": "ts"})
    items = as_file_items(gen_log_records(50_000), shards=8)
    report = ingest(plan, items, ds)
    print(f"ingested {sum(report.stage_items.values())} stage outputs "
          f"-> {len(ds.blocks())} blocks on {len(ds.nodes)} nodes")

    catalog = Catalog(ds)
    catalog.register_plan(plan, recovery_udfs=["transformation"])

    acc = DataAccess(ds)
    # incident triage: last hour of logs from the sorted replica
    recent = acc.filter_replica("serialize", "sorted").read_all(
        projection=["ts", "machine", "severity"], selection=(("ts", ">", 82_800)))
    print(f"last-hour rows: {len(recent['ts'])}, "
          f"errors: {(recent['severity'] >= 2).sum()}")

    # kill a columnar block; transformation-based recovery re-encodes it
    victim = next(e for e in ds.blocks() if e.layout == "columnar")
    ds.corrupt_block(victim.block_id)
    print(f"corrupted block {victim.block_id[:60]}...")
    daemon = FaultToleranceDaemon(ds, catalog.recovery_chain(plan.name))
    rep = daemon.sweep()
    print(f"recovered: {[(b[:40], u) for b, u in rep.recovered]}")
    assert ds.verify_block(victim.block_id)
    print("block verified after transformation-based recovery")


if __name__ == "__main__":
    main()
