"""Fig. 5(a): data-cleaning ingest overhead vs plain upload.

FD check (shipdate -> linestatus, global: shuffle on lhs), DC check
(quantity < 3 => discount <= 9%), DC check + single-pass repair.
"""
from __future__ import annotations

from typing import List

from repro.core import create_stage, format_, select
from repro.core import store as store_stmt
from repro.core.operators import resolve_op

from .common import Row, plain_upload_seconds, run_plan_seconds


def _fmt_store(p, ds, src):
    s2 = format_(p, src, chunk={"target_rows": 16384}, serialize="row")
    s3 = store_stmt(p, s2, upload=ds)
    return [s2, s3]


def run(n: int = 200_000) -> List[Row]:
    base = plain_upload_seconds(n)
    rows: List[Row] = [("cleaning/plain_upload", base, "1.00x")]

    def fd(p, ds):
        s1 = select(p)
        chk = p.add_statement([resolve_op("partition", scheme="hash",
                                          key="shipdate", num_partitions=8),
                               resolve_op("fd_check", lhs="shipdate",
                                          rhs="linestatus",
                                          shuffle_by="partition")],
                              kind="format", inputs=[s1])
        create_stage(p, using=[s1, chk] + _fmt_store(p, ds, chk), name="main")

    def dc(p, ds):
        s1 = select(p)
        chk = p.add_statement([resolve_op(
            "dc_check", violation_predicate=lambda c: (c["quantity"] < 3)
            & (c["discount"] > 0.09))], kind="format", inputs=[s1])
        create_stage(p, using=[s1, chk] + _fmt_store(p, ds, chk), name="main")

    def dc_repair(p, ds):
        s1 = select(p)

        def fix(viol):
            out = dict(viol)
            out["discount"] = viol["discount"].clip(max=0.09)
            return out

        chk = p.add_statement([resolve_op(
            "dc_check", violation_predicate=lambda c: (c["quantity"] < 3)
            & (c["discount"] > 0.09), repair=fix)], kind="format", inputs=[s1])
        create_stage(p, using=[s1, chk] + _fmt_store(p, ds, chk), name="main")

    for name, build in (("fd_check_global", fd), ("dc_check", dc),
                        ("dc_check_repair", dc_repair)):
        secs, _ = run_plan_seconds(build, n)
        rows.append((f"cleaning/{name}", secs, f"{secs / base:.2f}x"))
    return rows
