"""Fig. 5(b): sampling-during-ingest overhead vs plain upload.

Bernoulli, simple random (reservoir-per-node), systematic, local stratified,
global stratified (shuffle).
"""
from __future__ import annotations

from typing import List

from repro.core import create_stage, format_, select
from repro.core import store as store_stmt
from repro.core.operators import resolve_op

from .common import Row, plain_upload_seconds, run_plan_seconds


def _build(sampler_key, sampler_kw, partition_first=False):
    def build(p, ds):
        s1 = select(p)
        ops = []
        if partition_first:
            ops.append(resolve_op("partition", scheme="field", key="linestatus"))
        ops.append(resolve_op(sampler_key, **sampler_kw))
        samp = p.add_statement(ops, kind="format", inputs=[s1])
        s2 = format_(p, samp, chunk={"target_rows": 16384}, serialize="row")
        s3 = store_stmt(p, s2, upload=ds)
        create_stage(p, using=[s1, samp, s2, s3], name="main")
    return build


def run(n: int = 200_000) -> List[Row]:
    base = plain_upload_seconds(n)
    rows: List[Row] = [("sampling/plain_upload", base, "1.00x")]
    cases = [
        ("bernoulli", "bernoulli_sample", {"p": 0.01}, False),
        ("simple_random", "uniform_sample", {"k": 1024}, False),
        ("systematic", "systematic_sample", {"step": 100}, False),
        ("reservoir", "reservoir_sample", {"capacity": 1024}, False),
        ("stratified_local", "stratified_sample",
         {"key": "linestatus", "fraction": 0.01}, False),
        ("stratified_global", "stratified_sample",
         {"key": "linestatus", "fraction": 0.01, "shuffle_by": "partition"},
         True),
    ]
    for name, key, kw, part in cases:
        secs, _ = run_plan_seconds(_build(key, kw, part), n)
        rows.append((f"sampling/{name}", secs, f"{secs / base:.2f}x"))
    return rows
