"""Perf-iteration driver: run one dry-run cell with config overrides and log
the result under benchmarks/artifacts/perf/<cell>__<tag>.json.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch X --shape Y --tag T \
        [--overrides '{"attn_logits_dtype": "bfloat16"}'] [--grad-accum N] \
        [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json

PERF_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "perf")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--overrides", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--loss-chunk", type=int, default=1024)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sp", action="store_true", help="sequence-parallel prefill")
    ap.add_argument("--dp", action="store_true", help="pure data parallelism")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    art = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   grad_accum=args.grad_accum, loss_chunk=args.loss_chunk,
                   overrides=json.loads(args.overrides) if args.overrides else None,
                   sp=args.sp, dp=args.dp)
    os.makedirs(PERF_DIR, exist_ok=True)
    path = os.path.join(PERF_DIR, f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    r = art["roofline"]
    m = art["memory_analysis"]
    print(f"[{args.tag}] {args.arch} {args.shape} "
          f"compute={r['compute_s']:.3f}s mem={r['memory_s']:.3f}s "
          f"coll={r['collective_s']:.3f}s dom={r['dominant']} "
          f"useful={r['useful_ratio']:.2f} "
          f"frac={r['compute_s']/max(r['compute_s'],r['memory_s'],r['collective_s']):.2f} "
          f"GiB={(m['argument_bytes']+m['temp_bytes'])/2**30:.1f}")


if __name__ == "__main__":
    main()
