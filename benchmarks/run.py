"""Run every benchmark (one per paper table/figure) and print
``name,us_per_call,derived`` CSV.

  python -m benchmarks.run [--scale N] [--only cleaning,sampling,...]
"""
from __future__ import annotations

import argparse
import sys
import time


MODULES = ["cleaning", "sampling", "layouts", "storage", "cooking",
           "access", "recovery", "streaming", "roofline"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=200_000,
                    help="rows of TPC-H lineitem-like data per bench")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    only = args.only.split(",") if args.only else MODULES

    import importlib
    print("name,us_per_call,derived")
    failures = 0
    for mod in MODULES:
        if mod not in only:
            continue
        t0 = time.time()
        try:
            m = importlib.import_module(f"benchmarks.bench_{mod}")
            for name, secs, derived in m.run(args.scale):
                print(f"{name},{secs * 1e6:.1f},{derived}", flush=True)
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"{mod}/ERROR,0,{type(e).__name__}: {e}", flush=True)
        print(f"# {mod} done in {time.time() - t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
