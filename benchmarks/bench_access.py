"""Fig. 6: ingestion-aware data access vs naive full-scan access.

Projection (columnar/cpax vs row), selection (post-filter vs sorted index
access vs partition pruning), aggregation + join over co-partitioned data,
and a 2-table TPC-H-like pipeline (Q3 shape: join + group-by).
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import DataAccess, IngestPlan, create_stage, format_, ingest, select
from repro.core import store as store_stmt
from repro.data.generators import as_file_items, gen_lineitem

from .common import Row, cleanup, fresh_store, lineitem_shards, timed


def _ingest_layouts(n):
    """One store holding the same data in row / columnar / cpax / sorted /
    range-partitioned variants (distinct label signatures)."""
    ds = fresh_store()
    from repro.core import chain_stage
    p = IngestPlan("acc")
    s1 = select(p, replicate=5, replicate_tag="rep")
    variants = {
        1: dict(chunk={"target_rows": 16384}, serialize="row"),
        2: dict(chunk={"target_rows": 16384}, serialize="columnar"),
        3: dict(chunk={"target_rows": 16384}, serialize="cpax"),
        4: dict(chunk={"target_rows": 16384}, order={"key": "orderkey"},
                serialize="sorted", serialize_args={"key": "orderkey"}),
        5: dict(partition={"scheme": "range", "key": "orderkey",
                           "num_partitions": 8},
                chunk={"target_rows": 16384}, serialize="columnar"),
    }
    create_stage(p, using=[s1], name="a")
    for i, kw in variants.items():
        f = format_(p, s1, **kw)
        st = store_stmt(p, f, upload=ds)
        chain_stage(p, to=["a"], using=[f, st], where={"rep": i}, name=f"v{i}")
    ingest(p, lineitem_shards(n), ds)
    return ds


def run(n: int = 200_000) -> List[Row]:
    ds = _ingest_layouts(n)
    acc = DataAccess(ds)
    rows: List[Row] = []

    # ---- projection: 2 of 8 columns
    proj = ["quantity", "discount"]
    t_row = timed(lambda: acc.filter_replica("rep", 1).read_all(projection=proj))
    t_col = timed(lambda: acc.filter_replica("rep", 2).read_all(projection=proj))
    t_cpax = timed(lambda: acc.filter_replica("rep", 3).read_all(projection=proj))
    rows += [("access/projection/row_naive", t_row, "1.00x"),
             ("access/projection/columnar", t_col, f"{t_row / t_col:.1f}x faster"),
             ("access/projection/cpax", t_cpax, f"{t_row / t_cpax:.1f}x faster")]

    # ---- selection: 1% range predicate
    hi = int(0.01 * n // 4)
    sel = ("orderkey", "<", hi)
    t_post = timed(lambda: acc.filter_replica("rep", 1).read_all(selection=sel))
    t_idx = timed(lambda: acc.filter_replica("rep", 4).read_all(selection=sel))

    def pruned():
        a = acc.filter_replica("rep", 5)
        a = a.filter_block_by_label("partition", 0)  # range partition 0
        return a.read_all(selection=sel)

    t_prune = timed(pruned)
    rows += [("access/selection/post_filter", t_post, "1.00x"),
             ("access/selection/index_sorted", t_idx, f"{t_post / t_idx:.1f}x faster"),
             ("access/selection/partition_prune", t_prune,
              f"{t_post / t_prune:.1f}x faster")]

    # ---- aggregation: sum(extendedprice) by suppkey.  The naive path pays
    # the MapReduce shuffle: hash-partition + DFS round-trip before reducing
    # (HDFS-Naive in Fig. 6 shuffles on the group-by key).
    import os, pickle

    def _shuffle_roundtrip(c, key, parts=8):
        buckets = {}
        pids = c[key] % parts
        for pid in range(parts):
            idx = np.nonzero(pids == pid)[0]
            buckets[pid] = {k: v[idx] for k, v in c.items()}
        sdir = os.path.join(ds.dfs_dir, "bench_shuffle")
        os.makedirs(sdir, exist_ok=True)
        for pid, cols in buckets.items():
            with open(os.path.join(sdir, f"p{pid}"), "wb") as f:
                pickle.dump(cols, f)
        out = []
        for pid in range(parts):
            with open(os.path.join(sdir, f"p{pid}"), "rb") as f:
                out.append(pickle.load(f))
        return out

    def agg_naive():
        c = acc.filter_replica("rep", 1).read_all()
        res = []
        for cols in _shuffle_roundtrip(c, "suppkey"):
            keys, inv = np.unique(cols["suppkey"], return_inverse=True)
            res.append(np.bincount(inv, weights=cols["extendedprice"]))
        return res

    def agg_aware():
        out = []
        a = acc.filter_replica("rep", 5)
        for split in a.split_by_key("partition"):
            c = a.read_split(split, projection=["suppkey", "extendedprice"])
            keys, inv = np.unique(c["suppkey"], return_inverse=True)
            out.append(np.bincount(inv, weights=c["extendedprice"]))
        return out

    t_an = timed(agg_naive)
    t_aa = timed(agg_aware)
    rows += [("access/aggregation/naive", t_an, "1.00x"),
             ("access/aggregation/co_grouped", t_aa, f"{t_an / t_aa:.1f}x")]

    # ---- join: lineitem x orders-like (self-join on orderkey partitions)
    def join_naive():
        a = acc.filter_replica("rep", 1).read_all(projection=["orderkey", "quantity"])
        b = acc.filter_replica("rep", 1).read_all(projection=["orderkey", "extendedprice"])
        # both relations shuffle on the join key (DFS round-trip), then join
        total = 0
        for pa, pb in zip(_shuffle_roundtrip(a, "orderkey"),
                          _shuffle_roundtrip(b, "orderkey")):
            total += np.intersect1d(pa["orderkey"], pb["orderkey"]).size
        return total

    def join_aware():
        a5 = acc.filter_replica("rep", 5)
        total = 0
        for row in a5.co_split_by_key("partition", (a5, "partition")):
            la = a5.read_split(row[0], projection=["orderkey", "quantity"])
            lb = a5.read_split(row[1], projection=["orderkey", "extendedprice"])
            total += np.intersect1d(la["orderkey"], lb["orderkey"]).size
        return total

    t_jn = timed(join_naive)
    t_ja = timed(join_aware)
    rows += [("access/join/naive_shuffle", t_jn, "1.00x"),
             ("access/join/co_partitioned", t_ja, f"{t_jn / t_ja:.1f}x")]

    cleanup(ds)
    return rows
