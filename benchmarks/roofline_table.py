"""Render the dry-run artifacts as the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def fmt_flops(x: float) -> str:
    return f"{x/1e12:.2f}T" if x >= 1e10 else f"{x/1e9:.1f}G"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--dir", default=ART)
    args = ap.parse_args()

    rows = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        a = json.load(open(f))
        if a.get("skipped") or a["mesh"] != args.mesh:
            continue
        r = a["roofline"]
        m = a["memory_analysis"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append({
            "arch": a["arch"], "shape": a["shape"],
            "comp": r["compute_s"], "mem": r["memory_s"],
            "coll": r["collective_s"], "dom": r["dominant"],
            "useful": r["useful_ratio"],
            "frac": r["compute_s"] / bound if bound else 0.0,
            "gib": (m["argument_bytes"] + m["temp_bytes"]) / 2**30,
            "mf": r["model_flops"], "hf": r["hlo_flops_global"],
        })

    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " MODEL/HLO FLOPs | roofline frac | GiB/dev |")
    print("|---|---|---:|---:|---:|---|---:|---:|---:|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['comp']:.3f} | {r['mem']:.3f} "
              f"| {r['coll']:.3f} | {r['dom']} | {r['useful']:.2f} "
              f"| {r['frac']:.2f} | {r['gib']:.1f} |")


if __name__ == "__main__":
    main()
