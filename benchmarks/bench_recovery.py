"""Table II: per-block post-ingestion recovery latency.

Replication-based, transformation-based (re-encode a differently-serialized
replica), erasure-based (RS stripe decode).
"""
from __future__ import annotations

import time
from typing import List

from repro.core import (ErasureRecovery, FaultToleranceDaemon, IngestPlan,
                        ReplicationRecovery, TransformationRecovery,
                        chain_stage, create_stage, format_, ingest, select)
from repro.core import store as store_stmt
from repro.core.operators import resolve_op

from .common import Row, cleanup, fresh_store, lineitem_shards


def _ingest_replicated(ds, n, layouts=("row", "row")):
    p = IngestPlan("r")
    s1 = select(p, replicate=len(layouts), replicate_tag="rep")
    create_stage(p, using=[s1], name="a")
    sts = []
    for i, layout in enumerate(layouts, start=1):
        f = format_(p, s1, chunk={"target_rows": 16384}, serialize=layout)
        st = store_stmt(p, f, upload=ds)
        chain_stage(p, to=["a"], using=[f, st], where={"rep": i}, name=f"v{i}")
    ingest(p, lineitem_shards(n), ds)


def _ingest_erasure(ds, n, k=4, m=2):
    p = IngestPlan("e")
    s1 = select(p)
    f = p.add_statement([resolve_op("chunk", target_rows=8192),
                         resolve_op("serialize", layout="row"),
                         resolve_op("erasure", k=k, m=m)],
                        kind="format", inputs=[s1])
    st = store_stmt(p, f, upload=ds)
    create_stage(p, using=[s1, f, st], name="main")
    ingest(p, lineitem_shards(n), ds)


def _recover_once(ds, udf, victim_pred) -> float:
    victim = next(e for e in ds.blocks() if victim_pred(e))
    ds.corrupt_block(victim.block_id)
    daemon = FaultToleranceDaemon(ds, [udf])
    rep = daemon.sweep()
    assert rep.recovered, f"{udf.name} failed to recover"
    return rep.per_block_seconds[victim.block_id]


def run(n: int = 200_000) -> List[Row]:
    rows: List[Row] = []

    ds = fresh_store()
    _ingest_replicated(ds, n, ("row", "row"))
    t = _recover_once(ds, ReplicationRecovery(), lambda e: e.replica_index == 0)
    rows.append(("recovery/replication_based", t, "per 64MB-block analogue"))
    cleanup(ds)

    ds = fresh_store()
    _ingest_replicated(ds, n, ("columnar", "row"))
    t = _recover_once(ds, TransformationRecovery(),
                      lambda e: e.layout == "columnar")
    rows.append(("recovery/transformation_based", t, "re-encodes layout"))
    cleanup(ds)

    ds = fresh_store()
    _ingest_erasure(ds, n)
    t = _recover_once(ds, ErasureRecovery(), lambda e: bool(e.stripe_id))
    rows.append(("recovery/erasure_based", t, "RS(4,2) stripe decode"))
    cleanup(ds)
    return rows
