"""Table I: transform-at-ingest (INGESTBASE) vs cooking jobs after upload.

The cooking baseline is implemented faithfully to the paper's critique: the
data is first uploaded raw, then a separate "query processor" job RE-READS the
whole stored dataset, applies the same transformation, and writes the result
back — the extra pass the paper measures Hive doing.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import (DataAccess, IngestPlan, create_stage, format_, ingest,
                        select)
from repro.core import store as store_stmt
from repro.core.operators import resolve_op
from repro.core.items import IngestItem, Granularity

from .common import (Row, cleanup, fresh_store, lineitem_shards,
                     plain_upload_seconds, run_plan_seconds, timed)


def _ingest_with(ops_builder, n):
    def build(p, ds):
        s1 = select(p)
        mid = p.add_statement(ops_builder(), kind="format", inputs=[s1])
        s2 = format_(p, mid, chunk={"target_rows": 16384}, serialize="row")
        s3 = store_stmt(p, s2, upload=ds)
        create_stage(p, using=[s1, mid, s2, s3], name="main")
    return run_plan_seconds(build, n)


def _cook_after(ops_builder, n):
    """Upload raw first, then run the cooking job: re-read the WHOLE stored
    dataset, apply the same transformation through the engine, and write the
    result back — the second full pass the paper charges to Hive."""
    ds = fresh_store()
    p = IngestPlan("raw")
    s1 = select(p)
    s2 = format_(p, s1, chunk={"target_rows": 16384}, serialize="row")
    s3 = store_stmt(p, s2, upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    t_upload = timed(lambda: ingest(p, lineitem_shards(n), ds))

    def cook():
        # full re-read of the ingested dataset ...
        cols = DataAccess(ds).read_all()
        from repro.data.generators import as_file_items
        items = as_file_items(cols, shards=8)
        # ... then a second full engine pass: transform + re-serialize + store
        p2 = IngestPlan("cook")
        c1 = select(p2)
        mid = p2.add_statement(ops_builder(), kind="format", inputs=[c1])
        c2 = format_(p2, mid, chunk={"target_rows": 16384}, serialize="row")
        c3 = store_stmt(p2, c2, upload=ds)
        create_stage(p2, using=[c1, mid, c2, c3], name="main")
        ingest(p2, items, ds)

    t_cook = timed(cook)
    cleanup(ds)
    return t_upload, t_cook


CASES = {
    "fd_check": lambda: [resolve_op("fd_check", lhs="shipdate",
                                    rhs="linestatus")],
    "dc_check": lambda: [resolve_op(
        "dc_check", violation_predicate=lambda c: (c["quantity"] < 3)
        & (c["discount"] > 0.09))],
    "random_sampling": lambda: [resolve_op("bernoulli_sample", p=0.01)],
}


def run(n: int = 200_000) -> List[Row]:
    base = plain_upload_seconds(n)
    rows: List[Row] = []
    for name, ops_builder in CASES.items():
        t_ingest, _ = _ingest_with(ops_builder, n)
        t_upload, t_cook = _cook_after(ops_builder, n)
        # Table I reports the transformation overhead ABOVE plain upload;
        # floor at 1% of the upload time (piggy-backed ops can vanish in noise
        # — which is the paper's point)
        over_ingest = max(t_ingest - base, 0.01 * base)
        over_cook = t_cook                          # the whole extra job
        rows.append((f"cooking/{name}/ingestbase", over_ingest,
                     f"total={t_ingest:.3f}s"))
        rows.append((f"cooking/{name}/cook_after", over_cook,
                     f"{over_cook / over_ingest:.1f}x slower"))
    return rows
