"""Streaming vs batch ingestion, and sequential vs *pipelined* epochs.

The streaming engine pays a commit (manifest rename) per epoch; the batch
engine pays one barrier at the end — the first rows report the price of
incremental visibility.  The second group runs a shuffle-stage plan through
the same engine with epoch pipelining off and on (ISSUE 2): epoch N+1's
ingest segment (parse/partition/shuffle/serialize) overlaps epoch N's store
segment (upload + commit), and the double-buffered shuffle moves the DFS
journal write off the barrier.  The source section (ISSUE 6) compares the
pushed path (coordinator renders and ships every item) against worker-pull
descriptor sources (coordinator ships metadata; workers materialize shards
locally) and asserts the pulled run moves zero item bytes through the
coordinator.  Results are appended to the ``BENCH_streaming.json``
trajectory file at the repo root.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import (DataStore, GeneratorSpecSource, IngestPlan,
                        RuntimeEngine, StreamFaultInjection,
                        StreamingRuntimeEngine, chain_stage, create_stage,
                        format_, resolve_op, select)
from repro.core import store as store_stmt
from repro.core.items import IngestItem

from .common import NODES, Row, cleanup, fresh_store, lineitem_shards, timed

SHARDS = 32
EPOCH_ITEMS = 4
TRAJECTORY = os.path.join(os.path.dirname(__file__), "..", "BENCH_streaming.json")

# CPU-heavy plan: per-line regex parsing is interpreter-bound (GIL-held),
# erasure coding is compute — the workload the process backend exists for
# (ISSUE 3).  The log-line format is the paper's cloud-log scenario.
LOG_PATTERN = (r"ts=(?P<ts>\d+) host=h(?P<host>\d+) level=(?:\w+) "
               r"orderkey=(?P<orderkey>\d+) partkey=(?P<partkey>\d+) "
               r"qty=(?P<qty>\d+) price=(?P<price>[\d.]+) "
               r"status=(?P<status>\d)")
LOG_SCHEMA = {"ts": "int64", "host": "int32", "orderkey": "int64",
              "partkey": "int64", "qty": "int32", "price": "float32",
              "status": "int8"}


def _plan(ds):
    p = IngestPlan("stream_bench")
    s1 = select(p)
    s2 = format_(p, s1, chunk={"target_rows": 8192}, serialize="columnar")
    s3 = store_stmt(p, s2, locate="roundrobin",
                    locate_args={"num_locations": len(ds.nodes)}, upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    return p


def _shuffled_plan(ds):
    """Ingest segment: parse + hash-partition + shuffle, then chunk +
    serialize + replicate (the paper's scenarios all keep >=2 replicas);
    store segment: locate + upload.  The segment split is what the epoch
    pipeliner overlaps: transform compute against replica upload I/O."""
    p = IngestPlan("stream_shuffle_bench")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey", num_partitions=8),
        # importable spec (not a closure): the same plan must ship by pickle
        # to process-backend workers for the shuffle backend comparison
        resolve_op("map", fn="repro.core.ops_select:identity_columns",
                   shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([
        resolve_op("chunk", target_rows=8192),
        resolve_op("serialize", layout="columnar"),
        resolve_op("replicate", copies=2, tag="bench_rep"),
    ], kind="format", inputs=[s1])
    s3 = p.add_statement([
        resolve_op("locate", scheme="roundrobin", num_locations=len(ds.nodes)),
        resolve_op("upload", store=ds),
    ], kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def _narrow_plan(ds):
    """Cone-capable 3-stage chain (ISSUE 8): no shuffle before the segment
    split, every ingest stage's replay cone is ``self`` — a mid-epoch node
    death replays only the dead node's shards instead of the whole epoch."""
    p = IngestPlan("recovery_bench")
    s1 = p.add_statement([resolve_op("identity_parser")], kind="select")
    s2 = p.add_statement([
        resolve_op("chunk", target_rows=8192),
        resolve_op("serialize", layout="columnar"),
    ], kind="format", inputs=[s1])
    s3 = p.add_statement([
        resolve_op("locate", scheme="roundrobin", num_locations=len(ds.nodes)),
        resolve_op("upload", store=ds),
    ], kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def _run_recovery(shards, cone: bool):
    """One streaming run of the narrow plan with a node death injected at
    epoch 1's last ingest stage — the deterministic cone scenario of the
    recovery tests, at benchmark scale.  Returns the stream report; the
    faulted epoch's commit latency (cut -> manifest rename, replay included)
    is the recovery cost."""
    ds = fresh_store()
    eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                 queue_capacity=2 * EPOCH_ITEMS,
                                 cone_recovery=cone)
    faults = StreamFaultInjection(node_death_at={(ds.nodes[2], 1): "b"})
    rep = eng.run_stream(_narrow_plan(ds), _fresh_shards(shards),
                         faults=faults)
    eng.close()
    cleanup(ds)
    return rep


def _cpu_heavy_plan(ds):
    """regex parse -> serialize -> erasure -> upload: throughput is bounded
    by GIL-held compute, so thread-backend nodes cannot run it in parallel —
    the thread-vs-process comparison plan."""
    p = IngestPlan("cpu_heavy_bench")
    s1 = p.add_statement([
        resolve_op("regex_parser", pattern=LOG_PATTERN,
                   schema=dict(LOG_SCHEMA), chunk_rows=16384),
    ], kind="select")
    s2 = p.add_statement([
        resolve_op("serialize", layout="columnar"),
        resolve_op("erasure", k=4, m=2),
    ], kind="format", inputs=[s1])
    s3 = p.add_statement([
        resolve_op("locate", scheme="roundrobin", num_locations=len(ds.nodes)),
        resolve_op("upload", store=ds),
    ], kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


_TEXT_CACHE: Dict[int, List[np.ndarray]] = {}
CPU_SHARDS = 8   # few fat shards: the parse dominates the per-item overhead


def _log_shards(scale: int, shards: int) -> List[np.ndarray]:
    """Raw log-line shards for the CPU-heavy parser, as uint8 arrays so the
    text rides the zero-copy shm data plane (cached: the Python rendering is
    itself expensive and must not count in the runs)."""
    if scale not in _TEXT_CACHE:
        from repro.data.generators import gen_lineitem
        cols = gen_lineitem(scale)
        lines = [f"ts={cols['shipdate'][i]} host=h{cols['suppkey'][i] % 64} "
                 f"level=INFO orderkey={cols['orderkey'][i]} "
                 f"partkey={cols['partkey'][i]} qty={cols['quantity'][i]} "
                 f"price={cols['extendedprice'][i]} "
                 f"status={cols['linestatus'][i]}" for i in range(scale)]
        per = -(-scale // shards)
        _TEXT_CACHE[scale] = [
            np.frombuffer("\n".join(chunk).encode(), dtype=np.uint8)
            for s in range(shards)
            if (chunk := lines[s * per:(s + 1) * per])]
    return _TEXT_CACHE[scale]


def _run_backend(shards: List[np.ndarray], backend: str):
    """One streaming run of the CPU-heavy 3-stage *non-shuffle* plan on the
    given node backend.  Returns (seconds, report): since ISSUE 5 the report
    carries the node-resident dataflow counters — the bench asserts
    ``stage_coordinator_bytes == 0`` (narrow stage edges stay resident in
    worker buckets; only store-registration metadata crosses the pipes)."""
    import tempfile
    n_nodes = min(os.cpu_count() or 2, 4)
    ds = DataStore(tempfile.mkdtemp(prefix="ibench_cpu_"),
                   nodes=NODES[:n_nodes])
    eng = StreamingRuntimeEngine(ds, epoch_items=2, queue_capacity=4,
                                 backend=backend)
    if backend == "process":
        eng.prewarm_executors()   # worker spawn is setup, not throughput
    t0 = time.perf_counter()
    rep = eng.run_stream(_cpu_heavy_plan(ds), (IngestItem(s) for s in shards))
    secs = time.perf_counter() - t0
    eng.close()
    cleanup(ds)
    return secs, rep


def _host_parallel_efficiency(n_procs: int) -> float:
    """Measured speedup of ``n_procs`` CPU-bound processes vs one on this
    host — the physical ceiling for the backend comparison.  Containers with
    throttled/shared cores report well under ``n_procs``; record it so the
    thread-vs-process numbers are interpretable."""
    import multiprocessing as mp

    solo = _spin()
    ctx = mp.get_context("fork" if "fork" in mp.get_all_start_methods()
                         else "spawn")
    t0 = time.perf_counter()
    procs = [ctx.Process(target=_spin) for _ in range(n_procs)]
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    wall = time.perf_counter() - t0
    return n_procs * solo / wall if wall else 1.0


def _spin(n: int = 400_000) -> float:
    import re as _re
    pat = _re.compile(r"ts=(\d+) host=h(\d+)")
    line = "ts=1234 host=h42 level=INFO orderkey=123"
    t0 = time.perf_counter()
    for _ in range(n):
        pat.match(line).groups()
    return time.perf_counter() - t0


def _fresh_shards(shards, delay_s: float = 0.0):
    """Re-materialize the shard list as a source; ``delay_s`` > 0 makes it a
    *rate-limited feed* (one shard per tick — streaming arrival, not a
    pre-materialized list)."""
    items = [IngestItem(dict(it.data), it.granularity) for it in shards]

    def gen():
        for it in items:
            if delay_s:
                time.sleep(delay_s)
            yield it

    return gen()


def _run_shuffle_backend(shards, backend: str, transport: str = "pipe",
                         columnar: bool = False):
    """One streaming run of the shuffle-stage plan with the worker-side
    partition exchange (ISSUE 4), on the given node backend.  Returns
    (seconds, report) — the report carries the coordinator-vs-peer byte
    counters the trajectory records.  ``transport="socket"`` (ISSUE 9)
    runs the same plan over the framed loopback TCP fabric instead of
    multiprocessing pipes — the gated cost of the multi-host transport.
    ``columnar`` is pinned OFF by default so the pre-ISSUE-10 legs stay
    item-at-a-time baselines; the columnar leg flips it on."""
    import tempfile
    n_nodes = min(os.cpu_count() or 2, 4)
    ds = DataStore(tempfile.mkdtemp(prefix="ibench_shuf_"),
                   nodes=NODES[:n_nodes])
    eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                 queue_capacity=2 * EPOCH_ITEMS,
                                 backend=backend, transport=transport,
                                 columnar=columnar)
    if backend == "process":
        eng.prewarm_executors()   # worker spawn is setup, not throughput
    t0 = time.perf_counter()
    rep = eng.run_stream(_shuffled_plan(ds), _fresh_shards(shards))
    secs = time.perf_counter() - t0
    eng.close()
    cleanup(ds)
    return secs, rep


def _run_columnar(scale: int, columnar: bool):
    """One streaming run of the shuffle-stage plan on the process backend
    with a worker-pull descriptor source (ISSUE 6) and the columnar data
    plane (ISSUE 10) on or off.  The pulled source keeps the third
    coordinator-byte counter at zero, so the columnar leg can assert the
    complete invariant: NO item bytes through the coordinator on any of
    the source, stage, or shuffle paths while column buffers cross every
    eligible edge.  Returns (seconds, report)."""
    import tempfile
    n_nodes = min(os.cpu_count() or 2, 4)
    ds = DataStore(tempfile.mkdtemp(prefix="ibench_col_"),
                   nodes=NODES[:n_nodes])
    eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                 queue_capacity=2 * EPOCH_ITEMS,
                                 backend="process", columnar=columnar)
    eng.prewarm_executors()   # worker spawn is setup, not throughput
    src = GeneratorSpecSource("repro.data.generators:gen_lineitem",
                              shards=SHARDS, rows=scale // SHARDS)
    t0 = time.perf_counter()
    rep = eng.run_stream(_shuffled_plan(ds), src)
    secs = time.perf_counter() - t0
    eng.close()
    cleanup(ds)
    return secs, rep


def _run_source(scale: int, mode: str):
    """One streaming run of the columnar plan on the process backend with the
    item bytes either *pushed* (legacy path: a coordinator-side generator
    renders every shard and feeds it through the coordinator) or *pulled*
    (ISSUE 6: the coordinator distributes shard *descriptors*; each worker
    materializes its own shards locally).  Both sides generate lazily from
    the same spec — the pushed feeder is one thread, the pulled readers run
    one per node.  Returns (seconds, report)."""
    import tempfile
    n_nodes = min(os.cpu_count() or 2, 4)
    ds = DataStore(tempfile.mkdtemp(prefix="ibench_src_"),
                   nodes=NODES[:n_nodes])
    eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                 queue_capacity=2 * EPOCH_ITEMS,
                                 backend="process")
    eng.prewarm_executors()   # worker spawn is setup, not throughput
    per = scale // SHARDS
    if mode == "pulled":
        source = GeneratorSpecSource("repro.data.generators:gen_lineitem",
                                     shards=SHARDS, rows=per)
    else:
        from repro.data.generators import gen_lineitem

        def gen():
            for i in range(SHARDS):
                yield IngestItem(gen_lineitem(per, seed=i))

        source = gen()
    t0 = time.perf_counter()
    rep = eng.run_stream(_plan(ds), source)
    secs = time.perf_counter() - t0
    eng.close()
    cleanup(ds)
    return secs, rep


def _sum_runs(rep, field: str) -> int:
    return sum(getattr(e.run, field) for e in rep.epochs)


def _stream_once(shards, plan_fn, *, legacy: bool, delay_s: float = 0.0):
    """One streaming run.  ``legacy=True`` configures the pre-ISSUE-2
    runtime: strictly sequential epochs, synchronous per-epoch DFS shuffle
    round-trips, and O(store) snapshot-manifest commits.  ``legacy=False``
    is the pipelined execution core: overlapped epochs on the persistent
    node executors, in-memory double-buffered shuffle, O(epoch) journal
    commits."""
    ds = fresh_store()
    ds.journal_commits = not legacy
    eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                 queue_capacity=2 * EPOCH_ITEMS,
                                 pipelined=not legacy,
                                 shuffle_synchronous=legacy)
    t0 = time.perf_counter()
    rep = eng.run_stream(plan_fn(ds), _fresh_shards(shards, delay_s))
    secs = time.perf_counter() - t0
    eng.close()
    cleanup(ds)
    return secs, rep


def _append_trajectory(record: Dict) -> None:
    history: List[Dict] = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(record)
    with open(TRAJECTORY, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")


def run(scale: int) -> List[Row]:
    rows: List[Row] = []
    shards = lineitem_shards(scale, SHARDS)

    # ---- batch baseline: one full-barrier run
    ds = fresh_store()
    batch_s = timed(lambda: RuntimeEngine(ds).run(_plan(ds), list(shards)))
    cleanup(ds)
    rows.append(("streaming/batch_engine", batch_s,
                 f"{scale / batch_s:,.0f} rows/s"))

    # ---- streaming: same data as an unbounded feed, micro-batch epochs
    stream_s, rep = _stream_once(shards, _plan, legacy=False)
    lat = sorted(rep.commit_latencies())
    p50 = lat[len(lat) // 2]
    p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))]
    rows.append(("streaming/streaming_engine", stream_s,
                 f"{scale / stream_s:,.0f} rows/s "
                 f"({stream_s / batch_s:.2f}x batch, "
                 f"{len(rep.epochs)} epochs)"))
    rows.append(("streaming/epoch_commit_p50", p50, f"{p50 * 1e3:.1f} ms"))
    rows.append(("streaming/epoch_commit_p99", p99, f"{p99 * 1e3:.1f} ms"))

    # ---- sequential vs pipelined epochs over a shuffle-stage plan (ISSUE 2):
    # the pre-ISSUE-2 runtime (sequential epochs, sync DFS shuffle, snapshot
    # commits) against the pipelined execution core on the same plan + data
    # (best-of-N like the rest of the harness: the container scheduler is noisy)
    from .common import REPEATS
    seq_s, seq_rep = min((_stream_once(shards, _shuffled_plan, legacy=True)
                          for _ in range(REPEATS)), key=lambda t: t[0])
    pipe_s, pipe_rep = min((_stream_once(shards, _shuffled_plan, legacy=False)
                            for _ in range(REPEATS)), key=lambda t: t[0])
    speedup = seq_s / pipe_s
    rows.append(("streaming/shuffle_sequential_epochs", seq_s,
                 f"{scale / seq_s:,.0f} rows/s ({len(seq_rep.epochs)} epochs; "
                 f"sync shuffle, snapshot commits)"))
    rows.append(("streaming/shuffle_pipelined_epochs", pipe_s,
                 f"{scale / pipe_s:,.0f} rows/s ({speedup:.2f}x sequential)"))

    # ---- worker-side shuffle (ISSUE 4): the peer-to-peer partition
    # exchange on both backends.  The acceptance invariant is recorded, not
    # assumed: zero item bytes through the coordinator's shuffle path
    # (shuffle_coordinator_bytes) while shuffle_peer_bytes carries the
    # partitions worker-to-worker.  shuffle_rows_per_s (process backend) is
    # the nightly-gated metric — on a multi-core runner the exchange lets
    # shuffle throughput scale with host_cores instead of serializing on
    # the coordinator pipe.
    shuf_thread_s, shuf_trep = min((_run_shuffle_backend(shards, "thread")
                                    for _ in range(REPEATS)),
                                   key=lambda t: t[0])
    shuf_proc_s, shuf_prep = min((_run_shuffle_backend(shards, "process")
                                  for _ in range(REPEATS)),
                                 key=lambda t: t[0])
    coord_bytes = _sum_runs(shuf_prep, "shuffle_coordinator_bytes")
    peer_bytes = _sum_runs(shuf_prep, "shuffle_peer_bytes")
    rows.append(("streaming/shuffle_exchange_thread", shuf_thread_s,
                 f"{scale / shuf_thread_s:,.0f} rows/s (peer exchange, "
                 f"coordinator bytes "
                 f"{_sum_runs(shuf_trep, 'shuffle_coordinator_bytes')})"))
    rows.append(("streaming/shuffle_exchange_process", shuf_proc_s,
                 f"{scale / shuf_proc_s:,.0f} rows/s "
                 f"({shuf_thread_s / shuf_proc_s:.2f}x thread; "
                 f"coordinator {coord_bytes} B, peer {peer_bytes:,} B)"))

    # ---- socket fabric (ISSUE 9): the SAME shuffle plan + process backend,
    # but control and store channels ride the framed loopback TCP transport
    # instead of multiprocessing pipes.  socket_rows_per_s is nightly-gated
    # against its own trajectory; the pipe run above is the in-record
    # baseline — framing + CRC + a loopback hop is the whole price of
    # multi-host capability, and it should stay a modest constant factor.
    sock_s, sock_rep = min((_run_shuffle_backend(shards, "process",
                                                 transport="socket")
                            for _ in range(REPEATS)), key=lambda t: t[0])
    rows.append(("streaming/shuffle_socket_transport", sock_s,
                 f"{scale / sock_s:,.0f} rows/s "
                 f"({sock_s / shuf_proc_s:.2f}x pipe transport; framed "
                 f"TCP loopback)"))

    # ---- columnar data plane (ISSUE 10): the SAME shuffle plan + process
    # backend + worker-pull source, item-at-a-time vs column buffers across
    # every eligible stage edge.  The columnar run must hold the complete
    # zero-coordinator-bytes story — source, stage, AND shuffle counters all
    # zero — while columnar_rounds proves the plane was actually engaged and
    # columnar_fallbacks stays 0 (no silent scalar retreat).
    # columnar_rows_per_s is the nightly-gated metric.
    item_s, item_rep = min((_run_columnar(scale, columnar=False)
                            for _ in range(REPEATS)), key=lambda t: t[0])
    col_s, col_rep = min((_run_columnar(scale, columnar=True)
                          for _ in range(REPEATS)), key=lambda t: t[0])
    assert col_rep.columnar_rounds() > 0, (
        "columnar leg ran zero columnar exchange rounds — the edge "
        "annotation or round gating is broken")
    assert col_rep.columnar_fallbacks() == 0, (
        f"columnar leg fell back to items {col_rep.columnar_fallbacks()} "
        f"times on a uniform columnar plan")
    for counter in ("source_coordinator_bytes", "stage_coordinator_bytes",
                    "shuffle_coordinator_bytes"):
        leaked = _sum_runs(col_rep, counter)
        assert leaked == 0, (
            f"columnar leg leaked {leaked} B through the coordinator "
            f"({counter})")
    columnar_speedup = item_s / col_s
    rows.append(("streaming/columnar_item_at_a_time", item_s,
                 f"{scale / item_s:,.0f} rows/s (pulled source, scalar "
                 f"exchange baseline)"))
    rows.append(("streaming/columnar_plane", col_s,
                 f"{scale / col_s:,.0f} rows/s ({columnar_speedup:.2f}x "
                 f"item-at-a-time; {col_rep.columnar_rounds()} columnar "
                 f"rounds, {col_rep.columnar_bytes():,} B as columns, "
                 f"0 fallbacks, 0 coordinator bytes)"))

    # ---- thread vs process node backend on the CPU-heavy plan (ISSUE 3):
    # regex parse is interpreter-bound (GIL-held), so thread-backend nodes
    # serialize on one core while process-backend workers use them all.
    # Same data, same plan, only the node substrate changes.  The host's raw
    # n-process parallel efficiency is measured alongside: on throttled/
    # shared-core containers it is the physical ceiling of the comparison.
    host_cores = os.cpu_count() or 1
    n_workers = min(host_cores, 4)
    parallel_ceiling = _host_parallel_efficiency(n_workers)
    text = _log_shards(scale, CPU_SHARDS)
    thread_s, _ = min((_run_backend(text, "thread") for _ in range(REPEATS)),
                      key=lambda t: t[0])
    proc_s, proc_rep = min((_run_backend(text, "process")
                            for _ in range(REPEATS)), key=lambda t: t[0])
    backend_speedup = thread_s / proc_s
    # node-resident dataflow (ISSUE 5): the 3-stage non-shuffle process plan
    # must move ZERO item bytes through coordinator pipes at stage
    # boundaries — asserted here so the nightly records the invariant, not
    # an assumption.  resident_rows_per_s is the gated throughput of this
    # zero-coordinator path (>= the PR-4 process_rows_per_s, which paid a
    # coordinator round-trip per stage edge).
    stage_coord_bytes = _sum_runs(proc_rep, "stage_coordinator_bytes")
    resident_bytes = _sum_runs(proc_rep, "stage_resident_bytes")
    assert stage_coord_bytes == 0, (
        f"resident dataflow leaked {stage_coord_bytes} B through the "
        f"coordinator on a non-shuffle process plan")
    rows.append(("streaming/cpu_heavy_thread_backend", thread_s,
                 f"{scale / thread_s:,.0f} rows/s (regex parse + erasure, "
                 f"{host_cores} cores)"))
    rows.append(("streaming/cpu_heavy_process_backend", proc_s,
                 f"{scale / proc_s:,.0f} rows/s ({backend_speedup:.2f}x thread "
                 f"backend; host {n_workers}-proc ceiling "
                 f"{parallel_ceiling:.2f}x; stage coordinator bytes "
                 f"{stage_coord_bytes}, resident {resident_bytes:,} B)"))

    # ---- pushed vs worker-pull sources (ISSUE 6): same spec, same plan,
    # same process backend.  Pushed renders every shard in the coordinator's
    # feeder thread and ships the bytes down worker pipes; pulled ships
    # shard DESCRIPTORS (metadata) and each worker materializes its own
    # shards.  The acceptance invariant is asserted, not assumed: zero item
    # bytes through the coordinator on the pulled run.  pull_rows_per_s is
    # the nightly-gated metric.
    src_rows = SHARDS * (scale // SHARDS)
    push_s, push_rep = min((_run_source(scale, "pushed")
                            for _ in range(REPEATS)), key=lambda t: t[0])
    pull_s, pull_rep = min((_run_source(scale, "pulled")
                            for _ in range(REPEATS)), key=lambda t: t[0])
    pull_coord_bytes = _sum_runs(pull_rep, "source_coordinator_bytes")
    push_coord_bytes = _sum_runs(push_rep, "source_coordinator_bytes")
    n_descriptors = _sum_runs(pull_rep, "source_descriptors")
    assert pull_coord_bytes == 0, (
        f"worker-pull source leaked {pull_coord_bytes} B of item bytes "
        f"through the coordinator")
    assert push_coord_bytes > 0, (
        "pushed-source baseline recorded zero coordinator bytes — the "
        "legacy-path counter is broken")
    rows.append(("streaming/source_pushed", push_s,
                 f"{src_rows / push_s:,.0f} rows/s (coordinator-fed items, "
                 f"{push_coord_bytes:,} B through coordinator)"))
    rows.append(("streaming/source_pulled", pull_s,
                 f"{src_rows / pull_s:,.0f} rows/s "
                 f"({push_s / pull_s:.2f}x pushed; {n_descriptors} "
                 f"descriptors, coordinator bytes {pull_coord_bytes})"))

    # ---- lineage-cone recovery (ISSUE 8): the same injected mid-epoch
    # death on the narrow plan, cone recovery on vs the whole-epoch
    # fallback.  The faulted epoch's commit latency (epoch cut -> manifest
    # rename, replay included) is the recovery cost; the cone replays only
    # the dead node's shards where the fallback recomputes the whole epoch.
    # recovery_ms (the cone road) is nightly-gated LOWER-is-better.
    def _faulted_latency(cone: bool):
        rep = _run_recovery(shards, cone)
        faulted = next(e for e in rep.epochs if e.epoch == 1)
        return faulted.commit_latency_s, rep

    cone_lat, cone_rep = min((_faulted_latency(True)
                              for _ in range(REPEATS)), key=lambda t: t[0])
    whole_lat, whole_rep = min((_faulted_latency(False)
                                for _ in range(REPEATS)), key=lambda t: t[0])
    assert cone_rep.cone_replays() >= 1, "injected death missed the cone road"
    assert cone_rep.replayed_rows() < whole_rep.replayed_rows(), (
        "cone replay recomputed as many rows as the whole-epoch fallback")
    rows.append(("streaming/recovery_cone", cone_lat,
                 f"{cone_lat * 1e3:.1f} ms faulted-epoch commit "
                 f"({cone_rep.replayed_rows()} rows replayed)"))
    rows.append(("streaming/recovery_whole_epoch", whole_lat,
                 f"{whole_lat * 1e3:.1f} ms faulted-epoch commit "
                 f"({whole_rep.replayed_rows()} rows replayed, "
                 f"{whole_lat / cone_lat:.2f}x cone)"))

    _append_trajectory({
        "ts": time.time(),
        "scale": scale,
        "batch_s": batch_s,
        "stream_s": stream_s,
        "epoch_commit_p50_s": p50,
        "epoch_commit_p99_s": p99,
        "shuffle_sequential_s": seq_s,
        "shuffle_pipelined_s": pipe_s,
        "pipelined_speedup": speedup,
        "sequential_epochs": seq_rep.committed_epoch_ids(),
        "pipelined_epochs": pipe_rep.committed_epoch_ids(),
        "pipelined_rows_per_s": scale / pipe_s,
        "cpu_heavy_thread_s": thread_s,
        "cpu_heavy_process_s": proc_s,
        "process_backend_speedup": backend_speedup,
        "process_rows_per_s": scale / proc_s,
        # ISSUE 5: the SAME cpu-heavy process run, re-recorded under the
        # gated name — its stage edges are now node-resident end-to-end
        # (stage_coordinator_bytes asserted 0 above).  process_rows_per_s
        # stays for cross-PR comparability but is NOT in the gate's default
        # metric set; resident_rows_per_s is its gated successor.
        "resident_rows_per_s": scale / proc_s,
        "stage_coordinator_bytes": stage_coord_bytes,
        "stage_resident_bytes": resident_bytes,
        "shuffle_thread_s": shuf_thread_s,
        "shuffle_process_s": shuf_proc_s,
        "shuffle_rows_per_s": scale / shuf_proc_s,
        "shuffle_thread_rows_per_s": scale / shuf_thread_s,
        "shuffle_coordinator_bytes": coord_bytes,
        "shuffle_peer_bytes": peer_bytes,
        # ISSUE 9: the framed loopback TCP fabric on the same shuffle plan —
        # socket_rows_per_s is gated; socket_vs_pipe rides along so the
        # transport tax stays visible next to its pipe baseline.
        "socket_s": sock_s,
        "socket_rows_per_s": scale / sock_s,
        "socket_vs_pipe": sock_s / shuf_proc_s,
        # ISSUE 10: the columnar data plane — columnar_rows_per_s is gated;
        # the item-at-a-time leg (same plan, same pulled source, columnar
        # pinned off) rides along as the in-record baseline, and the round/
        # byte counters keep the engagement observable in the trajectory.
        "columnar_item_s": item_s,
        "columnar_s": col_s,
        "columnar_rows_per_s": scale / col_s,
        "columnar_item_rows_per_s": scale / item_s,
        "columnar_speedup": columnar_speedup,
        "columnar_rounds": col_rep.columnar_rounds(),
        "columnar_bytes": col_rep.columnar_bytes(),
        "columnar_fallbacks": col_rep.columnar_fallbacks(),
        # ISSUE 6: worker-pull sources — pull_rows_per_s is gated; the
        # pushed baseline rides along for the hop-deletion comparison.
        "source_pushed_s": push_s,
        "source_pulled_s": pull_s,
        "push_rows_per_s": src_rows / push_s,
        "pull_rows_per_s": src_rows / pull_s,
        "pull_speedup": push_s / pull_s,
        "source_coordinator_bytes": pull_coord_bytes,
        "source_pushed_coordinator_bytes": push_coord_bytes,
        "source_descriptors": n_descriptors,
        "source_reissues": _sum_runs(pull_rep, "source_reissues"),
        # ISSUE 8: lineage-cone recovery — recovery_ms is gated (LOWER is
        # better: fresh/base - 1 in perf_gate); the whole-epoch fallback
        # latency and replayed-row counts ride along for the comparison.
        "recovery_ms": cone_lat * 1e3,
        "recovery_whole_epoch_ms": whole_lat * 1e3,
        "recovery_replayed_rows": cone_rep.replayed_rows(),
        "recovery_whole_epoch_replayed_rows": whole_rep.replayed_rows(),
        "host_cores": host_cores,
        "process_workers": n_workers,
        "host_parallel_ceiling": parallel_ceiling,
    })
    return rows
