"""Streaming vs batch ingestion: sustained throughput and epoch-commit
latency.  The streaming engine pays a commit (manifest rename) per epoch; the
batch engine pays one barrier at the end — this bench reports the price of
incremental visibility."""
from __future__ import annotations

import time
from typing import List

from repro.core import (IngestPlan, RuntimeEngine, StreamingRuntimeEngine,
                        create_stage, format_, select)
from repro.core import store as store_stmt
from repro.core.items import IngestItem

from .common import Row, cleanup, fresh_store, lineitem_shards, timed

SHARDS = 32
EPOCH_ITEMS = 4


def _plan(ds):
    p = IngestPlan("stream_bench")
    s1 = select(p)
    s2 = format_(p, s1, chunk={"target_rows": 8192}, serialize="columnar")
    s3 = store_stmt(p, s2, locate="roundrobin",
                    locate_args={"num_locations": len(ds.nodes)}, upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    return p


def run(scale: int) -> List[Row]:
    rows: List[Row] = []
    shards = lineitem_shards(scale, SHARDS)

    # ---- batch baseline: one full-barrier run
    ds = fresh_store()
    batch_s = timed(lambda: RuntimeEngine(ds).run(_plan(ds), list(shards)))
    cleanup(ds)
    rows.append(("streaming/batch_engine", batch_s,
                 f"{scale / batch_s:,.0f} rows/s"))

    # ---- streaming: same data as an unbounded feed, micro-batch epochs
    ds = fresh_store()
    eng = StreamingRuntimeEngine(ds, epoch_items=EPOCH_ITEMS,
                                 queue_capacity=2 * EPOCH_ITEMS)
    t0 = time.perf_counter()
    rep = eng.run_stream(_plan(ds), iter([IngestItem(dict(it.data), it.granularity)
                                          for it in shards]))
    stream_s = time.perf_counter() - t0
    cleanup(ds)
    lat = sorted(rep.commit_latencies())
    p50 = lat[len(lat) // 2]
    p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))]
    rows.append(("streaming/streaming_engine", stream_s,
                 f"{scale / stream_s:,.0f} rows/s "
                 f"({stream_s / batch_s:.2f}x batch, "
                 f"{len(rep.epochs)} epochs)"))
    rows.append(("streaming/epoch_commit_p50", p50, f"{p50 * 1e3:.1f} ms"))
    rows.append(("streaming/epoch_commit_p99", p99, f"{p99 * 1e3:.1f} ms"))
    return rows
