"""Roofline report: reads the dry-run artifacts and prints the three-term
roofline per (arch x shape x mesh) — the §Roofline deliverable's data source.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

from .common import Row

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_artifacts(mesh: str = None):
    out = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        a = json.load(open(f))
        if a.get("skipped"):
            continue
        if mesh and a["mesh"] != mesh:
            continue
        out.append(a)
    return out


def run(n: int = 0) -> List[Row]:
    rows: List[Row] = []
    for a in load_artifacts():
        r = a["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / bound if bound else 0.0
        rows.append((
            f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}",
            bound,
            f"dom={r['dominant']};comp={r['compute_s']:.3f}s;"
            f"mem={r['memory_s']:.3f}s;coll={r['collective_s']:.3f}s;"
            f"useful={r['useful_ratio']:.2f};roofline_frac={frac:.2f}",
        ))
    if not rows:
        rows.append(("roofline/NO_ARTIFACTS", 0.0,
                     "run python -m repro.launch.dryrun --all first"))
    return rows
