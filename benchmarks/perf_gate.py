"""CI perf gate over the streaming benchmark trajectory.

The nightly job appends a fresh record to ``BENCH_streaming.json``
(``benchmarks.bench_streaming``) and then runs this gate: it compares the
fresh entry's throughput metrics against the previous entry *at the same
benchmark scale* and fails the job (exit 1) on a regression beyond the
threshold.  Gated metrics default to ``pipelined_rows_per_s`` (the
pipelined-core throughput), ``shuffle_rows_per_s`` (the worker-side
peer-exchange shuffle, ISSUE 4), ``resident_rows_per_s`` (the
node-resident dataflow on the process backend, ISSUE 5), and
``pull_rows_per_s`` (worker-pull descriptor sources, ISSUE 6),
``erasure_mb_per_s`` (the batched erasure encode tier, ISSUE 7 — read from
``BENCH_storage.json``), and ``recovery_ms`` (the lineage-cone faulted-epoch
commit latency, ISSUE 8 — in ``LOWER_IS_BETTER``, so the regression
direction inverts: a *rise* beyond the threshold fails); ``--metric`` may
be repeated to gate a custom set.
Each metric reads the trajectory file in ``METRIC_FILES`` unless an explicit
``--file`` overrides it for all metrics.  With fewer than two comparable
entries for a metric (first run, wiped trajectory, pre-metric history,
unreadable file) that metric skips cleanly — a missing history must never
fail the build.

Usage::

    python -m benchmarks.perf_gate [--file BENCH_streaming.json]
        [--metric pipelined_rows_per_s --metric shuffle_rows_per_s]
        [--threshold 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Tuple

DEFAULT_FILE = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_streaming.json")
STORAGE_FILE = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_storage.json")
DEFAULT_METRIC = "pipelined_rows_per_s"
DEFAULT_METRICS = (DEFAULT_METRIC, "shuffle_rows_per_s",
                   "resident_rows_per_s", "pull_rows_per_s",
                   "erasure_mb_per_s", "recovery_ms",
                   "socket_rows_per_s", "columnar_rows_per_s")
# per-metric trajectory files; metrics not listed read DEFAULT_FILE
METRIC_FILES = {"erasure_mb_per_s": STORAGE_FILE}
# latency-style metrics regress by RISING: drop = fresh/base - 1 instead of
# 1 - fresh/base, so the same threshold bounds the allowed increase
LOWER_IS_BETTER = {"recovery_ms"}
DEFAULT_THRESHOLD = 0.25


def check(path: str, metric: str = DEFAULT_METRIC,
          threshold: float = DEFAULT_THRESHOLD) -> Tuple[int, str]:
    """Compare the trajectory's last entry against its predecessor.

    Returns ``(exit_code, message)``: 0 = pass or clean skip, 1 = regression
    beyond ``threshold`` (fractional, e.g. 0.25 = 25%).
    """
    if not os.path.exists(path):
        return 0, f"perf gate: no trajectory at {path} — skipping"
    try:
        with open(path) as f:
            history = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        return 0, f"perf gate: unreadable trajectory ({e}) — skipping"
    entries = [h for h in history
               if isinstance(h, dict) and h.get(metric)]
    if entries and entries[-1].get("scale") is not None:
        # rows/s is scale-dependent: only entries at the fresh run's scale
        # are comparable baselines (manual runs at other scales don't gate)
        scale = entries[-1]["scale"]
        entries = [h for h in entries if h.get("scale") == scale]
    if entries and entries[-1].get("host_cores") is not None:
        # ... and hardware-dependent: dev-container entries must not gate a
        # CI runner (or vice versa).  host_cores is the recorded proxy, so
        # a runner's first nightly skips cleanly instead of comparing
        # against different hardware's baseline.
        cores = entries[-1]["host_cores"]
        entries = [h for h in entries if h.get("host_cores") == cores]
    if len(entries) < 2:
        return 0, (f"perf gate: {len(entries)} entr{'y' if len(entries) == 1 else 'ies'} "
                   f"with {metric!r} — nothing to compare, skipping")
    prev, last = entries[-2], entries[-1]
    base, fresh = float(prev[metric]), float(last[metric])
    if base <= 0:
        return 0, f"perf gate: baseline {metric}={base} — skipping"
    if metric in LOWER_IS_BETTER:
        drop = fresh / base - 1.0
    else:
        drop = 1.0 - fresh / base
    detail = f"{metric}: {fresh:,.0f} vs {base:,.0f} baseline ({-drop:+.1%})"
    if drop > threshold:
        return 1, f"perf gate: REGRESSION {detail} exceeds {threshold:.0%} budget"
    return 0, f"perf gate: OK {detail}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--file", default=None,
                    help="trajectory file for ALL metrics (default: the "
                         "per-metric METRIC_FILES map)")
    ap.add_argument("--metric", action="append", default=None,
                    help="gated metric; repeatable (default: "
                         + ", ".join(DEFAULT_METRICS) + ")")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD)
    args = ap.parse_args(argv)
    worst = 0
    for metric in (args.metric or list(DEFAULT_METRICS)):
        path = args.file or METRIC_FILES.get(metric, DEFAULT_FILE)
        code, msg = check(path, metric, args.threshold)
        print(msg)
        worst = max(worst, code)
    return worst


if __name__ == "__main__":
    sys.exit(main())
