"""Fig. 5(d): storage-space optimization at ingest time vs plain upload.

Flexible replication (hot 10x / cold 2x), erasure coding RS(10,3), flexible
erasure (RS(5,3) hot / RS(10,3) cold), mixed replication+erasure.
"""
from __future__ import annotations

from typing import List

from repro.core import chain_stage, create_stage, format_, select
from repro.core import store as store_stmt
from repro.core.operators import resolve_op

from .common import Row, plain_upload_seconds, run_plan_seconds


def _partitioned(p, num=10):
    s1 = select(p)
    part = p.add_statement([resolve_op("partition", scheme="range",
                                       key="shipdate", num_partitions=num),
                            resolve_op("chunk", target_rows=8192)],
                           kind="format", inputs=[s1])
    return s1, part


def flexible_replication(p, ds):
    s1, part = _partitioned(p)
    hot = p.add_statement([resolve_op("replicate", copies=10),
                           resolve_op("serialize", layout="row")],
                          kind="format", inputs=[part])
    cold = p.add_statement([resolve_op("replicate", copies=2),
                            resolve_op("serialize", layout="row")],
                           kind="format", inputs=[part])
    st = store_stmt(p, hot, cold, upload=ds)
    create_stage(p, using=[s1, part], name="a")
    chain_stage(p, to=["a"], using=[hot], where={"partition": 0}, name="hot")
    chain_stage(p, to=["a"], using=[cold],
                where={"partition": lambda v: v is not None and v > 0},
                name="cold")
    chain_stage(p, to=["hot", "cold"], using=[st], name="up")


def erasure_10_3(p, ds):
    s1, part = _partitioned(p)
    enc = p.add_statement([resolve_op("serialize", layout="row"),
                           resolve_op("erasure", k=10, m=3)],
                          kind="format", inputs=[part])
    st = store_stmt(p, enc, upload=ds)
    create_stage(p, using=[s1, part, enc, st], name="main")


def flexible_erasure(p, ds):
    s1, part = _partitioned(p)
    hot = p.add_statement([resolve_op("serialize", layout="row"),
                           resolve_op("erasure", k=5, m=3)],
                          kind="format", inputs=[part])
    cold = p.add_statement([resolve_op("serialize", layout="row"),
                            resolve_op("erasure", k=10, m=3)],
                           kind="format", inputs=[part])
    st = store_stmt(p, hot, cold, upload=ds)
    create_stage(p, using=[s1, part], name="a")
    chain_stage(p, to=["a"], using=[hot], where={"partition": 0}, name="hot")
    chain_stage(p, to=["a"], using=[cold],
                where={"partition": lambda v: v is not None and v > 0},
                name="cold")
    chain_stage(p, to=["hot", "cold"], using=[st], name="up")


def mixed_replication_erasure(p, ds):
    s1, part = _partitioned(p)
    hot = p.add_statement([resolve_op("replicate", copies=10),
                           resolve_op("serialize", layout="row")],
                          kind="format", inputs=[part])
    cold = p.add_statement([resolve_op("serialize", layout="row"),
                            resolve_op("erasure", k=10, m=3)],
                           kind="format", inputs=[part])
    st = store_stmt(p, hot, cold, upload=ds)
    create_stage(p, using=[s1, part], name="a")
    chain_stage(p, to=["a"], using=[hot], where={"partition": 0}, name="hot")
    chain_stage(p, to=["a"], using=[cold],
                where={"partition": lambda v: v is not None and v > 0},
                name="cold")
    chain_stage(p, to=["hot", "cold"], using=[st], name="up")


def run(n: int = 200_000) -> List[Row]:
    base = plain_upload_seconds(n)
    rows: List[Row] = [("storage/plain_upload", base, "1.00x")]
    for name, build in (("flexible_replication", flexible_replication),
                        ("erasure_rs10_3", erasure_10_3),
                        ("flexible_erasure", flexible_erasure),
                        ("mixed_repl_erasure", mixed_replication_erasure)):
        secs, ds = run_plan_seconds(build, n, keep_store=True)
        stored = ds.total_bytes() / 1e6
        from .common import cleanup
        cleanup(ds)
        rows.append((f"storage/{name}", secs,
                     f"{secs / base:.2f}x;{stored:.1f}MB"))
    return rows
