"""Fig. 5(d): storage-space optimization at ingest time vs plain upload.

Flexible replication (hot 10x / cold 2x), erasure coding RS(10,3), flexible
erasure (RS(5,3) hot / RS(10,3) cold), mixed replication+erasure.

The kernel-tier section (ISSUE 7) measures the batched erasure path — one
stacked GF(256) matmul over all of a batch's stripes — against the scalar
per-stripe iterator path, and appends ``erasure_mb_per_s`` to the
``BENCH_storage.json`` trajectory for the nightly perf gate.
"""
from __future__ import annotations

import copy
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import chain_stage, create_stage, format_, select
from repro.core import store as store_stmt
from repro.core.items import Granularity, IngestItem
from repro.core.operators import resolve_op

from .common import Row, plain_upload_seconds, run_plan_seconds

TRAJECTORY = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_storage.json")
ERASURE_REPEATS = 3
ERASURE_BLOCK_BYTES = 64 * 1024
ERASURE_K, ERASURE_M = 10, 3


def _partitioned(p, num=10):
    s1 = select(p)
    part = p.add_statement([resolve_op("partition", scheme="range",
                                       key="shipdate", num_partitions=num),
                            resolve_op("chunk", target_rows=8192)],
                           kind="format", inputs=[s1])
    return s1, part


def flexible_replication(p, ds):
    s1, part = _partitioned(p)
    hot = p.add_statement([resolve_op("replicate", copies=10),
                           resolve_op("serialize", layout="row")],
                          kind="format", inputs=[part])
    cold = p.add_statement([resolve_op("replicate", copies=2),
                            resolve_op("serialize", layout="row")],
                           kind="format", inputs=[part])
    st = store_stmt(p, hot, cold, upload=ds)
    create_stage(p, using=[s1, part], name="a")
    chain_stage(p, to=["a"], using=[hot], where={"partition": 0}, name="hot")
    chain_stage(p, to=["a"], using=[cold],
                where={"partition": lambda v: v is not None and v > 0},
                name="cold")
    chain_stage(p, to=["hot", "cold"], using=[st], name="up")


def erasure_10_3(p, ds):
    s1, part = _partitioned(p)
    enc = p.add_statement([resolve_op("serialize", layout="row"),
                           resolve_op("erasure", k=10, m=3)],
                          kind="format", inputs=[part])
    st = store_stmt(p, enc, upload=ds)
    create_stage(p, using=[s1, part, enc, st], name="main")


def flexible_erasure(p, ds):
    s1, part = _partitioned(p)
    hot = p.add_statement([resolve_op("serialize", layout="row"),
                           resolve_op("erasure", k=5, m=3)],
                          kind="format", inputs=[part])
    cold = p.add_statement([resolve_op("serialize", layout="row"),
                            resolve_op("erasure", k=10, m=3)],
                           kind="format", inputs=[part])
    st = store_stmt(p, hot, cold, upload=ds)
    create_stage(p, using=[s1, part], name="a")
    chain_stage(p, to=["a"], using=[hot], where={"partition": 0}, name="hot")
    chain_stage(p, to=["a"], using=[cold],
                where={"partition": lambda v: v is not None and v > 0},
                name="cold")
    chain_stage(p, to=["hot", "cold"], using=[st], name="up")


def mixed_replication_erasure(p, ds):
    s1, part = _partitioned(p)
    hot = p.add_statement([resolve_op("replicate", copies=10),
                           resolve_op("serialize", layout="row")],
                          kind="format", inputs=[part])
    cold = p.add_statement([resolve_op("serialize", layout="row"),
                            resolve_op("erasure", k=10, m=3)],
                           kind="format", inputs=[part])
    st = store_stmt(p, hot, cold, upload=ds)
    create_stage(p, using=[s1, part], name="a")
    chain_stage(p, to=["a"], using=[hot], where={"partition": 0}, name="hot")
    chain_stage(p, to=["a"], using=[cold],
                where={"partition": lambda v: v is not None and v > 0},
                name="cold")
    chain_stage(p, to=["hot", "cold"], using=[st], name="up")


def _append_trajectory(record: Dict) -> None:
    history: List[Dict] = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    history.append(record)
    with open(TRAJECTORY, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")


def _erasure_blocks(n: int) -> List[IngestItem]:
    # scale-proportional block count, full stripes only so scalar and batch
    # encode exactly the same stripe set
    num = max(ERASURE_K, min(400, n // 1000))
    num -= num % ERASURE_K
    rng = np.random.default_rng(7)
    return [IngestItem(rng.integers(0, 256, ERASURE_BLOCK_BYTES,
                                    dtype=np.uint8).tobytes(),
                       Granularity.BLOCK, (), {})
            for _ in range(num)]


def _normalized(items: List[IngestItem]) -> List[tuple]:
    # stripe ids embed a per-instance nonce; strip it for the equality check
    out = []
    for it in items:
        meta = dict(it.meta)
        if "stripe_id" in meta:
            meta["stripe_id"] = meta["stripe_id"].rsplit("-", 1)[-1]
        out.append((bytes(it.data), it.labels, meta))
    return out


def erasure_kernel_tier(n: int) -> Dict[str, float]:
    """Scalar per-stripe erasure encode vs the batched stacked-matmul path
    over identical RS(10,3) stripes of 64 KB blocks.  MB/s counts data bytes
    in (the paper-relevant rate: how fast blocks move through the encode
    stage), best of ``ERASURE_REPEATS``."""
    blocks = _erasure_blocks(n)
    data_mb = len(blocks) * ERASURE_BLOCK_BYTES / 1e6

    def scalar_pass():
        op = resolve_op("erasure", k=ERASURE_K, m=ERASURE_M)
        items = [copy.deepcopy(b) for b in blocks]
        t0 = time.perf_counter()
        out = op.run(items)
        return time.perf_counter() - t0, out

    def batch_pass():
        op = resolve_op("erasure", k=ERASURE_K, m=ERASURE_M)
        items = [copy.deepcopy(b) for b in blocks]
        t0 = time.perf_counter()
        out = op.run_batch(items)
        return time.perf_counter() - t0, out

    scalar_s, scalar_out = min((scalar_pass()
                                for _ in range(ERASURE_REPEATS)),
                               key=lambda t: t[0])
    batch_s, batch_out = min((batch_pass()
                              for _ in range(ERASURE_REPEATS)),
                             key=lambda t: t[0])
    assert _normalized(scalar_out) == _normalized(batch_out), (
        "batched erasure output diverged from the scalar oracle")
    return {
        "erasure_scalar_mb_per_s": data_mb / scalar_s,
        "erasure_mb_per_s": data_mb / batch_s,
        "erasure_batch_speedup": scalar_s / batch_s,
        "erasure_data_mb": data_mb,
    }


def run(n: int = 200_000) -> List[Row]:
    base = plain_upload_seconds(n)
    rows: List[Row] = [("storage/plain_upload", base, "1.00x")]
    for name, build in (("flexible_replication", flexible_replication),
                        ("erasure_rs10_3", erasure_10_3),
                        ("flexible_erasure", flexible_erasure),
                        ("mixed_repl_erasure", mixed_replication_erasure)):
        secs, ds = run_plan_seconds(build, n, keep_store=True)
        stored = ds.total_bytes() / 1e6
        from .common import cleanup
        cleanup(ds)
        rows.append((f"storage/{name}", secs,
                     f"{secs / base:.2f}x;{stored:.1f}MB"))

    # ---- kernel tier: scalar vs batched erasure encode (ISSUE 7)
    kt = erasure_kernel_tier(n)
    rows.append(("storage/erasure_scalar_encode",
                 kt["erasure_data_mb"] / kt["erasure_scalar_mb_per_s"],
                 f"{kt['erasure_scalar_mb_per_s']:.1f} MB/s"))
    rows.append(("storage/erasure_batch_encode",
                 kt["erasure_data_mb"] / kt["erasure_mb_per_s"],
                 f"{kt['erasure_mb_per_s']:.1f} MB/s "
                 f"({kt['erasure_batch_speedup']:.2f}x scalar)"))
    _append_trajectory({
        "ts": time.time(),
        "scale": n,
        "host_cores": os.cpu_count() or 1,
        **{k: v for k, v in kt.items()},
    })
    return rows
