"""Fig. 5(c): analytics-oriented layouts at ingest time vs plain upload.

Per-replica layouts (row / columnar / compressed columnar — the Trojan-Layout
scheme), hybrid replicas (different layouts across a replica's blocks),
content-based partitioning, content-based placement.
"""
from __future__ import annotations

from typing import List

from repro.core import chain_stage, create_stage, format_, select
from repro.core import store as store_stmt
from repro.core.operators import resolve_op

from .common import Row, plain_upload_seconds, run_plan_seconds


def per_replica_layouts(p, ds):
    s1 = select(p, replicate=3, replicate_tag="rep")
    chains = []
    for i, layout in enumerate(("row", "columnar", "cpax"), start=1):
        f = format_(p, s1, chunk={"target_rows": 16384}, serialize=layout)
        st = store_stmt(p, f, upload=ds)
        chains.append((i, [f, st]))
    create_stage(p, using=[s1], name="a")
    for i, stmts in chains:
        chain_stage(p, to=["a"], using=stmts, where={"rep": i}, name=f"r{i}")


def hybrid_replicas(p, ds):
    """One replica, alternating block layouts (hybrid: queries likely find
    some blocks in a favorable layout)."""
    s1 = select(p)
    f = p.add_statement(
        [resolve_op("chunk", target_rows=16384),
         resolve_op("serialize", layout="hybrid",
                    layouts=("row", "columnar", "cpax"))],
        kind="format", inputs=[s1])
    st = store_stmt(p, f, upload=ds)
    create_stage(p, using=[s1, f, st], name="main")


def content_partitioning(p, ds):
    s1 = select(p)
    f = format_(p, s1, partition={"scheme": "range", "key": "orderkey",
                                  "num_partitions": 10},
                chunk={"target_rows": 16384}, serialize="columnar")
    st = store_stmt(p, f, upload=ds)
    create_stage(p, using=[s1, f, st], name="main")


def content_placement(p, ds):
    s1 = select(p)
    f = format_(p, s1, partition={"scheme": "range", "key": "orderkey",
                                  "num_partitions": 10},
                chunk={"target_rows": 16384}, serialize="columnar")
    st = store_stmt(p, f, locate="content", locate_args={"by": "partition"},
                    upload=ds)
    create_stage(p, using=[s1, f, st], name="main")


def run(n: int = 200_000) -> List[Row]:
    base = plain_upload_seconds(n)
    rows: List[Row] = [("layouts/plain_upload", base, "1.00x")]
    for name, build in (("per_replica_layouts", per_replica_layouts),
                        ("hybrid_replicas", hybrid_replicas),
                        ("content_partitioning", content_partitioning),
                        ("content_placement", content_placement)):
        secs, _ = run_plan_seconds(build, n)
        rows.append((f"layouts/{name}", secs, f"{secs / base:.2f}x"))
    return rows
