"""Shared benchmark scaffolding.

Every bench module exposes ``run(scale) -> List[Row]``; a Row is
(name, seconds, derived) where ``derived`` is a short string such as the
overhead ratio vs the plain-upload baseline (the paper reports all of Fig. 5
as overhead over standard HDFS upload).
"""
from __future__ import annotations

import shutil
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import DataStore, IngestPlan, create_stage, format_, ingest, select
from repro.core import store as store_stmt
from repro.data.generators import as_file_items, gen_lineitem

# register the application operator packs (paper Sec. II scenarios)
import repro.cleaning.ops   # noqa: F401
import repro.sampling.ops   # noqa: F401

Row = Tuple[str, float, str]

NODES = ["n0", "n1", "n2", "n3"]


def fresh_store(durable: bool = False, compress: bool = False) -> DataStore:
    return DataStore(tempfile.mkdtemp(prefix="ibench_"), nodes=NODES,
                     durable=durable, compress=compress)


def cleanup(ds: DataStore) -> None:
    shutil.rmtree(ds.root, ignore_errors=True)


def timed(fn: Callable[[], Any]) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


_DATA_CACHE: Dict[int, Any] = {}


def lineitem_shards(n: int, shards: int = 8):
    if n not in _DATA_CACHE:
        _DATA_CACHE[n] = gen_lineitem(n)
    return as_file_items(_DATA_CACHE[n], shards)


REPEATS = 2  # best-of-N (single-core container: first run pays warmup)


def plain_upload_seconds(n: int) -> float:
    """The 'standard HDFS upload' baseline: chunk + raw serialize + upload,
    no preprocessing."""
    best = float("inf")
    for _ in range(REPEATS):
        ds = fresh_store()
        p = IngestPlan("plain")
        s1 = select(p)
        s2 = format_(p, s1, chunk={"target_rows": 16384}, serialize="row")
        s3 = store_stmt(p, s2, upload=ds)
        create_stage(p, using=[s1, s2, s3], name="main")
        best = min(best, timed(lambda: ingest(p, lineitem_shards(n), ds)))
        cleanup(ds)
    return best


def run_plan_seconds(build: Callable[[IngestPlan, DataStore], None], n: int,
                     keep_store: bool = False):
    best, kept = float("inf"), None
    for _ in range(REPEATS):
        ds = fresh_store()
        p = IngestPlan("bench")
        build(p, ds)
        best = min(best, timed(lambda: ingest(p, lineitem_shards(n), ds)))
        if keep_store and kept is None:
            kept = ds
        else:
            cleanup(ds)
    return best, kept
