"""Optimizers, checkpointing (async + elastic), feeder, and dry-run helpers."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import CheckpointManager
from repro.training.optim import (adafactor_init, adafactor_update, adamw_init,
                                  adamw_update, make_optimizer, opt_state_defs,
                                  OptConfig)


# ---------------------------------------------------------------- optimizers
class TestOptimizers:
    def quad_loss(self, p):
        return sum(jnp.sum((x - 3.0) ** 2) for x in jax.tree.leaves(p))

    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_converges_on_quadratic(self, name):
        params = {"w": jnp.zeros((256, 256)), "b": jnp.zeros((8,))}
        init, update, _ = make_optimizer(name, lr=0.5, weight_decay=0.0,
                                         warmup_steps=1)
        state = init(params)
        l0 = float(self.quad_loss(params))
        for _ in range(60):
            g = jax.grad(self.quad_loss)(params)
            params, state, m = update(g, state, params)
        assert float(self.quad_loss(params)) < 0.05 * l0

    def test_adafactor_state_is_factored(self):
        params = {"big": jnp.zeros((512, 256)), "small": jnp.zeros((8,))}
        state = adafactor_init(params, min_dim=128)
        assert set(state["v"]["big"]) == {"vr", "vc"}
        assert state["v"]["big"]["vr"].shape == (512,)
        assert set(state["v"]["small"]) == {"v"}

    def test_opt_state_defs_match_runtime_state(self):
        """ShapeDtypeStructs from opt_state_defs == actual optimizer state
        (so dry-run shardings are valid for the real thing)."""
        from repro.models.params import ParamDef, abstract_params, init_params
        pdefs = {"w": ParamDef((256, 192), ("embed", "ffn"), jnp.float32),
                 "s": ParamDef((16,), (None,), jnp.float32)}
        params = init_params(jax.random.PRNGKey(0), pdefs)
        for name in ("adamw", "adafactor"):
            odefs = opt_state_defs(name, pdefs)
            abstract = abstract_params(odefs)
            init, _, _ = make_optimizer(name)
            real = init(params)
            ab_tree = jax.tree.map(lambda x: (x.shape, str(x.dtype)), abstract)
            re_tree = jax.tree.map(lambda x: (x.shape, str(x.dtype)), real)
            assert ab_tree == re_tree, name

    def test_grad_clipping(self):
        params = {"w": jnp.zeros((4,))}
        g = {"w": jnp.full((4,), 100.0)}
        init, update, _ = make_optimizer("adamw", grad_clip=1.0)
        _, _, m = update(g, init(params), params)
        assert float(m["grad_norm"]) > 1.0  # reports pre-clip norm


# -------------------------------------------------------------- checkpointing
class TestCheckpoint:
    def tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"params": {"w": jax.random.normal(k, (32, 16)),
                           "stack": jax.random.normal(k, (4, 8, 8))},
                "opt": {"mu": jnp.zeros((32, 16)), "step": jnp.asarray(7)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        t = self.tree()
        mgr.save(10, t)
        out = mgr.restore(10, t)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), t, out)

    def test_async_write_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)
        for s in (1, 2, 3, 4):
            mgr.save(s, self.tree(s))
        mgr.wait()
        assert mgr.all_steps() == [3, 4]  # retention gc

    def test_elastic_restore_across_meshes(self, tmp_path):
        """A checkpoint written with one sharding restores onto another mesh
        (here: 1-device mesh with different PartitionSpecs) — the elastic
        scaling path."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.checkpoint import place_on_mesh
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        t = self.tree()
        mgr.save(5, t)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        specs = jax.tree.map(lambda _: P(), t)
        out = mgr.restore(5, t, place=place_on_mesh(mesh, specs))
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), t, out)
        leaf = out["params"]["w"]
        assert isinstance(leaf.sharding, NamedSharding)

    def test_interrupted_write_not_published(self, tmp_path):
        """A .tmp dir (simulated mid-write crash) is never listed as a step."""
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(1, self.tree())
        os.makedirs(str(tmp_path / "step_000000002.tmp"))
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1


# -------------------------------------------------------------------- feeder
class TestFeeder:
    def _ingest(self, tmp_path, n_docs=300, seq_len=128):
        from repro.core import DataStore
        from repro.data.feeder import ingest_corpus
        from repro.data.generators import gen_token_documents
        ds = DataStore(str(tmp_path / "c"), nodes=["n0", "n1"])
        docs = gen_token_documents(n_docs, vocab=1000, max_len=seq_len)
        ingest_corpus(docs, ds, seq_len=seq_len, rows_per_block=8)
        return ds

    def test_batches_have_model_shape(self, tmp_path):
        from repro.data.feeder import BlockFeeder
        ds = self._ingest(tmp_path)
        f = BlockFeeder(ds, batch_rows=4)
        b = next(iter(f.batches(1)))
        assert b["tokens"].shape == (4, 128)
        assert set(b) == {"tokens", "loss_mask", "positions", "segment_ids"}

    def test_resumable_position(self, tmp_path):
        from repro.data.feeder import BlockFeeder
        ds = self._ingest(tmp_path)
        f1 = BlockFeeder(ds, batch_rows=4, seed=1)
        first = [b["tokens"].sum() for b in f1.batches(4)]
        # resume from step 2: same stream suffix
        f2 = BlockFeeder(ds, batch_rows=4, seed=1, start_step=f1.step)
        nxt = next(iter(f2.batches(1)))
        f3 = BlockFeeder(ds, batch_rows=4, seed=1)
        replay = [b["tokens"].sum() for b in f3.batches(5)]
        assert replay[:4] == first

    def test_resume_equivalence_at_every_step(self, tmp_path):
        """Stop/restart at EVERY step yields the exact reference stream.

        batch_rows=3 never divides the 8-row blocks, so every batch leaves
        carry rows; before the (step, offset) cursor those rows were dropped
        or replayed on restart (bugfix, ISSUE 6)."""
        from repro.data.feeder import BlockFeeder
        ds = self._ingest(tmp_path)
        n = 12
        ref = list(BlockFeeder(ds, batch_rows=3, seed=7).batches(n))
        assert len(ref) == n
        for stop in range(n):
            f1 = BlockFeeder(ds, batch_rows=3, seed=7)
            head = list(f1.batches(stop))
            f2 = BlockFeeder(ds, batch_rows=3, seed=7,
                             start_step=f1.step, start_offset=f1.offset)
            stream = head + list(f2.batches(n - stop))
            assert len(stream) == n, stop
            for want, got in zip(ref, stream):
                for field in want:
                    np.testing.assert_array_equal(want[field], got[field])

    def test_work_stealing_queue_yields_all(self, tmp_path):
        from repro.data.feeder import BlockFeeder
        ds = self._ingest(tmp_path)
        feeders = [BlockFeeder(ds, num_tasks=2, task=t, batch_rows=4)
                   for t in range(2)]
        q = BlockFeeder.stealing_queue(feeders, num_steps=6)
        got = [q.get(timeout=10) for _ in range(6)]
        assert len(got) == 6
        for t in q.workers:
            t.join(timeout=5)
        assert not any(t.is_alive() for t in q.workers)
        assert q.delivered() == 6

    def test_work_stealing_queue_consumer_abandons(self, tmp_path):
        """A consumer that walks away mid-stream must not strand the workers.

        Before the fix the done event was never set and workers blocked
        forever on q.put() into the full queue (bugfix, ISSUE 6)."""
        from repro.data.feeder import BlockFeeder
        ds = self._ingest(tmp_path)
        feeders = [BlockFeeder(ds, num_tasks=2, task=t, batch_rows=4)
                   for t in range(2)]
        q = BlockFeeder.stealing_queue(feeders, num_steps=50)
        for _ in range(3):
            q.get(timeout=10)
        q.stop()   # the consumer abandons the stream
        for t in q.workers:
            t.join(timeout=5)
        assert not any(t.is_alive() for t in q.workers)
        # delivered counts only batches actually placed: at most the 3 we
        # consumed + the queue capacity (8) + one in-flight put per worker
        assert q.delivered() <= 3 + 8 + len(feeders)


# --------------------------------------------------------- dry-run utilities
class TestDryrunHelpers:
    def test_collective_parser_ring_model(self):
        from repro.launch.dryrun import parse_collectives
        hlo = """
  %ar = f32[16,1024]{1,0} all-reduce(f32[16,1024] %x), replica_groups=[16,16]<=[256]
  %ag = bf16[8,4096]{1,0} all-gather(bf16[8,256] %y), replica_groups={{0,1,2,3}}
  %t = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-reduce(%a, %b), replica_groups=[16,16]<=[16,16]T(1,0)
        """
        out = parse_collectives(hlo)
        assert out["by_kind_count"]["all-reduce"] == 2
        assert out["by_kind_count"]["all-gather"] == 1
        ar1 = 2 * (16 * 1024 * 4) * 15 / 16
        ag = (8 * 4096 * 2) * 3 / 4
        art = 2 * (2 * 4 * 4 * 4) * 15 / 16
        assert abs(out["total_bytes"] - (ar1 + ag + art)) < 1

    def test_extrapolation_is_linear(self):
        from repro.launch.dryrun import _extrapolate
        c1 = {"flops": 10.0, "bytes": 100.0, "bytes_raw": 200.0,
              "coll": {"total_bytes": 6.0, "by_kind_bytes": {"all-reduce": 6.0},
                       "by_kind_count": {"all-reduce": 2}}}
        c2 = {"flops": 14.0, "bytes": 120.0, "bytes_raw": 260.0,
              "coll": {"total_bytes": 8.0, "by_kind_bytes": {"all-reduce": 8.0},
                       "by_kind_count": {"all-reduce": 3}}}
        out = _extrapolate(c1, c2, 10)
        assert out["flops"] == 10 + 4 * 9
        assert out["coll"]["by_kind_bytes"]["all-reduce"] == 6 + 2 * 9
        assert out["coll"]["by_kind_count"]["all-reduce"] == 2 + 1 * 9

    def test_sharding_rules_divisibility(self):
        """9 heads never shard 16 ways; vocab multiples of 256 do."""
        from repro.models.params import logical_to_spec
        rules = {"heads": "model", "vocab": "model", "embed": "data"}
        sizes = {"data": 16, "model": 16}
        spec = logical_to_spec(("vocab", "embed"), rules, (49152, 576), sizes)
        assert spec == jax.sharding.PartitionSpec("model", "data")
        spec = logical_to_spec(("embed", "heads", None), rules, (576, 9, 64), sizes)
        assert spec == jax.sharding.PartitionSpec("data",)
