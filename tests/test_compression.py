"""Error-feedback int8 gradient compression (the cross-pod DCN trick)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.training.compression import (compression_ratio, ef_compress,
                                        ef_decompress, ef_init)


def test_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))}
    ef = ef_init(g)
    q, ef2 = ef_compress(g, ef)
    out = ef_decompress(q)
    # per-tensor int8: error bounded by scale/2 = amax/254
    amax = float(jnp.abs(g["w"]).max())
    assert float(jnp.abs(out["w"] - g["w"]).max()) <= amax / 254 + 1e-6
    # the residual carries exactly what was lost
    np.testing.assert_allclose(np.asarray(ef2["w"]),
                               np.asarray(g["w"] - out["w"]), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_error_feedback_compensates_over_steps(seed):
    """Sum of dequantized grads + final residual == sum of true grads:
    error feedback makes the compressed stream unbiased over time."""
    rng = np.random.default_rng(seed)
    true_sum = np.zeros((32,), np.float32)
    deq_sum = np.zeros((32,), np.float32)
    ef = ef_init({"g": jnp.zeros((32,))})
    for _ in range(10):
        g = {"g": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
        q, ef = ef_compress(g, ef)
        out = ef_decompress(q)
        true_sum += np.asarray(g["g"])
        deq_sum += np.asarray(out["g"])
    np.testing.assert_allclose(deq_sum + np.asarray(ef["g"]), true_sum,
                               atol=1e-4)


def test_ratio_is_4x():
    params = {"a": jnp.zeros((1024, 1024)), "b": jnp.zeros((512,))}
    assert compression_ratio(params) < 0.2501


def test_training_converges_with_compression():
    """A quadratic optimized with compressed grads still converges."""
    from repro.training.optim import make_optimizer
    params = {"w": jnp.zeros((64, 64))}
    init, update, _ = make_optimizer("adamw", lr=0.3, weight_decay=0.0,
                                     warmup_steps=1)
    state = init(params)
    ef = ef_init(params)
    loss = lambda p: jnp.sum((p["w"] - 2.0) ** 2)
    l0 = float(loss(params))
    for _ in range(80):
        g = jax.grad(loss)(params)
        q, ef = ef_compress(g, ef)
        g = ef_decompress(q)
        params, state, _ = update(g, state, params)
    assert float(loss(params)) < 0.05 * l0
