"""Multiprocess node backend (ISSUE 3): the shared-memory item codec, plan
shipping over the pickle seam, coordinator-routed commits, worker-death
mapping onto epoch replay, and thread/process output equivalence.

The streaming classes here are the acceptance subset: shuffle, epoch commit
ordering, and node-death replay all running with ``backend="process"``.
"""
import pickle
import threading
import time

import numpy as np
import pytest

from repro.core import (DataAccess, DataStore, FaultInjection, IngestPlan,
                        RuntimeEngine, StreamFaultInjection,
                        StreamingRuntimeEngine, chain_stage, create_stage,
                        decode_items, encode_items, format_, resolve_op,
                        select, serialize_plans)
from repro.core import store as store_stmt
from repro.core.items import Granularity, IngestItem
from repro.core.ops_select import FilterOp, MapOp
from repro.data.generators import gen_lineitem


def columnar_plan(ds, *, name="proc"):
    p = IngestPlan(name)
    s1 = select(p)
    s2 = format_(p, s1, chunk={"target_rows": 256}, serialize="columnar")
    s3 = store_stmt(p, s2, locate="roundrobin",
                    locate_args={"num_locations": len(ds.nodes)}, upload=ds)
    create_stage(p, using=[s1, s2, s3], name="main")
    return p


def shuffled_plan(ds):
    """Ingest segment (parse + partition + shuffle, chunk + serialize) and
    store segment (upload) — every op picklable for the process seam."""
    p = IngestPlan("shuf")
    s1 = p.add_statement([
        resolve_op("identity_parser"),
        resolve_op("partition", scheme="hash", key="orderkey", num_partitions=4),
        resolve_op("map", fn="repro.core.ops_select:identity_columns",
                   shuffle_by="partition"),
    ], kind="select")
    s2 = p.add_statement([
        resolve_op("chunk", target_rows=256),
        resolve_op("serialize", layout="columnar"),
    ], kind="format", inputs=[s1])
    s3 = p.add_statement([resolve_op("upload", store=ds)],
                         kind="store", inputs=[s2])
    create_stage(p, using=[s1], name="a")
    chain_stage(p, to=["a"], using=[s2], name="b")
    chain_stage(p, to=["b"], using=[s3], name="c")
    return p


def shard_source(n_shards, rows=100, delay_s=0.0):
    for i in range(n_shards):
        if delay_s:
            time.sleep(delay_s)
        yield IngestItem(gen_lineitem(rows, seed=i))


# ---------------------------------------------------------------------------
class TestShmCodec:
    def test_large_batch_rides_shared_memory_zero_copy(self):
        items = [IngestItem({"x": np.arange(20000, dtype=np.int64),
                             "y": np.ones(20000, dtype=np.float32)}
                            ).with_label("parser", i) for i in range(3)]
        payload, lease = encode_items(items, shm_min_bytes=1024)
        assert payload["kind"] == "shm"
        lease.detach()
        out, rlease = decode_items(payload)
        assert rlease is not None
        assert all(np.array_equal(a.data["x"], b.data["x"])
                   and np.array_equal(a.data["y"], b.data["y"])
                   and a.labels == b.labels for a, b in zip(items, out))
        # receive side is zero-copy: arrays view the mapped segment
        assert out[0].data["x"].base is not None
        del out
        rlease.release()

    def test_small_batch_inlines_as_pickle(self):
        items = [IngestItem({"x": np.arange(4)})]
        payload, lease = encode_items(items)
        assert payload["kind"] == "pickle" and lease is None
        out, rlease = decode_items(payload)
        assert rlease is None
        np.testing.assert_array_equal(out[0].data["x"], np.arange(4))

    def test_copy_mode_destroys_segment(self):
        from multiprocessing import shared_memory
        items = [IngestItem({"x": np.arange(50000, dtype=np.int64)})]
        payload, lease = encode_items(items, shm_min_bytes=1024)
        lease.detach()
        out, rlease = decode_items(payload, copy=True)
        assert rlease is None
        np.testing.assert_array_equal(out[0].data["x"], np.arange(50000))
        with pytest.raises(FileNotFoundError):   # consumed exactly once
            shared_memory.SharedMemory(name=payload["shm"])

    def test_non_array_payloads_roundtrip(self):
        items = [IngestItem(b"raw file bytes" * 10000),
                 IngestItem({"x": np.arange(30000, dtype=np.int64)})]
        payload, lease = encode_items(items, shm_min_bytes=1024)
        if lease is not None:
            lease.detach()
        out, rlease = decode_items(payload, copy=True)
        assert out[0].data == items[0].data
        np.testing.assert_array_equal(out[1].data["x"], items[1].data["x"])
        assert rlease is None


# ---------------------------------------------------------------------------
class TestPlanShipping:
    def test_ops_pickle_by_spec(self):
        op = FilterOp(predicate=("quantity", ">", 10))
        clone = pickle.loads(pickle.dumps(op))
        cols = {"quantity": np.array([5, 20, 30], dtype=np.int32)}
        out = clone.run([IngestItem(cols, Granularity.CHUNK)])
        assert out[0].nrows() == 2
        m = pickle.loads(pickle.dumps(
            MapOp(fn="repro.core.ops_select:identity_columns")))
        assert m.run([IngestItem(cols, Granularity.CHUNK)])[0].data is not None

    def test_closure_param_raises_named_error(self, store):
        p = IngestPlan("bad")
        p.add_statement([resolve_op("identity_parser"),
                         resolve_op("map", fn=lambda c: c)], kind="select")
        create_stage(p, using=["s1"], name="main")
        with pytest.raises(TypeError, match=r"stage 'main' op \[1\].*MapOp"):
            serialize_plans(p.compile())

    def test_process_backend_rejects_foreign_store(self, store, tmp_path):
        other = DataStore(str(tmp_path / "other"), nodes=store.nodes)
        p = columnar_plan(other)
        eng = StreamingRuntimeEngine(store, epoch_items=4, backend="process")
        try:
            with pytest.raises(ValueError, match="engine's store"):
                eng.run_stream(p, shard_source(4))
        finally:
            eng.close()


# ---------------------------------------------------------------------------
class TestProcessStreaming:
    def test_matches_thread_backend_output(self, tmp_path):
        rows = {}
        for backend in ("thread", "process"):
            ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1", "n2", "n3"])
            eng = StreamingRuntimeEngine(ds, epoch_items=4, queue_capacity=8,
                                         backend=backend)
            rep = eng.run_stream(shuffled_plan(ds), shard_source(12, rows=100))
            assert rep.committed_epoch_ids() == [0, 1, 2]
            cols = DataAccess(ds).since_epoch(-1).read_all(projection=["quantity"])
            rows[backend] = np.sort(cols["quantity"])
            eng.close()
        np.testing.assert_array_equal(rows["thread"], rows["process"])

    def test_shuffle_exact_once(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     backend="process")
        rep = eng.run_stream(shuffled_plan(store), shard_source(8, rows=100))
        assert sum(e.run.shuffled_items for e in rep.epochs) > 0
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 100
        eng.close()

    def test_commit_ordering_under_concurrent_reader(self, store):
        """Epoch commit ordering: a reader polling mid-stream only ever sees
        gap-free committed prefixes while process workers ingest."""
        stop = threading.Event()
        bad: list = []

        def poll():
            while not stop.is_set():
                ids = store.committed_epoch_ids()
                if ids != list(range(len(ids))):
                    bad.append(ids)
                time.sleep(0.002)

        reader = threading.Thread(target=poll, daemon=True)
        reader.start()
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     backend="process")
        rep = eng.run_stream(shuffled_plan(store), shard_source(16, rows=60))
        stop.set()
        reader.join(timeout=5)
        eng.close()
        assert not bad, f"non-contiguous commit observations: {bad[:5]}"
        assert rep.committed_epoch_ids() == [0, 1, 2, 3]

    def test_injected_node_death_replays_epoch(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     backend="process")
        faults = StreamFaultInjection(node_death_in_epoch={"n2": 1})
        rep = eng.run_stream(shuffled_plan(store), shard_source(16, rows=100),
                             faults=faults)
        ids = rep.committed_epoch_ids()
        assert ids == [0, 1, 2, 3]
        assert rep.node_failures == ["n2"]
        assert rep.replayed_epochs == [1]
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 16 * 100
        eng.close()

    def test_real_worker_kill_maps_to_epoch_replay(self, store):
        """SIGTERM a live worker process mid-stream: pipe EOF is the death
        sentinel, the node joins the existing fault path, the epoch replays
        on survivors, and no items are lost."""
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     backend="process")
        eng.prewarm_executors()
        killer = threading.Timer(0.3, lambda: eng.executor("n1").kill())
        killer.start()
        rep = eng.run_stream(shuffled_plan(store),
                             shard_source(16, rows=100, delay_s=0.05))
        killer.cancel()
        ids = rep.committed_epoch_ids()
        assert ids == list(range(len(ids))) and len(ids) == 4
        assert "n1" in rep.node_failures
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 16 * 100
        eng.close()

    def test_injected_op_failures_are_retried(self, store):
        eng = StreamingRuntimeEngine(store, epoch_items=4, queue_capacity=8,
                                     backend="process", max_retries=5)
        faults = StreamFaultInjection(op_failures={("main", 0): 2})
        rep = eng.run_stream(columnar_plan(store), shard_source(8, rows=50),
                             faults=faults)
        total_failures = sum(e.run.op_failures.get("main[0]", 0)
                             for e in rep.epochs)
        assert total_failures >= 2
        assert not any(e.run.dummy_substitutions for e in rep.epochs)
        cols = DataAccess(store).since_epoch(-1).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 50   # retries, no loss
        eng.close()


# ---------------------------------------------------------------------------
class TestProcessBatch:
    def test_batch_run_equivalent(self, tmp_path):
        totals = {}
        for backend in ("thread", "process"):
            ds = DataStore(str(tmp_path / backend), nodes=["n0", "n1"])
            with RuntimeEngine(ds, backend=backend) as eng:
                rep = eng.run(columnar_plan(ds),
                              list(shard_source(8, rows=100)))
            assert rep.stage_items["main"] > 0
            cols = DataAccess(ds).read_all(projection=["quantity"])
            totals[backend] = (len(cols["quantity"]),
                               int(cols["quantity"].sum()))
        assert totals["thread"] == totals["process"]

    def test_batch_injected_death_reassigns_shards(self, store):
        """Death after the pre-upload stage: the dead worker's shards replay
        on the next live node's worker, exactly once end-to-end."""
        p = IngestPlan("batch2")
        s1 = p.add_statement([resolve_op("identity_parser"),
                              resolve_op("chunk", target_rows=256),
                              resolve_op("serialize", layout="columnar")],
                             kind="select")
        s2 = p.add_statement([resolve_op("upload", store=store)],
                             kind="store", inputs=[s1])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b")
        eng = RuntimeEngine(store, backend="process")
        faults = FaultInjection(node_death_after_stage={"n1": "a"})
        rep = eng.run(p, list(shard_source(8, rows=50)), faults=faults)
        assert "n1" in rep.node_failures
        assert rep.reassigned_shards > 0
        cols = DataAccess(store).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 50
        eng.close()

    def test_batch_replay_survives_target_worker_death(self, store):
        """The reassignment target's worker dies right before the replay job:
        the replay loop marks it dead and moves the shards to the next
        survivor instead of surfacing a raw WorkerDeath."""
        p = IngestPlan("batch3")
        s1 = p.add_statement([resolve_op("identity_parser"),
                              resolve_op("chunk", target_rows=256),
                              resolve_op("serialize", layout="columnar")],
                             kind="select")
        s2 = p.add_statement([resolve_op("upload", store=store)],
                             kind="store", inputs=[s1])
        create_stage(p, using=[s1], name="a")
        chain_stage(p, to=["a"], using=[s2], name="b")
        eng = RuntimeEngine(store, backend="process")
        eng.prewarm_executors()
        ex2 = eng.executor("n2")
        orig = ex2.run_stage
        calls = {"n": 0}

        def flaky(*a, **kw):
            calls["n"] += 1
            if calls["n"] == 2:      # call 1 = own stage "a"; call 2 = replay
                ex2.kill()
                time.sleep(0.4)      # let the EOF sentinel land
            return orig(*a, **kw)

        ex2.run_stage = flaky
        faults = FaultInjection(node_death_after_stage={"n1": "a"})
        rep = eng.run(p, list(shard_source(8, rows=50)), faults=faults)
        assert "n1" in rep.node_failures and "n2" in rep.node_failures
        cols = DataAccess(store).read_all(projection=["quantity"])
        assert len(cols["quantity"]) == 8 * 50
        eng.close()

    def test_worker_plan_state_persists_dummy_substitution(self, store):
        """An operator failing past max_retries is dummy-substituted inside
        the worker's resident plan (paper Sec. VI-C1), and the substitution
        is reported back to the coordinator."""
        eng = StreamingRuntimeEngine(store, epoch_items=8, queue_capacity=8,
                                     backend="process", max_retries=2)
        faults = StreamFaultInjection(op_failures={("main", 1): 4})
        rep = eng.run_stream(columnar_plan(store), shard_source(8, rows=50),
                             faults=faults)
        subs = [s for e in rep.epochs for s in e.run.dummy_substitutions]
        assert any("main[1]" in s for s in subs)
        eng.close()
